# Repo task runner. `make test` is the tier-1 gate (see ROADMAP.md).
PY ?= python
export PYTHONPATH := src:.

.PHONY: test bench-smoke bench-serving bench-kernels

test:
	$(PY) -m pytest -x -q

# tiny-size benchmark smoke: serving (static vs continuous + paged vs
# contiguous) + kernels
bench-smoke: bench-kernels
	$(PY) benchmarks/serving_bench.py --smoke --check

# full-size serving benchmark with the acceptance checks (continuous >=1.5x
# static; paged >=2x residents at equal KV memory, tokens/s within 5%)
bench-serving:
	$(PY) benchmarks/serving_bench.py --check

# kernel microbenchmark smoke (interpret mode off-TPU); leaves a JSON
# artifact at results/benchmarks/kernels_bench.json for CI to upload
bench-kernels:
	$(PY) -c "from benchmarks.kernels_bench import run; run(quick=True)"
