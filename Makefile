# Repo task runner. `make test` is the tier-1 gate (see ROADMAP.md).
PY ?= python
export PYTHONPATH := src:.

.PHONY: test test-opt bench-smoke bench-serving bench-serving-smoke \
	bench-kernels bench-cluster-smoke

test:
	$(PY) -m pytest -x -q

# the guard-path tests under python -O: bare asserts are stripped there, so
# this lane proves the engine/scheduler guards are real exceptions
test-opt:
	$(PY) -O -m pytest tests/test_scheduler.py tests/test_cluster_engines.py -q

# tiny-size benchmark smoke: serving (static vs continuous + paged vs
# contiguous + prefix-cache scenarios) + kernels + closed-loop cluster
bench-smoke: bench-kernels bench-serving-smoke bench-cluster-smoke

# serving benchmark smoke (tiny config, prefix scenario included); leaves a
# JSON artifact at results/benchmarks/serving_bench.json for CI to upload
bench-serving-smoke:
	$(PY) benchmarks/serving_bench.py --smoke --check

# full-size serving benchmark with the acceptance checks (continuous >=1.5x
# static; paged >=2x residents at equal KV memory; prefix cache >=2x prefill
# throughput at 90% shared prefix, token-identical, bounded prefill traces)
bench-serving:
	$(PY) benchmarks/serving_bench.py --check

# kernel microbenchmark smoke (interpret mode off-TPU); leaves a JSON
# artifact at results/benchmarks/kernels_bench.json for CI to upload
bench-kernels:
	$(PY) -c "from benchmarks.kernels_bench import run; run(quick=True)"

# closed-loop cluster smoke: eaco + the four fixed arms served end-to-end
# through shared real engine pools on one virtual clock; checks every query
# completes, zero decode retraces per engine, sane Table-4 cost structure.
# Leaves results/benchmarks/cluster_bench.json for CI to upload
bench-cluster-smoke:
	$(PY) benchmarks/cluster_bench.py --smoke --check
