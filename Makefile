# Repo task runner. `make test` is the tier-1 gate (see ROADMAP.md).
PY ?= python
export PYTHONPATH := src:.

.PHONY: test bench-smoke bench-serving

test:
	$(PY) -m pytest -x -q

# tiny-size benchmark smoke: serving (static vs continuous) + kernels
bench-smoke:
	$(PY) benchmarks/serving_bench.py --smoke --check
	$(PY) -c "from benchmarks.kernels_bench import run; run(quick=True)"

# full-size serving benchmark with the >=1.5x acceptance check
bench-serving:
	$(PY) benchmarks/serving_bench.py --check
