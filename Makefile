# Repo task runner. `make test` is the tier-1 gate (see ROADMAP.md).
PY ?= python
export PYTHONPATH := src:.

.PHONY: test test-opt bench-smoke bench-serving bench-serving-smoke \
	bench-kernels bench-cluster-smoke bench-overload-smoke bench-overload \
	bench-chaos-smoke bench-chaos fuzz fuzz-smoke fuzz-replay fuzz-shrink

test:
	$(PY) -m pytest -x -q

# the guard-path tests under python -O: bare asserts are stripped there, so
# this lane proves the engine/scheduler guards are real exceptions. The
# fault-injection tests repeat under three hash seeds: crash/partition
# recovery must not lean on dict/set iteration order
test-opt:
	$(PY) -O -m pytest tests/test_scheduler.py tests/test_cluster_engines.py \
		tests/test_preemption.py tests/test_faults.py tests/test_health.py -q
	for s in 1 2 3; do \
		PYTHONHASHSEED=$$s $(PY) -O -m pytest tests/test_faults.py \
			tests/test_crash_recovery.py -q || exit 1; \
	done

# tiny-size benchmark smoke: serving (static vs continuous + paged vs
# contiguous + prefix-cache scenarios) + kernels + closed-loop cluster +
# overload robustness
bench-smoke: bench-kernels bench-serving-smoke bench-cluster-smoke \
	bench-overload-smoke bench-chaos-smoke

# serving benchmark smoke (tiny config, prefix scenario included); leaves a
# JSON artifact at results/benchmarks/serving_bench.json for CI to upload
bench-serving-smoke:
	$(PY) benchmarks/serving_bench.py --smoke --check

# full-size serving benchmark with the acceptance checks (continuous >=1.5x
# static; paged >=2x residents at equal KV memory; prefix cache >=2x prefill
# throughput at 90% shared prefix, token-identical, bounded prefill traces)
bench-serving:
	$(PY) benchmarks/serving_bench.py --check

# kernel microbenchmark smoke (interpret mode off-TPU); leaves a JSON
# artifact at results/benchmarks/kernels_bench.json for CI to upload
bench-kernels:
	$(PY) -c "from benchmarks.kernels_bench import run; run(quick=True)"

# closed-loop cluster smoke: eaco + the four fixed arms served end-to-end
# through shared real engine pools on one virtual clock; checks every query
# completes, request conservation (submitted == completed + shed + failed),
# zero decode retraces per engine, sane Table-4 cost structure.
# Leaves results/benchmarks/cluster_bench.json for CI to upload
bench-cluster-smoke:
	$(PY) benchmarks/cluster_bench.py --smoke --check

# overload robustness smoke: 1x/2x/5x oversubscription + no-preemption
# baseline + fault injection on one edge engine (virtual clock, modeled
# service times); gates on zero wedges, request conservation, token-identical
# preempt/resume, and interactive p95 at 2x meeting the SLO and beating the
# baseline. Leaves results/benchmarks/overload_bench.json for CI to upload
bench-overload-smoke:
	$(PY) benchmarks/overload_bench.py --smoke --check

# full-size overload benchmark with the same gates
bench-overload:
	$(PY) benchmarks/overload_bench.py --check

# chaos smoke: engine crash/restart + pinned flaky node + stall spikes +
# cluster-level crash/partition run. Gates: crash-and-restart loses zero
# requests (token-identical re-serves), the breaker bounds post-crash p95
# and cuts requeue churn vs no-breaker, hedging cuts tail p99 under
# spikes, no unflagged stale-epoch completions, anti-entropy runs on
# partition heal, and the gate never selects a masked arm. Leaves
# results/benchmarks/chaos_bench.json for CI to upload
bench-chaos-smoke:
	$(PY) benchmarks/chaos_bench.py --smoke --check

# full-size chaos benchmark with the same gates
bench-chaos:
	$(PY) benchmarks/chaos_bench.py --check

# ---- deterministic simulation testing (src/repro/cluster/dst.py) ------
# Randomized seeded chaos schedules over real engine pools with per-pump
# invariant oracles (conservation, fences, breaker legality, monotone
# epochs, page-arena audit, token identity). A failing seed records a
# JSON trace that replays byte-identically and ddmin-shrinks to a
# minimal event schedule; minimized traces land under results/dst/.
#
#   make fuzz SEED=7           # 50 seeds starting at 7 (SEEDS=n to vary)
#   make fuzz-replay TRACE=results/dst/seed7.min.json
#   make fuzz-shrink TRACE=results/dst/seed7.json
SEED ?= 0
SEEDS ?= 50
fuzz:
	$(PY) benchmarks/dst_bench.py --check --seed $(SEED) --seeds $(SEEDS)

# CI lane: a small seed sweep under two PYTHONHASHSEEDs (oracle results
# must not lean on dict/set iteration order); on failure the minimized
# trace JSON under results/dst/ is the artifact to upload
fuzz-smoke:
	for s in 1 2; do \
		PYTHONHASHSEED=$$s $(PY) benchmarks/dst_bench.py --smoke --check \
			|| exit 1; \
	done

# deterministically re-run a recorded trace; exits 1 on any divergence
fuzz-replay:
	$(PY) benchmarks/dst_bench.py --replay $(TRACE)

# ddmin-minimize a failing recorded trace to its minimal event schedule
fuzz-shrink:
	$(PY) benchmarks/dst_bench.py --shrink $(TRACE)
