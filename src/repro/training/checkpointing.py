"""Checkpointing: msgpack-serialized pytrees (params + optimizer state).

Arrays are stored as (dtype, shape, raw bytes); the tree structure is
reconstructed against a template pytree on load, so sharded/replicated
restore just requires re-placing leaves.
"""
from __future__ import annotations

import io
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import ml_dtypes
import msgpack
import numpy as np


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _pack_leaf(x) -> Dict[str, Any]:
    a = np.asarray(x)
    return {"dtype": a.dtype.name, "shape": list(a.shape),
            "data": a.tobytes()}


def _unpack_leaf(d) -> np.ndarray:
    return np.frombuffer(d["data"], dtype=_np_dtype(d["dtype"])).reshape(
        d["shape"])


def save_checkpoint(path: str, params, opt_state=None,
                    meta: Optional[dict] = None) -> None:
    leaves_p, treedef_p = jax.tree.flatten(params)
    payload = {
        "params": [_pack_leaf(l) for l in leaves_p],
        "meta": meta or {},
    }
    if opt_state is not None:
        leaves_o, _ = jax.tree.flatten(opt_state)
        payload["opt_state"] = [_pack_leaf(l) for l in leaves_o]
    Path(path).parent.mkdir(parents=True, exist_ok=True)
    with open(path, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))


def load_checkpoint(path: str, params_template, opt_template=None
                    ) -> Tuple[Any, Any, dict]:
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves_p, treedef_p = jax.tree.flatten(params_template)
    restored_p = [jnp.asarray(_unpack_leaf(d), l.dtype)
                  for d, l in zip(payload["params"], leaves_p)]
    params = treedef_p.unflatten(restored_p)
    opt_state = None
    if opt_template is not None and "opt_state" in payload:
        leaves_o, treedef_o = jax.tree.flatten(opt_template)
        restored_o = [jnp.asarray(_unpack_leaf(d), l.dtype)
                      for d, l in zip(payload["opt_state"], leaves_o)]
        opt_state = treedef_o.unflatten(restored_o)
    return params, opt_state, payload["meta"]


__all__ = ["save_checkpoint", "load_checkpoint"]
