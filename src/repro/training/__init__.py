from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update, lr_schedule
from repro.training.steps import (
    init_train_state, make_decode_step, make_prefill_step, make_train_step,
)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "lr_schedule",
           "make_train_step", "make_prefill_step", "make_decode_step",
           "init_train_state"]
