"""jit-able train / prefill / decode step builders used by the launcher,
the serving engine and the dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


def make_train_step(model: Model, opt_cfg: Optional[AdamWConfig] = None):
    opt_cfg = opt_cfg or AdamWConfig()

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = model.train_loss(p, batch)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, tokens, memory=None):
        return model.prefill(params, tokens, memory)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens1, positions):
        return model.decode_step(params, cache, tokens1, positions)
    return decode_step


def init_train_state(model: Model, key):
    params = model.init(key)
    return params, adamw_init(params)


__all__ = ["make_train_step", "make_prefill_step", "make_decode_step",
           "init_train_state"]
