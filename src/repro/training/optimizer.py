"""AdamW in pure JAX (no optax offline). Moments are f32 regardless of the
parameter dtype; the update is computed in f32 and cast back.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_init(params):
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(f32, params),
        "nu": jax.tree.map(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(cfg: AdamWConfig, grads, opt_state, params):
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(cfg, step)
    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32) * scale
        mu2 = cfg.b1 * mu + (1 - cfg.b1) * g
        nu2 = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu2 / b1t
        vhat = nu2 / b2t
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mu2, nu2

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, mu, nu, p) for g, mu, nu, p in
           zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "mu": treedef.unflatten([o[1] for o in out]),
        "nu": treedef.unflatten([o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "lr_schedule",
           "global_norm"]
