"""Serving/cluster health primitives: the circuit breaker.

Hard failures repeat: a flaky edge node that crashed once will very likely
crash again inside the same incident window, and routing fresh work onto it
just feeds the failure (every lost residency is a request that restarts
from its original prompt). A :class:`CircuitBreaker` is the standard fix —
per protected resource (a pool engine, a whole tier) it tracks consecutive
failures and trips open, shedding the resource from routing until a timed
half-open probe proves it healthy again.

State machine (driven entirely by an injected clock — virtual time in
simulations, ``time.perf_counter`` live)::

    closed ──[threshold consecutive failures]──> open
    open   ──[reset_timeout_s elapsed]─────────> half_open
    half_open ──[probe admitted, succeeds]─────> closed
    half_open ──[any failure]──────────────────> open (timer restarts)

``allow()`` answers "may new work be routed here right now": always in
``closed``, never in ``open``, and exactly ONE in-flight probe at a time in
``half_open`` (callers mark the probe with :meth:`begin_probe` when they
actually commit work — ``allow`` alone never consumes the probe slot, so a
caller that asks but then admits elsewhere doesn't burn it).

The breaker never touches the resource it guards; it is pure host-side
bookkeeping consulted at routing time, exactly like the
:class:`~repro.serving.paging.PageAllocator` is consulted at admission
time. Failure *sources* are the caller's choice: the scheduler records a
failure per resident lost to an engine crash and per stuck-resident
timeout; the cluster records tier-level sheds, drops and crash events.
"""
from __future__ import annotations

from typing import Dict, Optional

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


class CircuitBreaker:
    """Consecutive-failure breaker with timed half-open probes."""

    def __init__(self, threshold: int = 3, reset_timeout_s: float = 5.0):
        if threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {threshold}")
        if reset_timeout_s <= 0:
            raise ValueError(
                f"reset_timeout_s must be > 0, got {reset_timeout_s}")
        self.threshold = threshold
        self.reset_timeout_s = reset_timeout_s
        self._state = CLOSED
        self._failures = 0            # consecutive failures since success
        self._opened_at = 0.0
        self._probing = False         # half-open probe committed, in flight
        self.trips = 0                # closed/half_open -> open transitions
        self.probes = 0               # half-open probes committed

    # ------------------------------------------------------------------
    def state(self, now: float) -> str:
        """Current state at time ``now`` (promotes open -> half_open once
        the reset timeout has elapsed)."""
        if (self._state == OPEN
                and now - self._opened_at >= self.reset_timeout_s):
            self._state = HALF_OPEN
            self._probing = False
        return self._state

    @property
    def consecutive_failures(self) -> int:
        return self._failures

    @property
    def opened_at(self) -> float:
        """Clock time of the most recent failure while tripped (the reset
        window counts from here). Meaningful only after the first trip."""
        return self._opened_at

    @property
    def probing(self) -> bool:
        """Is the single half-open probe slot currently occupied?"""
        return self._probing

    def snapshot(self, now: float) -> Dict[str, object]:
        """Machine-readable state for DST oracle snapshots / wedge dumps.
        Legality checkers use ``state``/``opened_at``: the only admissible
        transitions are the documented state machine, and an observed
        ``half_open`` always implies ``now - opened_at >= reset_timeout_s``
        (up to float epsilon) since the last trip."""
        return {"state": self.state(now), "failures": self._failures,
                "probing": self._probing, "opened_at": self._opened_at,
                "trips": self.trips, "probes": self.probes}

    def allow(self, now: float) -> bool:
        """May new work be routed to the guarded resource right now?"""
        s = self.state(now)
        if s == CLOSED:
            return True
        if s == HALF_OPEN:
            return not self._probing
        return False

    def begin_probe(self, now: float) -> None:
        """Caller committed work during half-open: occupy the single probe
        slot until the work succeeds (-> closed) or fails (-> open).
        No-op outside half-open."""
        if self.state(now) == HALF_OPEN and not self._probing:
            self._probing = True
            self.probes += 1

    def record_success(self, now: float) -> None:
        """Work on the guarded resource finished cleanly."""
        self._state = CLOSED
        self._failures = 0
        self._probing = False

    def record_failure(self, now: float) -> None:
        """Work on the guarded resource failed (crash, timeout, shed)."""
        self._failures += 1
        s = self.state(now)
        if s == HALF_OPEN or (s == CLOSED
                              and self._failures >= self.threshold):
            self._state = OPEN
            self._opened_at = now
            self._probing = False
            self.trips += 1
        elif s == OPEN:
            # repeated failures while open (e.g. residents reaped after the
            # trip) hold the window open from the latest failure
            self._opened_at = now

    def __repr__(self) -> str:
        return (f"CircuitBreaker(state={self._state!r}, "
                f"failures={self._failures}, trips={self.trips})")


def breaker_states(breakers: Dict, now: float) -> Dict[str, str]:
    """Snapshot ``{name: state}`` for a dict of breakers (diagnostics)."""
    return {str(k): b.state(now) for k, b in breakers.items()}


__all__ = ["CircuitBreaker", "breaker_states", "CLOSED", "OPEN", "HALF_OPEN"]
