"""Host-side page allocator + prefix index for the paged KV-cache.

The device holds one page arena per layer (``[num_pages + 1, page_size,
...]``); this module owns the *ids*. Physical page 0 is reserved as the
trash page: page-table entries beyond a slot's allocation point at it, so
fixed-shape scatters can always write a full table row and fixed-shape
gathers can always read one — writes land in trash, reads are masked by the
per-row valid length.

Two classes cooperate:

* :class:`PageAllocator` — refcounted free-list over physical page ids.
  ``alloc`` hands out pages at refcount 1; ``ref`` lets several slots map
  the SAME physical page (prefix sharing); ``free`` decrements and only a
  decrement-to-zero releases the page. A page that the prefix index still
  wants (``retain``) parks in an LRU side pool instead of the free list: its
  KV bytes stay valid on device and a later request can revive it for free,
  but the allocator reclaims LRU pages (oldest first, notifying
  ``evict_cb``) the moment real demand needs them — cached pages are
  capacity, not leaks.

* :class:`PrefixCache` — vLLM/SGLang-style block-hash index. The prompt is
  cut into page-sized token blocks and each block keyed by a *chain* hash
  (parent hash + this block's tokens, verified token-exact on lookup, so a
  Python hash collision can only cause a miss, never false sharing).
  ``match`` walks the chain for the longest page-aligned shared prefix and
  then tries the *partial tail* entries under the last matched hash — a
  cached page whose first ``k`` tokens agree can be copy-on-write'd by the
  engine (device page copy) so even a non-page-aligned retrieval context is
  shared up to the last token.

Everything here is plain numpy/python — consulted at admission/retirement
only (host side, off the jit path), never per decode step.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

TRASH_PAGE = 0


class PagingError(RuntimeError):
    """Page bookkeeping violation (double free, trash-page free, foreign id,
    pool exhaustion). A real exception — unlike an ``assert`` — survives
    ``python -O``, where a silently corrupted free list would hand the same
    physical page to two slots and let their device scatters race."""


def pages_needed(tokens: int, page_size: int) -> int:
    """Pages to reserve for a request that will occupy ``tokens`` cache
    positions (prompt + decode budget)."""
    return max(1, -(-tokens // page_size))


class PageAllocator:
    """Refcounted free-list over physical page ids ``1..num_pages`` (0 is
    trash). Page states: FREE (on the free list), ACTIVE (refcount >= 1,
    mapped by one or more slots), CACHED (refcount 0 but retained in the LRU
    pool for prefix reuse; reclaimed on demand)."""

    def __init__(self, num_pages: int):
        if num_pages <= 0:
            raise PagingError(f"need at least one page, got {num_pages}")
        self.num_pages = num_pages
        # LIFO: recently freed pages are reused first (warm in cache)
        self._free: List[int] = list(range(num_pages, 0, -1))
        self._free_set = set(self._free)    # O(1) membership/double-free check
        self._refs = np.zeros(num_pages + 1, np.int32)
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        self.evict_cb: Optional[Callable[[int], None]] = None
        self.generation = 0       # bumped on every state change (plan memos)

    # ---- introspection ------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def cached_pages(self) -> int:
        """Refcount-0 pages retained for prefix reuse (reclaimable)."""
        return len(self._lru)

    @property
    def available_pages(self) -> int:
        """Pages an ``alloc`` could obtain right now (free + evictable)."""
        return len(self._free) + len(self._lru)

    @property
    def used_pages(self) -> int:
        return self.num_pages - self.available_pages

    def refcount(self, pid: int) -> int:
        return int(self._refs[int(pid)])

    def audit(self, mapped: Optional[Dict[int, int]] = None
              ) -> Dict[str, int]:
        """Full page-accounting audit — the DST page oracle, also run by the
        bench ``--check`` quiescence sweeps.

        Verifies that every physical page is in exactly one of the three
        states (FREE on the free list, CACHED in the LRU pool, ACTIVE with
        refcount >= 1) and that the three populations sum to ``num_pages``
        (zero leaks, zero aliasing). When ``mapped`` is given — ``{page id:
        number of slot mappings}`` gathered from the engine's resident page
        tables — additionally verifies that each page's refcount equals its
        mapping count (a skipped decrement or double ref shows up here).
        Raises :class:`PagingError` on any breach; returns the population
        counts ``{"num_pages", "free", "cached", "active"}`` otherwise."""
        free_list = [int(p) for p in self._free]
        free = set(free_list)
        if len(free) != len(free_list):
            dup = sorted(p for p in free if free_list.count(p) > 1)
            raise PagingError(f"free list contains duplicates: {dup}")
        if free != self._free_set:
            raise PagingError(
                f"free list/set disagree: list {sorted(free)} vs "
                f"set {sorted(self._free_set)}")
        cached = {int(p) for p in self._lru}
        for name, grp in (("free list", free), ("LRU pool", cached)):
            if TRASH_PAGE in grp:
                raise PagingError(f"trash page 0 found in the {name}")
            bad = sorted(p for p in grp if not 1 <= p <= self.num_pages)
            if bad:
                raise PagingError(f"foreign page ids in the {name}: {bad}")
        both = free & cached
        if both:
            raise PagingError(
                f"pages simultaneously free and cached: {sorted(both)}")
        neg = [p for p in range(1, self.num_pages + 1) if self._refs[p] < 0]
        if neg:
            raise PagingError(f"negative refcounts on pages {neg}")
        active = {p for p in range(1, self.num_pages + 1)
                  if self._refs[p] > 0}
        ghost = (free | cached) & active
        if ghost:
            raise PagingError(
                f"pages on the free list/LRU pool with refcount > 0: "
                f"{sorted(ghost)}")
        if len(free) + len(cached) + len(active) != self.num_pages:
            lost = sorted(set(range(1, self.num_pages + 1))
                          - free - cached - active)
            raise PagingError(
                f"page leak: free {len(free)} + cached {len(cached)} + "
                f"active {len(active)} != num_pages {self.num_pages}; "
                f"unaccounted pages {lost}")
        if mapped is not None:
            bad = sorted(p for p in mapped
                         if not 1 <= int(p) <= self.num_pages)
            if bad:
                raise PagingError(f"slots map foreign page ids: {bad}")
            for p in range(1, self.num_pages + 1):
                want = int(mapped.get(p, 0))
                have = int(self._refs[p])
                if want != have:
                    raise PagingError(
                        f"refcount mismatch on page {p}: refcount {have} "
                        f"but {want} resident slot mapping(s)")
        return {"num_pages": self.num_pages, "free": len(free),
                "cached": len(cached), "active": len(active)}

    def bump_generation(self) -> None:
        """Force plan-memo invalidation without a page state change (e.g.
        the prefix index was cleared, so cached admission matches are
        stale even though no page moved)."""
        self.generation += 1

    def is_cached(self, pid: int) -> bool:
        return int(pid) in self._lru

    def can_reserve(self, n_fresh: int, reuse_ids: Sequence[int] = ()) -> bool:
        """Could a request mapping ``reuse_ids`` (shared/CoW-source pages)
        still allocate ``n_fresh`` pages? Reviving a CACHED reused page
        removes it from the evictable pool, so it is not double-counted."""
        revive = sum(1 for p in reuse_ids if int(p) in self._lru)
        return n_fresh <= len(self._free) + len(self._lru) - revive

    # ---- validation helpers -------------------------------------------
    def _check_id(self, pid: int) -> int:
        pid = int(pid)
        if pid == TRASH_PAGE:
            raise PagingError("page 0 is the trash page and is never owned")
        if not 1 <= pid <= self.num_pages:
            raise PagingError(
                f"page id {pid} outside pool 1..{self.num_pages}")
        return pid

    # ---- lifecycle ----------------------------------------------------
    def alloc(self, n: int) -> np.ndarray:
        """Pop ``n`` distinct physical page ids at refcount 1, evicting LRU
        cached pages (oldest first, via ``evict_cb``) if the free list runs
        short. Raises :class:`PagingError` if even eviction cannot cover the
        request — callers gate on :meth:`can_reserve` first."""
        if n > self.available_pages:
            raise PagingError(
                f"page pool exhausted: need {n}, have {len(self._free)} free "
                f"+ {len(self._lru)} cached of {self.num_pages}")
        while len(self._free) < n:
            self._evict_one()
        ids = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(ids)
        self._refs[ids] = 1
        self.generation += 1
        return np.asarray(ids, np.int32)

    def _evict_one(self) -> None:
        pid, _ = self._lru.popitem(last=False)        # oldest first
        if self.evict_cb is not None:
            self.evict_cb(pid)
        self._free.append(pid)
        self._free_set.add(pid)

    def ref(self, ids: Sequence[int]) -> None:
        """Take one extra reference on each page (a slot mapping a shared
        prefix page). Reviving a CACHED page removes it from the LRU pool."""
        for pid in ids:
            pid = self._check_id(pid)
            if pid in self._free_set:
                raise PagingError(f"ref of free page {pid}")
            if self._refs[pid] == 0:
                if pid not in self._lru:
                    raise PagingError(
                        f"page {pid} has refcount 0 but is not cached")
                del self._lru[pid]
            self._refs[pid] += 1
        self.generation += 1

    def free(self, ids: Sequence[int],
             retain: Optional[Callable[[int], bool]] = None) -> None:
        """Drop one reference per page. On decrement-to-zero the page either
        returns to the free list or — when ``retain(pid)`` says the prefix
        index still values its contents — parks in the LRU pool, where its
        KV stays valid until the allocator actually needs the capacity."""
        for pid in ids:
            pid = self._check_id(pid)
            if pid in self._free_set:
                raise PagingError(f"double free of page {pid}")
            if self._refs[pid] <= 0:
                raise PagingError(
                    f"free of page {pid} with refcount {int(self._refs[pid])}")
            self._refs[pid] -= 1
            if self._refs[pid] == 0:
                if retain is not None and retain(pid):
                    self._lru[pid] = None
                    self._lru.move_to_end(pid)        # most-recently used
                else:
                    self._free.append(pid)
                    self._free_set.add(pid)
        self.generation += 1


class PrefixCache:
    """Block-hash index: chain hashes of page-sized token blocks -> the
    physical page holding that block's KV, plus partial-tail entries for the
    copy-on-write path. Pure host-side bookkeeping; the engine owns when to
    ref/copy pages."""

    _ROOT = 0xE0C0

    def __init__(self, page_size: int):
        self.page_size = page_size
        # chain hash -> (page id, block tokens) — tokens kept to verify the
        # match exactly (hash collisions degrade to misses, never aliasing)
        self._blocks: Dict[int, Tuple[int, Tuple[int, ...]]] = {}
        # parent chain hash -> {tail tokens -> page id} (partially filled
        # last prompt page, CoW source)
        self._tails: Dict[int, Dict[Tuple[int, ...], int]] = {}
        # page id -> index keys referencing it (for O(keys) eviction)
        self._page_keys: Dict[int, List[tuple]] = {}

    @staticmethod
    def _chain(parent: int, block: Tuple[int, ...]) -> int:
        return hash((parent, block))

    # ---- introspection ------------------------------------------------
    def __len__(self) -> int:
        return len(self._blocks) + sum(len(b) for b in self._tails.values())

    def owns(self, pid: int) -> bool:
        """Does the index reference this page (i.e. retain it on free)?"""
        return int(pid) in self._page_keys

    # ---- lookup -------------------------------------------------------
    def match(self, tokens: Sequence[int]
              ) -> Tuple[List[int], Optional[Tuple[int, int]]]:
        """Longest page-aligned shared prefix of ``tokens``.

        Returns ``(full_page_ids, tail)`` where ``full_page_ids`` are the
        physical pages of consecutively matched full blocks and ``tail`` is
        ``(page_id, n_tokens)`` for the best partial-tail continuation (a
        cached page whose first ``n_tokens`` agree with what follows the
        full match) — the engine copies that page (CoW) rather than mapping
        it, because the new request will keep writing into it. Callers cap
        ``tokens`` (e.g. at prompt length - 1) so a suffix always remains to
        prefill for first-token logits."""
        ps = self.page_size
        tokens = tuple(int(t) for t in tokens)
        h = self._ROOT
        pages: List[int] = []
        i = 0
        while i + ps <= len(tokens):
            block = tokens[i:i + ps]
            nxt = self._chain(h, block)
            hit = self._blocks.get(nxt)
            if hit is None or hit[1] != block:
                break
            pages.append(hit[0])
            h = nxt
            i += ps
        tail: Optional[Tuple[int, int]] = None
        rest = tokens[i:]
        if rest:
            best = 0
            for ttoks, pid in self._tails.get(h, {}).items():
                k = 0
                for a, b in zip(rest, ttoks):
                    if a != b:
                        break
                    k += 1
                if k > best:
                    best, tail = k, (pid, k)
        return pages, tail

    # ---- registration -------------------------------------------------
    def insert(self, tokens: Sequence[int], page_row: Sequence[int]) -> None:
        """Index a freshly prefilled prompt: every full block (and the
        partial tail, if any) of ``tokens`` maps to the page at the same
        logical index in ``page_row``. Already-indexed blocks keep their
        canonical page (first writer wins)."""
        ps = self.page_size
        tokens = tuple(int(t) for t in tokens)
        h = self._ROOT
        n_full = len(tokens) // ps
        for j in range(n_full):
            block = tokens[j * ps:(j + 1) * ps]
            h = self._chain(h, block)
            hit = self._blocks.get(h)
            if hit is None:
                pid = int(page_row[j])
                self._blocks[h] = (pid, block)
                self._page_keys.setdefault(pid, []).append(("b", h))
            elif hit[1] != block:
                # hash collision with a different block: registering our
                # descendants under this chain would let a later walker
                # token-verify them against the WRONG prefix — stop here so
                # a collision stays a miss, never false sharing
                return
        tail = tokens[n_full * ps:]
        if tail:
            bucket = self._tails.setdefault(h, {})
            if tail not in bucket:
                pid = int(page_row[n_full])
                bucket[tail] = pid
                self._page_keys.setdefault(pid, []).append(("t", h, tail))

    def forget(self, pid: int) -> None:
        """Drop every index entry referencing ``pid`` (allocator evicted the
        page). Orphaned descendants of a dropped chain link simply become
        unreachable and age out of the LRU pool on their own."""
        for key in self._page_keys.pop(int(pid), []):
            if key[0] == "b":
                self._blocks.pop(key[1], None)
            else:
                bucket = self._tails.get(key[1])
                if bucket is not None:
                    bucket.pop(key[2], None)
                    if not bucket:
                        del self._tails[key[1]]

    def clear(self) -> int:
        """Drop EVERY index entry (knowledge rotation made the cached
        retrieved-context prefixes stale). Page refcounts are untouched:
        resident slots keep their mappings, and refcount-0 pages parked in
        the allocator's LRU pool simply stop being revivable — ``owns``
        now answers False, so they return to the free list on their next
        release or are reclaimed on demand. Returns the number of entries
        dropped."""
        n = len(self)
        self._blocks.clear()
        self._tails.clear()
        self._page_keys.clear()
        return n


__all__ = ["PageAllocator", "PrefixCache", "PagingError", "pages_needed",
           "TRASH_PAGE"]
