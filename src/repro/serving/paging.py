"""Host-side page allocator for the paged KV-cache.

The device holds one page arena per layer (``[num_pages + 1, page_size,
...]``); this module owns the *ids*. Physical page 0 is reserved as the
trash page: page-table entries beyond a slot's allocation point at it, so
fixed-shape scatters can always write a full table row and fixed-shape
gathers can always read one — writes land in trash, reads are masked by the
per-row valid length.

Allocation is a LIFO free-list in plain numpy/python — the allocator is
consulted at admission/retirement only (host side, off the jit path), never
per decode step.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

TRASH_PAGE = 0


def pages_needed(tokens: int, page_size: int) -> int:
    """Pages to reserve for a request that will occupy ``tokens`` cache
    positions (prompt + decode budget)."""
    return max(1, -(-tokens // page_size))


class PageAllocator:
    """Free-list over physical page ids ``1..num_pages`` (0 is trash)."""

    def __init__(self, num_pages: int):
        assert num_pages > 0
        self.num_pages = num_pages
        # LIFO: recently freed pages are reused first (warm in cache)
        self._free: List[int] = list(range(num_pages, 0, -1))
        self._free_set = set(self._free)    # O(1) double-free check

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def alloc(self, n: int) -> np.ndarray:
        """Pop ``n`` distinct physical page ids; raises if unavailable —
        callers gate on :attr:`free_pages` first (see ``can_admit``)."""
        if n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: need {n}, have {len(self._free)} "
                f"of {self.num_pages}")
        ids = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(ids)
        return np.asarray(ids, np.int32)

    def free(self, ids: Sequence[int]) -> None:
        for pid in ids:
            pid = int(pid)
            assert pid != TRASH_PAGE, "freeing the trash page"
            assert 1 <= pid <= self.num_pages, pid
            assert pid not in self._free_set, f"double free of page {pid}"
            self._free.append(pid)
            self._free_set.add(pid)


__all__ = ["PageAllocator", "pages_needed", "TRASH_PAGE"]
