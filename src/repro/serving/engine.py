"""Continuous-batching serving engine with a block-granular paged KV-cache.

This is the engine that runs at edge nodes (reduced SLM) and — in pod
deployment — behind the cloud tier. Requests stream through a fixed pool of
``max_batch`` slots; the KV-cache behind those slots comes in two layouts:

* ``paged`` (default where the model supports it) — one global page arena
  per layer, ``[num_pages + 1, page_size, KV, hd]``, plus a host-side
  per-slot page table ``[max_batch, max_seq // page_size]`` of physical page
  ids. A slot reserves only ``ceil((prompt + decode_budget) / page_size)``
  pages at admission, so short requests no longer strand a worst-case
  ``max_seq`` lane and the number of *resident* requests is bounded by
  actual token demand, not by ``max_batch x max_seq`` worst-case memory.
  Physical page 0 is the trash page: table entries past a slot's allocation
  point at it, keeping every scatter/gather fixed-shape. Invariants:

  - the :class:`~repro.serving.paging.PageAllocator` (host numpy free-list)
    hands each active slot *distinct* pages — device scatters never race;
  - pages are reserved for prompt + full decode budget at admission, so a
    resident request can always run to completion (no mid-decode eviction);
  - page tables ride into the jitted decode as a fixed-shape ``[max_batch,
    pages_per_slot]`` int32 argument — remapping slots never re-traces;
  - completed slots return their pages to the free list before the next
    admission round.

* ``contiguous`` — the PR-1 layout, one persistent ``[max_batch, max_seq,
  ...]`` lane per slot. Kept as the numerical/throughput baseline (see
  ``benchmarks/serving_bench.py``) and as the fallback for models whose
  decoder state cannot be paged (sliding-window rings, int8 caches, SSM /
  RWKV state, cross-attention memories).

Admission via :meth:`admit` requires :meth:`can_admit` — a free slot AND, in
paged mode, enough free pages for the request's prompt + budget. Prefill is
per-slot (batch-1, chunk-padded) and its cache is scattered into freshly
allocated pages (or the slot's lane) by a single fixed-shape insert;
``step()`` runs ONE fused decode for all slots at ``[max_batch, 1]``.

All jitted functions run at fixed shapes — decode, sampling and insert
compile exactly once per engine config; prefill compiles once per
``q_chunk`` bucket. ``trace_counts`` exposes per-function trace counters so
tests and benchmarks can assert compile stability. Decode budgets stay
per-slot: each request may emit up to ``min(max_new_tokens, max_seq -
prompt_len)`` tokens.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import ByteTokenizer
from repro.models.api import Model, build_model
from repro.models.pdefs import is_pdef
from repro.serving.paging import TRASH_PAGE, PageAllocator, pages_needed


@dataclass
class GenStats:
    prompt_tokens: int
    new_tokens: int
    prefill_s: float
    decode_s: float

    @property
    def tokens_per_s(self) -> float:
        return self.new_tokens / self.decode_s if self.decode_s > 0 else 0.0


@dataclass
class Request:
    prompt: str
    max_new_tokens: int = 32
    temperature: float = 0.0     # 0 = greedy


@dataclass
class EngineCompletion:
    """Per-request result carried out of the slot pool."""
    req_id: int
    request: Request
    text: str
    token_ids: List[int]
    prompt_tokens: int
    new_tokens: int
    time_in_engine_s: float      # admit -> finish (prefill + resident decode)


@dataclass
class _Slot:
    req_id: int
    request: Request
    budget: int                  # per-slot decode budget
    prompt_tokens: int
    pending: int                 # sampled, not yet emitted/fed token
    admitted_at: float
    page_ids: Optional[np.ndarray] = None   # physical pages owned (paged)
    out_ids: List[int] = field(default_factory=list)


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees, is_leaf=is_pdef)


class ServingEngine:
    """One model instance serving a continuously-batched slot pool."""

    def __init__(self, cfg: ModelConfig, *, max_seq: int = 512,
                 max_batch: int = 8, seed: int = 0, params=None,
                 kv_layout: str = "auto", page_size: int = 16,
                 num_pages: Optional[int] = None):
        self.cfg = cfg
        self.max_seq = max_seq
        self.max_batch = max_batch
        self.tok = ByteTokenizer()
        assert cfg.vocab >= self.tok.vocab_size, "vocab must cover bytes"
        self.model = build_model(cfg, max_seq=max_seq)
        self.params = params if params is not None else self.model.init(
            jax.random.PRNGKey(seed))
        self._key = jax.random.PRNGKey(seed + 1)

        assert kv_layout in ("auto", "paged", "contiguous"), kv_layout
        if kv_layout == "auto":
            kv_layout = ("paged" if self.model.supports_paged_cache
                         else "contiguous")
        if kv_layout == "paged" and not self.model.supports_paged_cache:
            raise ValueError(
                f"{cfg.arch_id}: decoder cache cannot be paged "
                "(window/int8/SSM/cross state); use kv_layout='contiguous'")
        self.kv_layout = kv_layout

        lane_defs = self.model.cache_defs(1)     # batch-1 prefill lane
        if kv_layout == "paged":
            assert page_size % 8 == 0, "page_size must keep the 8-row layout"
            assert max_seq % page_size == 0, (max_seq, page_size)
            self.page_size = page_size
            self.pages_per_slot = max_seq // page_size
            self.num_pages = (max_batch * self.pages_per_slot
                              if num_pages is None else num_pages)
            assert self.num_pages >= self.pages_per_slot, \
                "pool must fit at least one worst-case request"
            # ---- page arena (+1: trash page 0) + host page state ----------
            arena_defs = self.model.paged_cache_defs(self.num_pages + 1,
                                                     page_size)
            self._cache = _tmap(lambda d: jnp.zeros(d.shape, d.dtype),
                                arena_defs)
            self._page_ax = _tmap(lambda d: d.axes.index("pages"), arena_defs)
            self._pseq_ax = _tmap(lambda d: d.axes.index("page_seq"),
                                  arena_defs)
            self._allocator = PageAllocator(self.num_pages)
            self._page_tables = np.full(
                (max_batch, self.pages_per_slot), TRASH_PAGE, np.int32)
        else:
            self.page_size = None
            self.pages_per_slot = None
            self.num_pages = None
            self._allocator = None
            self._page_tables = None
            # ---- persistent KV-cache pool: one lane per slot --------------
            pool_defs = self.model.cache_defs(max_batch)
            self._batch_ax = _tmap(lambda d: d.axes.index("batch"), pool_defs)
            self._cache = _tmap(lambda d: jnp.zeros(d.shape, d.dtype),
                                pool_defs)
        self._lane_b_ax = _tmap(lambda d: d.axes.index("batch"), lane_defs)
        self._lane_s_ax = _tmap(
            lambda d: d.axes.index("cache_seq") if "cache_seq" in d.axes
            else -1, lane_defs)

        # ---- host-side slot state -----------------------------------------
        self._slots: List[Optional[_Slot]] = [None] * max_batch
        self._tokens = np.full(max_batch, self.tok.pad_id, np.int32)
        self._positions = np.zeros(max_batch, np.int32)
        self._temps = np.zeros(max_batch, np.float32)
        self._next_req_id = 0
        self._plan_cache = None   # one-entry (request, plan) memo
        self.peak_active = 0      # high-water mark of resident requests
        self.prefill_s = 0.0      # cumulative engine-lifetime timers
        self.decode_s = 0.0

        # ---- fixed-shape jitted functions with trace instrumentation ------
        # the counters increment only when JAX (re)traces a function, so a
        # stable engine shows exactly one decode/sample/insert trace no
        # matter how many streams of differing batch mix it serves.
        self.trace_counts: Dict[str, int] = {
            "prefill": 0, "decode": 0, "sample": 0, "insert": 0}

        def _prefill_fn(params, tokens, lengths):
            self.trace_counts["prefill"] += 1
            return self.model.prefill(params, tokens, None, lengths)

        def _decode_fn(params, cache, tokens1, positions):
            self.trace_counts["decode"] += 1
            return self.model.decode_step(params, cache, tokens1, positions)

        def _decode_paged_fn(params, cache, tokens1, positions, page_tables):
            self.trace_counts["decode"] += 1
            return self.model.decode_step_paged(
                params, cache, tokens1, positions, page_tables,
                page_size=self.page_size)

        def _sample_fn(logits, temps, key):
            self.trace_counts["sample"] += 1
            greedy = jnp.argmax(logits, -1).astype(jnp.int32)
            t = jnp.maximum(temps, 1e-4)[:, None]
            sampled = jax.random.categorical(key, logits / t, axis=-1)
            return jnp.where(temps > 0, sampled.astype(jnp.int32), greedy)

        def _insert_fn(pool, one, slot):
            self.trace_counts["insert"] += 1

            def put(big, small, ax):
                big_m = jnp.moveaxis(big, ax, 0)
                row = jnp.moveaxis(small, ax, 0)[0].astype(big_m.dtype)
                big_m = jax.lax.dynamic_update_index_in_dim(
                    big_m, row, slot, 0)
                return jnp.moveaxis(big_m, 0, ax)

            return jax.tree_util.tree_map(put, pool, one, self._batch_ax)

        def _insert_paged_fn(arena, lane, page_row):
            """Chop the batch-1 prefill lane into page_size chunks and
            scatter them at the slot's physical page ids. ``page_row`` is
            always the full ``[pages_per_slot]`` row (fixed shape); entries
            past the allocation are TRASH_PAGE, so the surplus lane chunks
            land in trash."""
            self.trace_counts["insert"] += 1
            ps = self.page_size

            def put(big, small, p_ax, s_ax, b_ax, q_ax):
                sm = jnp.moveaxis(small, b_ax, 0)[0]          # drop batch
                sq = q_ax - 1 if b_ax < q_ax else q_ax
                sm = jnp.moveaxis(sm, sq, 0)                  # [S, rest...]
                sm = sm.reshape((sm.shape[0] // ps, ps) + sm.shape[1:])
                bg = jnp.moveaxis(big, (p_ax, s_ax), (0, 1))
                bg = bg.at[page_row].set(sm.astype(bg.dtype))
                return jnp.moveaxis(bg, (0, 1), (p_ax, s_ax))

            return jax.tree_util.tree_map(
                put, arena, lane, self._page_ax, self._pseq_ax,
                self._lane_b_ax, self._lane_s_ax)

        # donate the cache pool/arena through decode/insert so XLA updates
        # it in place instead of copying the whole pool per token (CPU
        # doesn't implement donation and would warn)
        donate = jax.default_backend() != "cpu"
        self._prefill = jax.jit(_prefill_fn)
        self._sample = jax.jit(_sample_fn)
        if kv_layout == "paged":
            self._decode = jax.jit(_decode_paged_fn,
                                   donate_argnums=(1,) if donate else ())
            self._insert = jax.jit(_insert_paged_fn,
                                   donate_argnums=(0,) if donate else ())
        else:
            self._decode = jax.jit(_decode_fn,
                                   donate_argnums=(1,) if donate else ())
            self._insert = jax.jit(_insert_fn,
                                   donate_argnums=(0,) if donate else ())

    # ------------------------------------------------------------------
    # Slot-pool / page-pool introspection
    # ------------------------------------------------------------------
    @property
    def free_slots(self) -> int:
        return sum(s is None for s in self._slots)

    @property
    def active_slots(self) -> int:
        return self.max_batch - self.free_slots

    @property
    def has_active(self) -> bool:
        return any(s is not None for s in self._slots)

    @property
    def decode_traces(self) -> int:
        return self.trace_counts["decode"]

    @property
    def free_pages(self) -> Optional[int]:
        return self._allocator.free_pages if self._allocator else None

    @property
    def kv_cache_tokens(self) -> int:
        """Token capacity of the KV memory (paged: usable pages; contiguous:
        the full slot pool)."""
        if self.kv_layout == "paged":
            return self.num_pages * self.page_size
        return self.max_batch * self.max_seq

    @property
    def kv_cache_bytes(self) -> int:
        return int(sum(x.nbytes for x in jax.tree_util.tree_leaves(
            self._cache)))

    # ------------------------------------------------------------------
    # Continuous-batching API: can_admit / admit / step
    # ------------------------------------------------------------------
    def _plan(self, request: Request) -> Tuple[List[int], int, int]:
        """(encoded prompt, decode budget, pages needed). Memoized for the
        last request seen: a queue head blocked on pages is re-planned by
        ``can_admit`` every decode step, and ``admit`` re-plans right after
        the ``can_admit`` that green-lit it."""
        cached = self._plan_cache
        if cached is not None and cached[0] is request:
            return cached[1]
        enc = self.tok.encode(request.prompt)[: self.max_seq - 1]
        L = len(enc)
        budget = max(0, min(request.max_new_tokens, self.max_seq - L))
        need = (pages_needed(L + budget, self.page_size)
                if self.kv_layout == "paged" else 0)
        self._plan_cache = (request, (enc, budget, need))
        return enc, budget, need

    def can_admit(self, request: Request) -> bool:
        """A free slot AND (paged) enough free pages for prompt + budget.
        Because pages are reserved through a request's whole budget, an
        engine draining its residents always becomes admissible again."""
        if self.free_slots == 0:
            return False
        if self.kv_layout != "paged":
            return True
        _, _, need = self._plan(request)
        return need <= self._allocator.free_pages

    def admit(self, request: Request) -> int:
        """Prefill one request into a free slot (paged: into freshly
        allocated pages). Returns the engine-local request id used in
        :class:`EngineCompletion`. Callers gate on :meth:`can_admit`."""
        slot = next((i for i, s in enumerate(self._slots) if s is None), None)
        if slot is None:
            raise RuntimeError("no free slot; check can_admit before admit")
        enc, budget, need = self._plan(request)
        L = len(enc)
        page_ids = None
        if self.kv_layout == "paged":
            page_ids = self._allocator.alloc(need)     # raises if exhausted
            row = np.full(self.pages_per_slot, TRASH_PAGE, np.int32)
            row[:need] = page_ids
        qc = max(self.cfg.q_chunk, 1)
        pad_len = min(-(-L // qc) * qc, self.max_seq)
        tokens, lengths = self.tok.pad_batch([enc], pad_len)

        t0 = time.perf_counter()
        logits, lane = self._prefill(self.params, jnp.asarray(tokens),
                                     jnp.asarray(lengths))
        if self.kv_layout == "paged":
            self._cache = self._insert(self._cache, lane, jnp.asarray(row))
            self._page_tables[slot] = row
        else:
            self._cache = self._insert(self._cache, lane, np.int32(slot))
        self._key, sub = jax.random.split(self._key)
        first = self._sample(logits,
                             jnp.asarray([request.temperature], jnp.float32),
                             sub)
        pending = int(jax.block_until_ready(first)[0])
        self.prefill_s += time.perf_counter() - t0

        rid = self._next_req_id
        self._next_req_id += 1
        self._slots[slot] = _Slot(rid, request, budget, L, pending,
                                  admitted_at=time.perf_counter(),
                                  page_ids=page_ids)
        self._tokens[slot] = pending
        self._positions[slot] = L
        self._temps[slot] = request.temperature
        self.peak_active = max(self.peak_active, self.active_slots)
        return rid

    def step(self) -> List[EngineCompletion]:
        """One pump of the pool: harvest pending tokens (retiring finished
        sequences, freeing their slot and pages), then run ONE fixed-shape
        decode for whatever remains active."""
        done: List[EngineCompletion] = []
        now = time.perf_counter()
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            finished = (s.pending == self.tok.eos_id
                        or len(s.out_ids) >= s.budget)
            if not finished:
                s.out_ids.append(s.pending)
                finished = len(s.out_ids) >= s.budget
            if finished:
                done.append(EngineCompletion(
                    s.req_id, s.request, self.tok.decode(s.out_ids),
                    s.out_ids, s.prompt_tokens, len(s.out_ids),
                    time_in_engine_s=max(now - s.admitted_at, 0.0)))
                self._free(i)

        if self.has_active:
            t0 = time.perf_counter()
            args = (self.params, self._cache,
                    jnp.asarray(self._tokens)[:, None],
                    jnp.asarray(self._positions))
            if self.kv_layout == "paged":
                args += (jnp.asarray(self._page_tables),)
            logits, self._cache = self._decode(*args)
            self._key, sub = jax.random.split(self._key)
            nxt = np.asarray(jax.block_until_ready(
                self._sample(logits, jnp.asarray(self._temps), sub)))
            self.decode_s += time.perf_counter() - t0
            for i, s in enumerate(self._slots):
                if s is None:
                    continue
                s.pending = int(nxt[i])
                self._tokens[i] = s.pending
                self._positions[i] += 1
        return done

    def _free(self, slot: int) -> None:
        s = self._slots[slot]
        if s is not None and s.page_ids is not None:
            self._allocator.free(s.page_ids)
            self._page_tables[slot] = TRASH_PAGE
        self._slots[slot] = None
        self._tokens[slot] = self.tok.pad_id
        self._positions[slot] = 0     # inactive lanes park at position 0
        self._temps[slot] = 0.0

    # ------------------------------------------------------------------
    # Batch conveniences on top of the pool
    # ------------------------------------------------------------------
    def generate(self, requests: Sequence[Request]
                 ) -> Tuple[List[str], GenStats]:
        """Continuously-batched generation: requests are admitted as slots
        (and pages) free up, so any number of requests stream through
        ``max_batch`` lanes. Output order matches input order."""
        return self._pump_all(requests, continuous=True)

    def generate_static(self, requests: Sequence[Request]
                        ) -> Tuple[List[str], GenStats]:
        """Static-batch baseline: admit one batch (<= max_batch), then block
        until EVERY sequence finishes — no mid-decode admission. Kept for
        benchmarking and equivalence testing against the continuous path.
        With a deliberately small page pool the batch may not fit at once;
        size ``num_pages`` for the worst case when using this path."""
        assert 0 < len(requests) <= self.max_batch
        return self._pump_all(requests, continuous=False)

    def _pump_all(self, requests: Sequence[Request], *, continuous: bool
                  ) -> Tuple[List[str], GenStats]:
        assert not self.has_active, "engine already has resident requests"
        p0, d0 = self.prefill_s, self.decode_s
        queue = list(requests)
        rid_to_idx: Dict[int, int] = {}
        comps: Dict[int, EngineCompletion] = {}
        if not continuous:                      # one up-front batch, no more
            for i, r in enumerate(queue):
                rid_to_idx[self.admit(r)] = i
            queue = []
        while queue or self.has_active:
            while continuous and queue and self.can_admit(queue[0]):
                req = queue.pop(0)
                rid_to_idx[self.admit(req)] = len(requests) - len(queue) - 1
            for ec in self.step():
                comps[rid_to_idx[ec.req_id]] = ec
        ordered = [comps[i] for i in range(len(requests))]
        stats = GenStats(
            prompt_tokens=sum(c.prompt_tokens for c in ordered),
            new_tokens=sum(c.new_tokens for c in ordered),
            prefill_s=self.prefill_s - p0, decode_s=self.decode_s - d0)
        return [c.text for c in ordered], stats

    # ------------------------------------------------------------------
    def warmup(self, prompt_lens: Iterable[int] = (1,)) -> None:
        """Pre-compile every fixed-shape function (decode, sample, insert)
        and the prefill bucket for each given prompt length, leaving the
        pool idle. Lets benchmarks separate compile from serve time."""
        assert not self.has_active
        qc = max(self.cfg.q_chunk, 1)
        buckets = sorted({min(-(-max(n, 1) // qc) * qc, self.max_seq)
                          for n in prompt_lens})
        key = jax.random.PRNGKey(0)
        paged = self.kv_layout == "paged"
        # rebind the pool at every call: the cache argument is donated, so
        # the old buffer is dead after each decode/insert (pool is idle —
        # a paged warmup scribbles only on the trash page, a contiguous one
        # on lane 0, which is rewritten on admission)
        for pad_len in buckets:
            toks = jnp.zeros((1, pad_len), jnp.int32)
            logits, lane = self._prefill(self.params, toks,
                                         jnp.asarray([pad_len], jnp.int32))
            if paged:
                trash_row = jnp.full((self.pages_per_slot,), TRASH_PAGE,
                                     jnp.int32)
                self._cache = self._insert(self._cache, lane, trash_row)
            else:
                self._cache = self._insert(self._cache, lane, np.int32(0))
            self._sample(logits, jnp.asarray([0.0], jnp.float32), key)
        args = (self.params, self._cache,
                jnp.asarray(self._tokens)[:, None],
                jnp.asarray(self._positions))
        if paged:
            args += (jnp.asarray(self._page_tables),)
        _, self._cache = self._decode(*args)
        self._sample(jnp.zeros((self.max_batch, self.cfg.vocab), jnp.float32),
                     jnp.asarray(self._temps), key)


def make_edge_engine(*, max_seq: int = 512, max_batch: int = 8,
                     seed: int = 0, **kw) -> ServingEngine:
    """Default edge SLM: reduced qwen2-0.5b (byte vocab capable). Extra
    keyword args (kv_layout, page_size, num_pages, ...) pass through."""
    from repro.configs import get_config
    cfg = get_config("qwen2-0.5b", reduced=True)
    return ServingEngine(cfg, max_seq=max_seq, max_batch=max_batch, seed=seed,
                         **kw)


__all__ = ["ServingEngine", "Request", "GenStats", "EngineCompletion",
           "make_edge_engine"]
