"""Continuous-batching serving engine with a prefix-cached paged KV-cache.

This is the engine that runs at edge nodes (reduced SLM) and — in pod
deployment — behind the cloud tier. Requests stream through a fixed pool of
``max_batch`` slots; the KV-cache behind those slots comes in two layouts:

* ``paged`` (default where the model supports it) — one global page arena
  per layer, ``[num_pages + 1, page_size, KV, hd]``, plus a host-side
  per-slot page table ``[max_batch, max_seq // page_size]`` of physical page
  ids. A slot reserves only ``ceil((prompt + decode_budget) / page_size)``
  pages at admission, so short requests no longer strand a worst-case
  ``max_seq`` lane and the number of *resident* requests is bounded by
  actual token demand, not by ``max_batch x max_seq`` worst-case memory.
  Physical page 0 is the trash page: table entries past a slot's allocation
  point at it, keeping every scatter/gather fixed-shape.

  On top of the arena sits a **prefix cache** (on by default,
  ``prefix_cache=False`` to disable) — the EACO-RAG edge tier answers many
  queries grounded in the same retrieved context, so requests sharing a
  prompt prefix should share its KV instead of recomputing it:

  - *hash chains*: prompts are cut into page-sized token blocks and indexed
    by chain hash (parent hash + block tokens, token-verified on lookup) in
    :class:`~repro.serving.paging.PrefixCache`. ``admit`` walks the chain
    for the longest page-aligned shared prefix and maps those physical
    pages into the new slot's page table read-only.
  - *CoW tail*: the partially-filled last prompt page of a cached prompt is
    indexed too; when its leading tokens agree with the new request, the
    page is copied on-device (copy-on-write — the new slot will keep
    writing into that logical page) so even a non-page-aligned retrieval
    context is shared up to its last token. The match is always capped at
    ``prompt_len - 1`` so at least one suffix token remains to produce
    first-token logits.
  - *refcount lifecycle*: shared pages carry one reference per mapping slot
    (:meth:`PageAllocator.ref`); retirement decrements and only
    decrement-to-zero releases a page. Pages the index still values park in
    an LRU pool — KV bytes stay valid for future hits — and are reclaimed
    (oldest first) only when the allocator actually needs the capacity, so
    cached prefixes cost nothing under low pressure and nothing *extra*
    under high pressure.
  - *suffix-only prefill*: after the match, only the unique suffix runs
    through the model (``Model.prefill_paged`` -> per-layer ``fwd_append``
    -> the chunked paged append-attention kernel), scattering its KV
    straight into freshly allocated pages — there is no intermediate
    contiguous lane and no lane->arena copy anywhere in the paged path.

  Remaining invariants from the plain paged design: the allocator hands
  each slot's *private* pages to exactly one slot (shared pages are only
  ever read after their writer finishes with them — block pages are
  write-once at prefill, CoW sources are copied, and decode always writes
  at positions >= prompt_len, which land in private pages); pages are
  reserved for prompt + full decode budget at admission, so a resident
  request always runs to completion; page tables ride into the jitted
  decode as fixed-shape ``[max_batch, pages_per_slot]`` int32 arguments —
  remapping or sharing slots never re-traces.

* ``contiguous`` — the PR-1 layout, one persistent ``[max_batch, max_seq,
  ...]`` lane per slot. Kept as the numerical/throughput baseline (see
  ``benchmarks/serving_bench.py``) and as the fallback for models whose
  decoder state cannot be paged (sliding-window rings, int8 caches, SSM /
  RWKV state, cross-attention memories).

Admission via :meth:`admit` requires :meth:`can_admit` — a free slot AND, in
paged mode, enough allocatable pages (free + LRU-evictable) for the
request's *unshared* pages. ``step()`` runs ONE fused decode for all slots
at ``[max_batch, 1]``.

**Fused chunked-prefill + decode (the token-budget state machine).**
Passing ``step_token_budget`` (paged layout only) replaces stop-the-world
admission with a Sarathi-style fused step. The machinery:

- *Async admission*: :meth:`admit` becomes host-only — it plans, maps
  shared prefix pages, CoW-copies a matched tail and reserves fresh pages,
  but runs NO model compute. The slot parks **mid-prefill**
  (``prefill_done < prompt_tokens``, ``pending is None``) with its decode
  row masked: page-table row all trash, token ``pad``, position 0 — so the
  fixed-shape decode can carry it inertly (a 1-token attention over the
  trash page is finite and its result is never read).
- *Budgeted steps*: each :meth:`dispatch` packs every resident decode row
  (one token each) plus ONE bounded prefill chunk of the highest-priority
  mid-prefill resident into ``step_token_budget`` tokens. The chunk runs
  through the same ``fwd_append`` path (and chunked append-attention
  kernel) as whole-suffix prefill, fused with the decode in a single jit
  (:meth:`Model.fused_step` -> ``run_segments_fused``) that compiles
  exactly once — zero decode retraces, and chunk tokens are always padded
  to one fixed ``_pad_bucket(prefill_chunk)`` bucket. When decodes alone
  meet the budget, a chunk still rides along only for an *interactive*
  head (a small starvation guard); with no decodes resident the chunk runs
  through the ordinary suffix-prefill jit at the same fixed bucket.
- *Deferred first token*: chunk logits are computed every chunk at a fixed
  shape but only the FINAL chunk's are first-token logits — that step
  samples the pending token, unmasks the decode row (real page table,
  position ``prompt_tokens``), stamps ``first_token_at`` (engine
  :attr:`EngineCompletion.ttft_s`) and — only now — inserts the prompt
  into the prefix index (indexing pages before their KV is written would
  let a later admission map garbage read-only).
- *Async dispatch hazards*: :meth:`step` is ``harvest -> dispatch ->
  collect``, but a scheduler may dispatch EVERY engine and collect at the
  end of its round, overlapping host-side planning with device compute
  (JAX async dispatch — nothing blocks until ``collect`` fetches the
  sampled tokens). Between dispatch and collect the slot table may change
  under the in-flight step (preempt, cancel, crash): ``collect`` applies a
  result only if the slot still holds the same ``req_id`` in the same
  phase, and a stale in-flight write to a since-freed page is harmless —
  a reader only gathers positions below its own length, and every such
  position in a re-allocated private page is rewritten by its new owner
  before that owner's length covers it (shared pages are only ever
  indexed after being fully written).
- *Preempt / crash of a half-prefilled resident*: nothing special —
  ``preempt`` snapshots zero emitted tokens and the full budget (prefill
  compute already spent on chunks is the only loss; greedy resume is
  token-identical), ``crash`` drops the slot with everything else.
- *Accounting*: a step's cost is additive — ``decode_rounds`` counts steps
  with >= 1 live decode row, ``prefill_tokens`` counts chunk tokens — so
  the virtual-clock delta formula ``modeled_prefill_s(Δtokens) + Δrounds *
  modeled_decode_round_s`` (and its per-step form
  :func:`~repro.core.cost_model.modeled_mixed_step_s`) stays exact under
  chunking. ``mixed_steps`` / ``prefill_chunks`` / ``budget_utilization``
  expose the mix.

**Feasibility is explicit, never silent.** A prompt longer than
``max_seq - 1`` tokens can never leave room for a single generated token;
admitting it truncated would silently drop the prompt *tail* — which in a
context-first RAG prompt is the question itself. Such requests are
*infeasible*: :meth:`fits` answers False, :meth:`can_admit` permanently
refuses (so schedulers reject at submit instead of wedging their
deadline-ordered queue behind an inadmissible head), and :meth:`admit` /
:meth:`generate` raise :class:`EngineError`.

**Preemption (the overload state machine, engine side).** A resident
request can be reclaimed mid-decode with :meth:`preempt`: the slot is
freed immediately and every page reference is dropped exactly as on normal
retirement — private suffix pages return to the allocator while shared
prefix pages the index values survive in the LRU pool. The caller receives
a :class:`PreemptedRequest` snapshot (encoded prompt + tokens emitted so
far + remaining budget). Resuming is just a new admission of ``prompt_ids
= enc + emitted`` (token ids, via :attr:`Request.prompt_ids`, because
generated ids need not round-trip through text): the prefix cache matches
the original prompt's blocks — still indexed from the first admission —
so only the generated suffix is recomputed, and greedy decode emits the
exact tokens the victim would have produced uninterrupted (the sampled-
but-unemitted ``pending`` token is deliberately NOT part of the snapshot;
greedy resume re-derives it from identical logits). The scheduler layers
shed/timeout/failover on top (:mod:`repro.serving.scheduler`,
:mod:`repro.cluster.simulator`).

**Crash and recovery (the hard-failure state machine, engine side).**
Unlike a stall (engine frozen, state intact) or a preemption (one resident
reclaimed, shared pages survive), :meth:`crash` models a process/device
loss: EVERY slot, the whole page arena, the allocator and the prefix index
are gone at once. ``crash()`` marks the engine ``dead`` (admit/step/preempt
raise :class:`EngineError`; ``can_admit`` answers False) and returns the
request ids of the residents that died with it — the scheduler reaps those
as typed ``engine_lost`` outcomes and re-serves them from their original
prompts (tokens still in engine memory are lost; tokens a scheduler banked
from an earlier preemption survive, because they live in the control
plane). :meth:`restart` rebuilds a COLD engine — zeroed arena, fresh
allocator and prefix index, empty slots — and bumps
:attr:`engine_generation` so stale references (a scheduler's resident keys,
memoized admission plans) are detectably invalid. The jitted functions are
kept: shapes and dtypes are unchanged, so a restarted engine re-serves
without re-tracing, and greedy output is token-identical to a never-crashed
engine.

**Clocks.** Engine-level request timestamps (admission time, completion
``time_in_engine_s``) read the injectable ``clock`` (any zero-arg callable
returning seconds; default ``time.perf_counter``) — a simulator injecting a
:class:`~repro.core.clock.VirtualClock` gets logical residency times that
compose with its queue waits instead of mixing wall and event time. The
compute timers are explicitly wall-clock and NAMED so:
``prefill_wall_s``/``decode_wall_s`` measure real jit compute for
``engine_time="wall"`` (``prefill_s``/``decode_s`` remain as read-only
aliases), while the *logical* counters — ``prefill_tokens``,
``decode_rounds``, ``prefill_chunks``, ``mixed_steps`` — are pure
functions of the request stream, so DST replays that compare engine
progress stay byte-identical regardless of host speed.

All jitted functions run at fixed shapes — decode, sampling, page-copy and
(contiguous) insert compile exactly once per engine config; prefill
compiles once per power-of-two pad bucket (heavy-tailed prompt mixes
therefore retrace at most ``log2(max_seq)`` times, and :meth:`warmup`
precompiles every bucket up front). ``trace_counts`` exposes per-function
trace counters so tests and benchmarks can assert compile stability.
Decode budgets stay per-slot: each request may emit up to
``min(max_new_tokens, max_seq - prompt_len)`` tokens.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import (
    Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple,
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import ByteTokenizer
from repro.models.api import Model, build_model
from repro.models.pdefs import is_pdef
from repro.serving.paging import (
    TRASH_PAGE, PageAllocator, PrefixCache, pages_needed,
)


class EngineError(RuntimeError):
    """Caller-facing serving-engine invariant violation (vocab coverage,
    page-size divisibility, batch bounds, busy pool). A real exception —
    unlike a bare ``assert`` — survives ``python -O``, where a silently
    admitted bad config would corrupt KV state long after the cause
    (mirrors :class:`~repro.serving.paging.PagingError`)."""


@dataclass
class GenStats:
    prompt_tokens: int
    new_tokens: int
    prefill_s: float
    decode_s: float
    prefill_traces: int = 0        # _prefill traces during this generate
    prefix_hits: int = 0           # admissions that shared >= 1 prefix token
    prefix_misses: int = 0         # paged admissions with nothing shared
    prefix_tokens_shared: int = 0  # prompt tokens served from cached pages
    mixed_steps: int = 0           # fused steps carrying a chunk AND decodes
    prefill_chunks: int = 0        # bounded prefill chunks run (budget mode)
    budget_utilization: float = 0.0  # tokens used / step budget, mean

    @property
    def tokens_per_s(self) -> float:
        return self.new_tokens / self.decode_s if self.decode_s > 0 else 0.0

    @property
    def prefix_hit_rate(self) -> float:
        n = self.prefix_hits + self.prefix_misses
        return self.prefix_hits / n if n else 0.0


@dataclass
class Request:
    prompt: str
    max_new_tokens: int = 32
    temperature: float = 0.0     # 0 = greedy
    slo: str = "batch"           # SLO class: "interactive" | "batch"
    # pre-encoded prompt override (resume path): generated token ids need
    # not round-trip through text, so a preemption resume carries raw ids
    prompt_ids: Optional[List[int]] = None


@dataclass
class EngineCompletion:
    """Per-request result carried out of the slot pool."""
    req_id: int
    request: Request
    text: str
    token_ids: List[int]
    prompt_tokens: int
    new_tokens: int
    time_in_engine_s: float      # admit -> finish (prefill + resident decode)
    ttft_s: float = 0.0          # admit -> first token (engine clock; 0 in
    #                              whole-suffix mode, where admit blocks
    #                              through the first sample)


@dataclass
class PreemptedRequest:
    """Resumable snapshot returned by :meth:`ServingEngine.preempt`.

    ``prompt_ids + emitted_ids`` is the exact token state to re-admit
    (as :attr:`Request.prompt_ids`); the sampled-but-unemitted pending
    token is intentionally absent — greedy resume recomputes it from
    identical logits, keeping resumed output token-identical."""
    req_id: int
    request: Request
    prompt_ids: List[int]        # the prompt as admitted (encoded)
    emitted_ids: List[int]       # tokens generated before preemption
    prompt_tokens: int
    budget_left: int             # decode budget remaining at preemption


@dataclass
class _Slot:
    req_id: int
    request: Request
    budget: int                  # per-slot decode budget
    prompt_tokens: int
    pending: Optional[int]       # sampled, not yet emitted/fed token; None
    #                              while the slot is still mid-prefill
    admitted_at: float
    page_ids: Optional[np.ndarray] = None   # pages referenced (shared+own)
    out_ids: List[int] = field(default_factory=list)
    enc: List[int] = field(default_factory=list)   # encoded prompt
    # ---- budget-mode partial-prefill state ----------------------------
    prefill_done: int = 0        # prompt tokens already in the arena
    page_row: Optional[np.ndarray] = None   # full page-table row, applied
    #                              to the decode table at prefill finish
    first_token_at: Optional[float] = None  # engine clock at first sample


@dataclass
class _Plan:
    """Host-side admission plan (memoized per request + page-state
    generation: matches go stale whenever pages move)."""
    enc: List[int]
    budget: int
    feasible: bool = True        # prompt fits max_seq - 1 (never truncated)
    total_pages: int = 0
    shared_ids: List[int] = field(default_factory=list)   # full-block pages
    tail: Optional[Tuple[int, int]] = None   # (CoW source page, tokens)
    need_fresh: int = 0

    @property
    def reuse_ids(self) -> List[int]:
        """Pages the admission reads from the cache: shared full-block maps
        plus the CoW source — all must be protected from eviction."""
        return self.shared_ids + ([self.tail[0]] if self.tail else [])


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees, is_leaf=is_pdef)


class ServingEngine:
    """One model instance serving a continuously-batched slot pool."""

    def __init__(self, cfg: ModelConfig, *, max_seq: int = 512,
                 max_batch: int = 8, seed: int = 0, params=None,
                 kv_layout: str = "auto", page_size: int = 16,
                 num_pages: Optional[int] = None, prefix_cache: bool = True,
                 clock: Optional[Callable[[], float]] = None,
                 step_token_budget: Optional[int] = None,
                 prefill_chunk: int = 32):
        self.cfg = cfg
        self.max_seq = max_seq
        self.max_batch = max_batch
        self._clock: Callable[[], float] = (time.perf_counter
                                            if clock is None else clock)
        self.tok = ByteTokenizer()
        if cfg.vocab < self.tok.vocab_size:
            raise EngineError(
                f"vocab {cfg.vocab} cannot cover the byte tokenizer's "
                f"{self.tok.vocab_size} ids")
        self.model = build_model(cfg, max_seq=max_seq)
        self.params = params if params is not None else self.model.init(
            jax.random.PRNGKey(seed))
        self._key = jax.random.PRNGKey(seed + 1)

        if kv_layout not in ("auto", "paged", "contiguous"):
            raise EngineError(f"unknown kv_layout {kv_layout!r}")
        if kv_layout == "auto":
            kv_layout = ("paged" if self.model.supports_paged_cache
                         else "contiguous")
        if kv_layout == "paged" and not self.model.supports_paged_cache:
            raise ValueError(
                f"{cfg.arch_id}: decoder cache cannot be paged "
                "(window/int8/SSM/cross state); use kv_layout='contiguous'")
        self.kv_layout = kv_layout

        # ---- fused chunked-prefill + decode (token-budget) config ---------
        self.budget_mode = step_token_budget is not None
        if self.budget_mode:
            if kv_layout != "paged":
                raise EngineError(
                    "step_token_budget requires the paged KV layout "
                    "(chunked prefill appends straight into arena pages)")
            if step_token_budget < 1 or prefill_chunk < 1:
                raise EngineError(
                    f"step_token_budget {step_token_budget} and "
                    f"prefill_chunk {prefill_chunk} must be >= 1")
        self.step_token_budget = step_token_budget
        self.prefill_chunk = min(prefill_chunk, max_seq)

        if kv_layout == "paged":
            if page_size % 8 != 0:
                raise EngineError(
                    f"page_size {page_size} must keep the 8-row layout")
            if max_seq % page_size != 0:
                raise EngineError(
                    f"max_seq {max_seq} not divisible by page_size "
                    f"{page_size}")
            self.page_size = page_size
            self.pages_per_slot = max_seq // page_size
            self.num_pages = (max_batch * self.pages_per_slot
                              if num_pages is None else num_pages)
            if self.num_pages < self.pages_per_slot:
                raise EngineError(
                    f"page pool of {self.num_pages} cannot fit one "
                    f"worst-case request ({self.pages_per_slot} pages)")
            # ---- page arena (+1: trash page 0) + host page state ----------
            arena_defs = self.model.paged_cache_defs(self.num_pages + 1,
                                                     page_size)
            self._cache = _tmap(lambda d: jnp.zeros(d.shape, d.dtype),
                                arena_defs)
            self._page_ax = _tmap(lambda d: d.axes.index("pages"), arena_defs)
            self._allocator = PageAllocator(self.num_pages)
            self._prefix = PrefixCache(page_size) if prefix_cache else None
            if self._prefix is not None:
                self._allocator.evict_cb = self._prefix.forget
            self._page_tables = np.full(
                (max_batch, self.pages_per_slot), TRASH_PAGE, np.int32)
        else:
            self.page_size = None
            self.pages_per_slot = None
            self.num_pages = None
            self._allocator = None
            self._prefix = None
            self._page_tables = None
            # ---- persistent KV-cache pool: one lane per slot --------------
            pool_defs = self.model.cache_defs(max_batch)
            self._batch_ax = _tmap(lambda d: d.axes.index("batch"), pool_defs)
            self._cache = _tmap(lambda d: jnp.zeros(d.shape, d.dtype),
                                pool_defs)

        # ---- host-side slot state -----------------------------------------
        self._slots: List[Optional[_Slot]] = [None] * max_batch
        self._tokens = np.full(max_batch, self.tok.pad_id, np.int32)
        self._positions = np.zeros(max_batch, np.int32)
        self._temps = np.zeros(max_batch, np.float32)
        self._next_req_id = 0
        self._plan_cache = None   # one-entry (request, generation, plan) memo
        self.peak_active = 0      # high-water mark of resident requests
        # wall-clock compute timers (real jit time; see module docstring —
        # logical progress lives in the token/round counters below)
        self.prefill_wall_s = 0.0
        self.decode_wall_s = 0.0
        self.prefill_tokens = 0   # suffix tokens actually prefilled
        self.decode_rounds = 0    # fused decode steps run with active slots
        self.mixed_steps = 0      # fused steps with a chunk AND >=1 decode
        self.prefill_chunks = 0   # bounded prefill chunks run (budget mode)
        self.budget_steps = 0     # budget-mode steps dispatched
        self.budget_tokens_used = 0  # decode rows + chunk tokens dispatched
        self._outstanding = None  # in-flight dispatch awaiting collect()
        self.prefix_hits = 0      # engine-lifetime prefix-cache counters
        self.prefix_misses = 0
        self.prefix_tokens_shared = 0
        self.preemptions = 0      # residents reclaimed via preempt()
        self.dead = False         # crashed and not yet restarted
        self.engine_generation = 0  # bumped on every restart()
        self.crashes = 0          # crash() calls over the engine's lifetime

        # ---- fixed-shape jitted functions with trace instrumentation ------
        # the counters increment only when JAX (re)traces a function, so a
        # stable engine shows exactly one decode/sample/insert/copy trace no
        # matter how many streams of differing batch mix it serves; prefill
        # traces once per power-of-two pad bucket.
        self.trace_counts: Dict[str, int] = {
            "prefill": 0, "decode": 0, "sample": 0, "insert": 0, "copy": 0,
            "fused": 0}

        def _prefill_fn(params, tokens, lengths):
            self.trace_counts["prefill"] += 1
            return self.model.prefill(params, tokens, None, lengths)

        def _prefill_paged_fn(params, cache, tokens, suffix_len, prefix_len,
                              page_row):
            self.trace_counts["prefill"] += 1
            return self.model.prefill_paged(
                params, cache, tokens, suffix_len, prefix_len, page_row,
                page_size=self.page_size)

        def _decode_fn(params, cache, tokens1, positions):
            self.trace_counts["decode"] += 1
            return self.model.decode_step(params, cache, tokens1, positions)

        def _decode_paged_fn(params, cache, tokens1, positions, page_tables):
            self.trace_counts["decode"] += 1
            return self.model.decode_step_paged(
                params, cache, tokens1, positions, page_tables,
                page_size=self.page_size)

        def _fused_fn(params, cache, tokens1, positions, page_tables,
                      chunk_tokens, chunk_suffix_len, chunk_prefix_len,
                      chunk_page_row):
            self.trace_counts["fused"] += 1
            return self.model.fused_step(
                params, cache, tokens1, positions, page_tables,
                chunk_tokens, chunk_suffix_len, chunk_prefix_len,
                chunk_page_row, page_size=self.page_size)

        def _sample_fn(logits, temps, key):
            self.trace_counts["sample"] += 1
            greedy = jnp.argmax(logits, -1).astype(jnp.int32)
            t = jnp.maximum(temps, 1e-4)[:, None]
            sampled = jax.random.categorical(key, logits / t, axis=-1)
            return jnp.where(temps > 0, sampled.astype(jnp.int32), greedy)

        def _insert_fn(pool, one, slot):
            self.trace_counts["insert"] += 1

            def put(big, small, ax):
                big_m = jnp.moveaxis(big, ax, 0)
                row = jnp.moveaxis(small, ax, 0)[0].astype(big_m.dtype)
                big_m = jax.lax.dynamic_update_index_in_dim(
                    big_m, row, slot, 0)
                return jnp.moveaxis(big_m, 0, ax)

            return jax.tree_util.tree_map(put, pool, one, self._batch_ax)

        def _copy_page_fn(arena, src, dst):
            """Device copy of one physical page across every layer's arena —
            the copy-on-write step for a matched partial tail page."""
            self.trace_counts["copy"] += 1

            def cp(big, ax):
                big_m = jnp.moveaxis(big, ax, 0)
                row = jax.lax.dynamic_index_in_dim(big_m, src, 0,
                                                   keepdims=False)
                big_m = jax.lax.dynamic_update_index_in_dim(
                    big_m, row, dst, 0)
                return jnp.moveaxis(big_m, 0, ax)

            return jax.tree_util.tree_map(cp, arena, self._page_ax)

        # donate the cache pool/arena through decode/insert/prefill so XLA
        # updates it in place instead of copying the whole pool per call
        # (CPU doesn't implement donation and would warn)
        donate = jax.default_backend() != "cpu"
        self._sample = jax.jit(_sample_fn)
        if kv_layout == "paged":
            self._prefill_paged = jax.jit(
                _prefill_paged_fn, donate_argnums=(1,) if donate else ())
            self._copy_page = jax.jit(
                _copy_page_fn, donate_argnums=(0,) if donate else ())
            self._decode = jax.jit(_decode_paged_fn,
                                   donate_argnums=(1,) if donate else ())
            if self.budget_mode:
                self._fused = jax.jit(
                    _fused_fn, donate_argnums=(1,) if donate else ())
                # chunk tokens always pad to ONE fixed bucket, so the fused
                # step and the chunk-only prefill each compile exactly once
                self._chunk_pad = self._pad_bucket(self.prefill_chunk)
        else:
            self._prefill = jax.jit(_prefill_fn)
            self._decode = jax.jit(_decode_fn,
                                   donate_argnums=(1,) if donate else ())
            self._insert = jax.jit(_insert_fn,
                                   donate_argnums=(0,) if donate else ())

    # ------------------------------------------------------------------
    # Slot-pool / page-pool introspection
    # ------------------------------------------------------------------
    @property
    def free_slots(self) -> int:
        return sum(s is None for s in self._slots)

    @property
    def active_slots(self) -> int:
        return self.max_batch - self.free_slots

    @property
    def has_active(self) -> bool:
        return any(s is not None for s in self._slots)

    @property
    def decode_traces(self) -> int:
        return self.trace_counts["decode"]

    @property
    def prefill_s(self) -> float:
        """Read-only alias of :attr:`prefill_wall_s` (historical name)."""
        return self.prefill_wall_s

    @property
    def decode_s(self) -> float:
        """Read-only alias of :attr:`decode_wall_s` (historical name)."""
        return self.decode_wall_s

    @property
    def prefilling_slots(self) -> int:
        """Residents still mid-prefill (budget mode; no first token yet)."""
        return sum(1 for s in self._slots
                   if s is not None and s.pending is None)

    @property
    def budget_utilization(self) -> float:
        """Mean fraction of ``step_token_budget`` actually dispatched per
        budget-mode step (decode rows + chunk tokens)."""
        if not self.budget_mode or self.budget_steps == 0:
            return 0.0
        return self.budget_tokens_used / (
            self.budget_steps * self.step_token_budget)

    @property
    def free_pages(self) -> Optional[int]:
        return self._allocator.free_pages if self._allocator else None

    @property
    def cached_pages(self) -> Optional[int]:
        """Refcount-0 pages retained by the prefix cache (reclaimable)."""
        return self._allocator.cached_pages if self._allocator else None

    @property
    def available_pages(self) -> Optional[int]:
        """Pages an admission could obtain (free + LRU-evictable)."""
        return self._allocator.available_pages if self._allocator else None

    @property
    def kv_cache_tokens(self) -> int:
        """Token capacity of the KV memory (paged: usable pages; contiguous:
        the full slot pool)."""
        if self.kv_layout == "paged":
            return self.num_pages * self.page_size
        return self.max_batch * self.max_seq

    @property
    def kv_cache_bytes(self) -> int:
        return int(sum(x.nbytes for x in jax.tree_util.tree_leaves(
            self._cache)))

    @property
    def prefix_cache_enabled(self) -> bool:
        return self._prefix is not None

    @property
    def pad_buckets(self) -> List[int]:
        """Every prefill pad bucket this engine can compile — the bound on
        lifetime prefill traces; also what :meth:`warmup` iterates. In
        budget mode all prefill runs as fixed-size chunks, so exactly ONE
        bucket (``_pad_bucket(prefill_chunk)``) is reachable and the
        power-of-two sweep collapses; otherwise 8, 16, ..., ``max_seq``."""
        if self.budget_mode:
            return [self._chunk_pad]
        out, b = [], self._pad_bucket(1)
        while b < self.max_seq:
            out.append(b)
            b = self._pad_bucket(b + 1)
        out.append(self._pad_bucket(self.max_seq))
        return out

    # ------------------------------------------------------------------
    # Continuous-batching API: can_admit / admit / step
    # ------------------------------------------------------------------
    def _pad_bucket(self, n: int) -> int:
        """Prefill pad length for ``n`` tokens: next power of two (>= 8,
        capped at ``max_seq``). Heavy-tailed workloads therefore retrace
        prefill at most ``log2(max_seq)`` times instead of once per
        ``q_chunk`` multiple; :meth:`warmup` precompiles every bucket."""
        p = max(8, 1 << (max(n, 1) - 1).bit_length())
        qc = max(self.cfg.q_chunk, 1)
        if p > qc and p % qc:
            p = -(-p // qc) * qc          # blockwise prefill needs qc chunks
        return min(p, self.max_seq)

    def _encode(self, request: Request) -> List[int]:
        """Token ids for a request's prompt: the pre-encoded override when
        present (preemption resume carries generated ids that need not
        round-trip through text), otherwise the tokenizer."""
        if request.prompt_ids is not None:
            return [int(t) for t in request.prompt_ids]
        return self.tok.encode(request.prompt)

    def fits(self, request: Request) -> bool:
        """Could this request EVER be admitted here (i.e. on an idle
        engine)? False when the encoded prompt is empty or cannot leave
        room for one generated token — admission would have to silently
        truncate the prompt tail (the question, in a context-first RAG
        prompt), so such requests are rejected up front instead
        (:class:`SchedulerError <repro.serving.scheduler.SchedulerError>`
        at submit; :class:`EngineError` at admit)."""
        return 1 <= len(self._encode(request)) <= self.max_seq - 1

    def _plan(self, request: Request) -> _Plan:
        """Admission plan: encoded prompt, decode budget and — in paged
        mode — the prefix-cache match (shared full-block pages + CoW tail)
        and the fresh-page demand it leaves. Memoized for the last request
        seen at the current page-state generation: a queue head blocked on
        pages is re-planned by ``can_admit`` every decode step, and
        ``admit`` re-plans right after the ``can_admit`` that green-lit it
        — but any alloc/free/evict in between invalidates the match.
        Prompts that cannot fit are marked infeasible, never truncated."""
        gen = self._allocator.generation if self._allocator else 0
        cached = self._plan_cache
        if cached is not None and cached[0] is request and cached[1] == gen:
            return cached[2]
        enc = self._encode(request)
        L = len(enc)
        if not 1 <= L <= self.max_seq - 1:
            plan = _Plan(enc, 0, feasible=False)
            self._plan_cache = (request, gen, plan)
            return plan
        budget = max(0, min(request.max_new_tokens, self.max_seq - L))
        plan = _Plan(enc, budget)
        if self.kv_layout == "paged":
            plan.total_pages = pages_needed(L + budget, self.page_size)
            if self._prefix is not None:
                # cap the match at L-1 tokens: at least one suffix token
                # must remain to prefill for first-token logits
                plan.shared_ids, plan.tail = self._prefix.match(enc[:L - 1])
            plan.need_fresh = plan.total_pages - len(plan.shared_ids)
        self._plan_cache = (request, gen, plan)
        return plan

    def can_admit(self, request: Request) -> bool:
        """A free slot AND (paged) enough allocatable pages for the
        request's unshared demand. Because pages are reserved through a
        request's whole budget, an engine draining its residents always
        becomes admissible again. A crashed engine admits nothing until
        :meth:`restart`."""
        if self.dead or self.free_slots == 0:
            return False
        plan = self._plan(request)
        if not plan.feasible:
            return False
        if self.kv_layout != "paged":
            return True
        return self._allocator.can_reserve(plan.need_fresh, plan.reuse_ids)

    def admit(self, request: Request) -> int:
        """Prefill one request into a free slot. In paged mode this is the
        prefix-cache hot path: map matched shared pages, CoW-copy a matched
        partial tail page, then prefill ONLY the unique suffix straight
        into freshly allocated pages. Returns the engine-local request id
        used in :class:`EngineCompletion`. Callers gate on
        :meth:`can_admit`."""
        if self.dead:
            raise EngineError("admit: engine crashed; restart() first")
        slot = next((i for i, s in enumerate(self._slots) if s is None), None)
        if slot is None:
            raise RuntimeError("no free slot; check can_admit before admit")
        plan = self._plan(request)
        if not plan.feasible:
            raise EngineError(
                f"prompt of {len(plan.enc)} tokens cannot fit max_seq "
                f"{self.max_seq} with >=1 generated token; refusing to "
                "truncate silently")
        enc, budget = plan.enc, plan.budget
        L = len(enc)

        t0 = time.perf_counter()
        if self.kv_layout == "paged":
            ps = self.page_size
            # protect every reused page (shared maps AND the CoW source)
            # from the eviction that alloc may trigger
            self._allocator.ref(plan.reuse_ids)
            try:
                fresh = self._allocator.alloc(plan.need_fresh)
            except Exception:
                # callers that skipped can_admit must not leak references
                self._allocator.free(
                    plan.reuse_ids,
                    retain=self._prefix.owns if self._prefix else None)
                raise
            n_shared = len(plan.shared_ids)
            row = np.full(self.pages_per_slot, TRASH_PAGE, np.int32)
            row[:n_shared] = plan.shared_ids
            row[n_shared:plan.total_pages] = fresh
            prefix_len = n_shared * ps
            if plan.tail is not None:
                src, t_match = plan.tail
                self._cache = self._copy_page(
                    self._cache, jnp.int32(src), jnp.int32(int(row[n_shared])))
                prefix_len += t_match
                # drop the temporary protection ref on the CoW source (its
                # contents now live in the slot's private copy)
                self._allocator.free(
                    [src], retain=self._prefix.owns if self._prefix else None)
            if self.budget_mode:
                # ---- async admission: NO model compute here ----------
                # Pages are mapped and reserved, but every prefill token
                # runs later as budgeted chunks in dispatch(). The slot
                # parks mid-prefill with a masked decode row (trash page
                # table, pad token, position 0); the prefix-cache insert
                # waits for the final chunk — indexing pages before their
                # KV exists would let a later admission map garbage.
                page_ids = row[:plan.total_pages].copy()
                if self._prefix is not None:
                    if prefix_len:
                        self.prefix_hits += 1
                    else:
                        self.prefix_misses += 1
                    self.prefix_tokens_shared += prefix_len
                self.prefill_wall_s += time.perf_counter() - t0
                rid = self._next_req_id
                self._next_req_id += 1
                self._slots[slot] = _Slot(
                    rid, request, budget, L, None,
                    admitted_at=self._clock(), page_ids=page_ids, enc=enc,
                    prefill_done=prefix_len, page_row=row)
                self.peak_active = max(self.peak_active, self.active_slots)
                return rid
            suffix = enc[prefix_len:]
            pad_len = self._pad_bucket(len(suffix))
            tokens, _ = self.tok.pad_batch([suffix], pad_len)
            logits, self._cache = self._prefill_paged(
                self.params, self._cache, jnp.asarray(tokens),
                jnp.int32(len(suffix)), jnp.int32(prefix_len),
                jnp.asarray(row))
            self._page_tables[slot] = row
            self.prefill_tokens += len(suffix)
            page_ids = row[:plan.total_pages].copy()
            if self._prefix is not None:
                self._prefix.insert(enc, row)
                if prefix_len:
                    self.prefix_hits += 1
                else:
                    self.prefix_misses += 1
                self.prefix_tokens_shared += prefix_len
        else:
            page_ids = None
            pad_len = self._pad_bucket(L)
            tokens, lengths = self.tok.pad_batch([enc], pad_len)
            logits, lane = self._prefill(self.params, jnp.asarray(tokens),
                                         jnp.asarray(lengths))
            self._cache = self._insert(self._cache, lane, np.int32(slot))
            self.prefill_tokens += L
        self._key, sub = jax.random.split(self._key)
        first = self._sample(logits,
                             jnp.asarray([request.temperature], jnp.float32),
                             sub)
        pending = int(jax.block_until_ready(first)[0])
        self.prefill_wall_s += time.perf_counter() - t0

        rid = self._next_req_id
        self._next_req_id += 1
        self._slots[slot] = _Slot(rid, request, budget, L, pending,
                                  admitted_at=self._clock(),
                                  page_ids=page_ids, enc=enc)
        self._tokens[slot] = pending
        self._positions[slot] = L
        self._temps[slot] = request.temperature
        self.peak_active = max(self.peak_active, self.active_slots)
        return rid

    def step(self) -> List[EngineCompletion]:
        """One pump of the pool: harvest pending tokens (retiring finished
        sequences, freeing their slot and page references), then dispatch
        and immediately collect ONE fixed-shape device step — a fused
        decode, or in budget mode a fused chunked-prefill + decode — for
        whatever remains active. Schedulers wanting async overlap call
        :meth:`harvest` / :meth:`dispatch` per engine and :meth:`collect`
        at the end of the round instead."""
        done = self.harvest()
        self.dispatch()
        self.collect()
        return done

    def harvest(self) -> List[EngineCompletion]:
        """Emit pending tokens and retire finished sequences (freeing their
        slot and page references). Mid-prefill residents (budget mode,
        ``pending is None``) have nothing to emit and are skipped."""
        if self.dead:
            raise EngineError("step: engine crashed; restart() first")
        done: List[EngineCompletion] = []
        now = self._clock()
        for i, s in enumerate(self._slots):
            if s is None or s.pending is None:
                continue
            finished = (s.pending == self.tok.eos_id
                        or len(s.out_ids) >= s.budget)
            if not finished:
                s.out_ids.append(s.pending)
                finished = len(s.out_ids) >= s.budget
            if finished:
                ft = (s.first_token_at if s.first_token_at is not None
                      else s.admitted_at)
                done.append(EngineCompletion(
                    s.req_id, s.request, self.tok.decode(s.out_ids),
                    s.out_ids, s.prompt_tokens, len(s.out_ids),
                    time_in_engine_s=max(now - s.admitted_at, 0.0),
                    ttft_s=max(ft - s.admitted_at, 0.0)))
                self._free(i)
        return done

    def _pick_chunk(self, n_decode: int):
        """Budget policy: which mid-prefill resident advances this step,
        and by how many tokens. Highest priority first (interactive SLO
        before batch, then admission order). Decode rows spend one budget
        token each; the chunk gets what is left, capped at
        ``prefill_chunk``. A fully decode-consumed budget still yields a
        small chunk for an *interactive* head (starvation guard — first
        tokens are what the interactive SLO is about); with no decodes
        resident the chunk takes the whole ``prefill_chunk``."""
        cands = [(0 if s.request.slo == "interactive" else 1, s.req_id, i, s)
                 for i, s in enumerate(self._slots)
                 if s is not None and s.pending is None]
        if not cands:
            return None
        _, _, ci, cs = min(cands)
        remaining = cs.prompt_tokens - cs.prefill_done
        leftover = self.step_token_budget - n_decode
        if n_decode == 0:
            clen = min(self.prefill_chunk, remaining)
        elif leftover > 0:
            clen = min(self.prefill_chunk, remaining, leftover)
        elif cs.request.slo == "interactive":
            clen = min(8, self.prefill_chunk, remaining)
        else:
            return None
        return (ci, cs, clen)

    def dispatch(self) -> None:
        """Launch the next device step WITHOUT blocking (JAX async
        dispatch): the fixed-shape decode for every live decode row, fused
        — in budget mode — with one bounded prefill chunk chosen by
        :meth:`_pick_chunk`. Results are fetched by :meth:`collect`; a
        second dispatch before that is an error. No-op when nothing is
        resident (or, budget mode, nothing fits the policy this step)."""
        if self.dead:
            raise EngineError("dispatch: engine crashed; restart() first")
        if self._outstanding is not None:
            raise EngineError(
                "dispatch: a step is already in flight; collect() first")
        dec = [(i, s.req_id) for i, s in enumerate(self._slots)
               if s is not None and s.pending is not None]
        chunk = self._pick_chunk(len(dec)) if self.budget_mode else None
        if not dec and chunk is None:
            return
        t0 = time.perf_counter()
        out = {"t0": t0, "dec": dec, "dec_tokens": None, "chunk": None}
        if self.budget_mode:
            self.budget_steps += 1
            self.budget_tokens_used += len(dec) + (chunk[2] if chunk else 0)
        dec_logits = None
        if chunk is not None:
            ci, cs, clen = chunk
            lo = cs.prefill_done
            ctoks, _ = self.tok.pad_batch([cs.enc[lo:lo + clen]],
                                          self._chunk_pad)
            finishing = lo + clen >= cs.prompt_tokens
            if dec:
                dec_logits, chunk_logits, self._cache = self._fused(
                    self.params, self._cache,
                    jnp.asarray(self._tokens)[:, None],
                    jnp.asarray(self._positions),
                    jnp.asarray(self._page_tables),
                    jnp.asarray(ctoks), jnp.int32(clen), jnp.int32(lo),
                    jnp.asarray(cs.page_row))
            else:
                chunk_logits, self._cache = self._prefill_paged(
                    self.params, self._cache, jnp.asarray(ctoks),
                    jnp.int32(clen), jnp.int32(lo),
                    jnp.asarray(cs.page_row))
            ctok = None
            if finishing:     # only the FINAL chunk's logits are the first-
                self._key, sub = jax.random.split(self._key)  # token logits
                ctok = self._sample(
                    chunk_logits,
                    jnp.asarray([cs.request.temperature], jnp.float32), sub)
            out["chunk"] = (ci, cs.req_id, clen, finishing, ctok)
        elif dec:
            args = (self.params, self._cache,
                    jnp.asarray(self._tokens)[:, None],
                    jnp.asarray(self._positions))
            if self.kv_layout == "paged":
                args += (jnp.asarray(self._page_tables),)
            dec_logits, self._cache = self._decode(*args)
        if dec:
            self.decode_rounds += 1
            if chunk is not None:
                self.mixed_steps += 1
            self._key, sub = jax.random.split(self._key)
            out["dec_tokens"] = self._sample(dec_logits,
                                             jnp.asarray(self._temps), sub)
        self._outstanding = out

    def collect(self) -> None:
        """Block on the in-flight step (if any) and apply its results
        host-side: feed sampled decode tokens back as the next pending
        token, advance the chunk owner's ``prefill_done``, and — on the
        final chunk — unmask its decode row, stamp ``first_token_at`` and
        insert the now-complete prompt into the prefix index. Results are
        applied only to slots still holding the same request in the same
        phase, so a preempt/cancel/crash that raced the in-flight step is
        simply dropped (see the module docstring's hazard notes)."""
        if self._outstanding is None:
            return
        out, self._outstanding = self._outstanding, None
        nxt = None
        if out["dec_tokens"] is not None:
            nxt = np.asarray(jax.block_until_ready(out["dec_tokens"]))
        ch = out["chunk"]
        ctok_val = None
        if ch is not None and ch[4] is not None:
            ctok_val = int(jax.block_until_ready(ch[4])[0])
        span = time.perf_counter() - out["t0"]
        if out["dec"]:
            self.decode_wall_s += span
        else:
            self.prefill_wall_s += span
        for i, rid in out["dec"]:
            s = self._slots[i]
            if s is None or s.req_id != rid or s.pending is None:
                continue      # retired/preempted while in flight
            s.pending = int(nxt[i])
            self._tokens[i] = s.pending
            self._positions[i] += 1
        if ch is not None:
            ci, rid, clen, finishing, _ = ch
            s = self._slots[ci]
            if s is not None and s.req_id == rid and s.pending is None:
                s.prefill_done += clen
                self.prefill_tokens += clen
                self.prefill_chunks += 1
                if finishing:
                    s.pending = ctok_val
                    self._tokens[ci] = ctok_val
                    self._positions[ci] = s.prompt_tokens
                    self._page_tables[ci] = s.page_row
                    s.first_token_at = self._clock()
                    if self._prefix is not None:
                        self._prefix.insert(s.enc, s.page_row)

    def _free(self, slot: int) -> None:
        s = self._slots[slot]
        if s is not None and s.page_ids is not None:
            # drop one reference per page; decrement-to-zero pages the
            # prefix index values are retained (LRU) instead of freed
            self._allocator.free(
                s.page_ids,
                retain=self._prefix.owns if self._prefix else None)
            self._page_tables[slot] = TRASH_PAGE
        self._slots[slot] = None
        self._tokens[slot] = self.tok.pad_id
        self._positions[slot] = 0     # inactive lanes park at position 0
        self._temps[slot] = 0.0

    def preempt(self, req_id: int) -> PreemptedRequest:
        """Reclaim a resident request mid-decode and return a resumable
        snapshot. The slot and every page reference are released exactly as
        on normal retirement (private suffix pages go back to the
        allocator; shared prefix pages the index values park in the LRU
        pool), so page accounting balances to the admission-time state.

        The snapshot excludes the sampled-but-unemitted pending token:
        resuming re-admits ``prompt_ids = enc + emitted_ids`` (through
        :attr:`Request.prompt_ids`), the prefix cache serves the original
        prompt's pages, only the generated suffix is recomputed, and greedy
        decode re-derives the pending token from identical logits — so a
        preempted-then-resumed greedy request is token-identical to an
        uninterrupted run. Raises :class:`EngineError` for unknown ids."""
        if self.dead:
            raise EngineError(
                "preempt: engine crashed — nothing survives a crash; the "
                "scheduler reaps lost residents instead of preempting them")
        slot = next((i for i, s in enumerate(self._slots)
                     if s is not None and s.req_id == req_id), None)
        if slot is None:
            raise EngineError(f"preempt: request {req_id} is not resident")
        s = self._slots[slot]
        snap = PreemptedRequest(
            req_id=s.req_id, request=s.request, prompt_ids=list(s.enc),
            emitted_ids=list(s.out_ids), prompt_tokens=s.prompt_tokens,
            budget_left=s.budget - len(s.out_ids))
        self._free(slot)
        self.preemptions += 1
        return snap

    def audit(self) -> Dict[str, int]:
        """Page-accounting audit: cross-check the allocator's free list, LRU
        pool and refcounts against the resident slots' page mappings (every
        page exactly one of FREE/CACHED/ACTIVE, populations summing to
        ``num_pages``, refcount == number of slots mapping the page).
        This is the DST page-arena oracle, also called at the end of every
        bench ``--check``. Raises :class:`PagingError` on any breach.
        Contiguous engines have no allocator and dead engines' device
        bookkeeping is declared lost until :meth:`restart` — both return a
        trivial report instead of being checked."""
        if self._allocator is None or self.dead:
            return {"num_pages": 0, "free": 0, "cached": 0, "active": 0,
                    "skipped": 1}
        mapped: Dict[int, int] = {}
        for s in self._slots:
            if s is not None and s.page_ids is not None:
                for pid in s.page_ids:
                    pid = int(pid)
                    mapped[pid] = mapped.get(pid, 0) + 1
        return self._allocator.audit(mapped)

    def assert_quiescent(self) -> Dict[str, int]:
        """Audit an engine that should be fully drained: no resident slots,
        and every page either free or parked in the LRU pool (ACTIVE count
        zero — anything else is a leaked reference). Raises
        :class:`EngineError` / :class:`PagingError` on violation; returns
        the audit report. Dead engines are skipped (restart rebuilds cold)."""
        if self.dead:
            return {"num_pages": 0, "free": 0, "cached": 0, "active": 0,
                    "skipped": 1}
        if self.has_active:
            raise EngineError(
                f"assert_quiescent: {self.active_slots} slot(s) still "
                f"resident")
        rep = self.audit()
        if rep.get("active", 0):
            raise EngineError(
                f"assert_quiescent: page leak — {rep['active']} page(s) "
                f"still referenced with no resident slots")
        return rep

    def invalidate_prefix_cache(self) -> int:
        """Drop every prefix-cache entry (knowledge rotation made cached
        retrieved-context prefixes stale). Bumps the allocator generation
        so memoized admission plans re-match, and leaves refcount-0 pages
        in the LRU pool unowned — reclaimed on demand, never served again.
        Returns the number of index entries dropped (0 when the prefix
        cache is disabled or the layout is contiguous)."""
        if self._prefix is None:
            return 0
        n = self._prefix.clear()
        self._allocator.bump_generation()
        self._plan_cache = None
        return n

    # ------------------------------------------------------------------
    # Hard failure: crash / restart
    # ------------------------------------------------------------------
    def crash(self) -> List[int]:
        """Hard failure: the engine process/device is gone. Every resident
        request dies with it (their generated-so-far tokens included —
        unlike :meth:`preempt`, nothing is snapshotted), the page arena,
        allocator and prefix index are lost, and the engine refuses all
        work (``dead``) until :meth:`restart`. Returns the engine-local
        request ids of the residents that were lost, so a scheduler can
        reap its bookkeeping for them."""
        if self.dead:
            raise EngineError("crash: engine is already dead")
        lost = [s.req_id for s in self._slots if s is not None]
        self.dead = True
        self.crashes += 1
        # host-side slot state is wiped immediately; the device arena and
        # page bookkeeping are rebuilt cold by restart()
        self._slots = [None] * self.max_batch
        self._tokens[:] = self.tok.pad_id
        self._positions[:] = 0
        self._temps[:] = 0.0
        self._plan_cache = None
        self._outstanding = None     # in-flight device step died with it
        return lost

    def restart(self) -> None:
        """Rebuild a COLD engine after :meth:`crash`: zeroed KV arena,
        fresh allocator and prefix index, empty slot pool, and a bumped
        :attr:`engine_generation` (so any stale external reference —
        scheduler resident keys, memoized plans — is detectably invalid).
        The jitted functions are kept: shapes and dtypes are unchanged,
        so a restarted engine serves without re-tracing. Request ids keep
        counting up across restarts — a pre-crash id can never collide
        with a post-restart admission."""
        if not self.dead:
            raise EngineError("restart: engine has not crashed")
        if self.kv_layout == "paged":
            arena_defs = self.model.paged_cache_defs(self.num_pages + 1,
                                                     self.page_size)
            self._cache = _tmap(lambda d: jnp.zeros(d.shape, d.dtype),
                                arena_defs)
            self._allocator = PageAllocator(self.num_pages)
            if self._prefix is not None:
                self._prefix = PrefixCache(self.page_size)
                self._allocator.evict_cb = self._prefix.forget
            self._page_tables = np.full(
                (self.max_batch, self.pages_per_slot), TRASH_PAGE, np.int32)
        else:
            pool_defs = self.model.cache_defs(self.max_batch)
            self._cache = _tmap(lambda d: jnp.zeros(d.shape, d.dtype),
                                pool_defs)
        self._plan_cache = None
        self.engine_generation += 1
        self.dead = False

    # ------------------------------------------------------------------
    # Batch conveniences on top of the pool
    # ------------------------------------------------------------------
    def generate(self, requests: Sequence[Request]
                 ) -> Tuple[List[str], GenStats]:
        """Continuously-batched generation: requests are admitted as slots
        (and pages) free up, so any number of requests stream through
        ``max_batch`` lanes. Output order matches input order."""
        return self._pump_all(requests, continuous=True)

    def generate_static(self, requests: Sequence[Request]
                        ) -> Tuple[List[str], GenStats]:
        """Static-batch baseline: admit one batch (<= max_batch), then block
        until EVERY sequence finishes — no mid-decode admission. Kept for
        benchmarking and equivalence testing against the continuous path.
        With a deliberately small page pool the batch may not fit at once;
        size ``num_pages`` for the worst case when using this path."""
        if not 0 < len(requests) <= self.max_batch:
            raise EngineError(
                f"static batch of {len(requests)} requests exceeds the "
                f"bounds (1..{self.max_batch})")
        return self._pump_all(requests, continuous=False)

    def _pump_all(self, requests: Sequence[Request], *, continuous: bool
                  ) -> Tuple[List[str], GenStats]:
        if self.dead:
            raise EngineError("engine crashed; restart() first")
        if self.has_active:
            raise EngineError("engine already has resident requests")
        bad = next((r for r in requests if not self.fits(r)), None)
        if bad is not None:
            raise EngineError(
                f"request with {len(self._encode(bad))} prompt tokens can "
                f"never fit max_seq {self.max_seq}; the pump loop would "
                "spin on it forever")
        p0, d0 = self.prefill_wall_s, self.decode_wall_s
        t0 = self.trace_counts["prefill"]
        h0, m0, s0 = (self.prefix_hits, self.prefix_misses,
                      self.prefix_tokens_shared)
        ms0, pc0 = self.mixed_steps, self.prefill_chunks
        queue = list(requests)
        rid_to_idx: Dict[int, int] = {}
        comps: Dict[int, EngineCompletion] = {}
        if not continuous:                      # one up-front batch, no more
            for i, r in enumerate(queue):
                rid_to_idx[self.admit(r)] = i
            queue = []
        while queue or self.has_active:
            while continuous and queue and self.can_admit(queue[0]):
                req = queue.pop(0)
                rid_to_idx[self.admit(req)] = len(requests) - len(queue) - 1
            for ec in self.step():
                comps[rid_to_idx[ec.req_id]] = ec
        ordered = [comps[i] for i in range(len(requests))]
        stats = GenStats(
            prompt_tokens=sum(c.prompt_tokens for c in ordered),
            new_tokens=sum(c.new_tokens for c in ordered),
            prefill_s=self.prefill_wall_s - p0,
            decode_s=self.decode_wall_s - d0,
            prefill_traces=self.trace_counts["prefill"] - t0,
            prefix_hits=self.prefix_hits - h0,
            prefix_misses=self.prefix_misses - m0,
            prefix_tokens_shared=self.prefix_tokens_shared - s0,
            mixed_steps=self.mixed_steps - ms0,
            prefill_chunks=self.prefill_chunks - pc0,
            budget_utilization=self.budget_utilization)
        return [c.text for c in ordered], stats

    # ------------------------------------------------------------------
    def warmup(self, prompt_lens: Iterable[int] = (1,)) -> None:
        """Pre-compile every fixed-shape function (decode, sample, page
        copy / insert) and EVERY reachable prefill bucket up to the
        largest implied by ``prompt_lens``, leaving the pool idle. Smaller
        buckets are compiled too because prefix-cache hits shrink the
        prefilled suffix below the prompt length. In budget mode the
        power-of-two sweep collapses to the single chunk bucket (the only
        prefill shape :meth:`dispatch` can ever issue) plus the fused
        step — ``prompt_lens`` no longer matters, and warmup compiles
        O(1) functions instead of ``log2(max_seq)`` unused ones. Lets
        benchmarks separate compile from serve time."""
        if self.dead:
            raise EngineError("cannot warm up a crashed engine")
        if self.has_active:
            raise EngineError("cannot warm up a busy engine")
        if self.budget_mode:
            buckets = list(self.pad_buckets)     # just the chunk bucket
        else:
            cap = max((self._pad_bucket(max(n, 1)) for n in prompt_lens),
                      default=8)
            buckets = [b for b in self.pad_buckets if b <= cap]
        key = jax.random.PRNGKey(0)
        paged = self.kv_layout == "paged"
        # rebind the pool at every call: the cache argument is donated, so
        # the old buffer is dead after each decode/prefill/copy (pool is
        # idle — a paged warmup scribbles only on the trash page, a
        # contiguous one on lane 0, which is rewritten on admission)
        for pad_len in buckets:
            toks = jnp.zeros((1, pad_len), jnp.int32)
            if paged:
                trash_row = jnp.full((self.pages_per_slot,), TRASH_PAGE,
                                     jnp.int32)
                logits, self._cache = self._prefill_paged(
                    self.params, self._cache, toks, jnp.int32(1),
                    jnp.int32(0), trash_row)
            else:
                logits, lane = self._prefill(
                    self.params, toks, jnp.asarray([pad_len], jnp.int32))
                self._cache = self._insert(self._cache, lane, np.int32(0))
            self._sample(logits, jnp.asarray([0.0], jnp.float32), key)
        if paged:
            self._cache = self._copy_page(self._cache, jnp.int32(TRASH_PAGE),
                                          jnp.int32(TRASH_PAGE))
        if self.budget_mode:
            # warm the fused step: all-trash rows, 1-token chunk — writes
            # land only on the trash page, results are discarded
            trash_row = jnp.full((self.pages_per_slot,), TRASH_PAGE,
                                 jnp.int32)
            _, cl, self._cache = self._fused(
                self.params, self._cache,
                jnp.asarray(self._tokens)[:, None],
                jnp.asarray(self._positions),
                jnp.asarray(self._page_tables),
                jnp.zeros((1, self._chunk_pad), jnp.int32),
                jnp.int32(1), jnp.int32(0), trash_row)
            self._sample(cl, jnp.asarray([0.0], jnp.float32), key)
        args = (self.params, self._cache,
                jnp.asarray(self._tokens)[:, None],
                jnp.asarray(self._positions))
        if paged:
            args += (jnp.asarray(self._page_tables),)
        _, self._cache = self._decode(*args)
        self._sample(jnp.zeros((self.max_batch, self.cfg.vocab), jnp.float32),
                     jnp.asarray(self._temps), key)


def make_edge_engine(*, max_seq: int = 512, max_batch: int = 8,
                     seed: int = 0, **kw) -> ServingEngine:
    """Default edge SLM: reduced qwen2-0.5b (byte vocab capable). Extra
    keyword args (kv_layout, page_size, num_pages, prefix_cache, ...) pass
    through."""
    from repro.configs import get_config
    cfg = get_config("qwen2-0.5b", reduced=True)
    return ServingEngine(cfg, max_seq=max_seq, max_batch=max_batch, seed=seed,
                         **kw)


def make_cloud_engine(*, max_seq: int = 512, max_batch: int = 8,
                      seed: int = 0, **kw) -> ServingEngine:
    """Cloud-tier engine: reduced qwen2-72b family (the paper's large-LLM
    arm), byte-vocab capable. Extra keyword args pass through."""
    from repro.configs import get_config
    cfg = get_config("qwen2-72b", reduced=True)
    return ServingEngine(cfg, max_seq=max_seq, max_batch=max_batch, seed=seed,
                         **kw)


__all__ = ["ServingEngine", "Request", "GenStats", "EngineCompletion",
           "EngineError", "PreemptedRequest", "make_edge_engine",
           "make_cloud_engine"]
