"""Batched serving engine: real JAX prefill + autoregressive decode with a
KV cache, greedy or temperature sampling. This is the engine that runs at
edge nodes (reduced SLM) and — in pod deployment — behind the cloud tier.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import ByteTokenizer
from repro.models.api import Model, build_model
from repro.models.pdefs import abstract_from_defs, init_from_defs


@dataclass
class GenStats:
    prompt_tokens: int
    new_tokens: int
    prefill_s: float
    decode_s: float

    @property
    def tokens_per_s(self) -> float:
        return self.new_tokens / self.decode_s if self.decode_s > 0 else 0.0


@dataclass
class Request:
    prompt: str
    max_new_tokens: int = 32
    temperature: float = 0.0     # 0 = greedy


class ServingEngine:
    """One model instance serving padded batches."""

    def __init__(self, cfg: ModelConfig, *, max_seq: int = 512,
                 max_batch: int = 8, seed: int = 0,
                 params=None):
        self.cfg = cfg
        self.max_seq = max_seq
        self.max_batch = max_batch
        self.tok = ByteTokenizer()
        assert cfg.vocab >= self.tok.vocab_size, "vocab must cover bytes"
        self.model = build_model(cfg, max_seq=max_seq)
        self.params = params if params is not None else self.model.init(
            jax.random.PRNGKey(seed))
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)
        self._key = jax.random.PRNGKey(seed + 1)

    # ------------------------------------------------------------------
    def generate(self, requests: Sequence[Request]
                 ) -> Tuple[List[str], GenStats]:
        assert 0 < len(requests) <= self.max_batch
        B = len(requests)
        enc = [self.tok.encode(r.prompt)[: self.max_seq - 1] for r in requests]
        max_new = max(r.max_new_tokens for r in requests)
        max_new = min(max_new, self.max_seq - max(len(e) for e in enc))
        # pad the prompt block to a q_chunk multiple (blockwise attention);
        # per-row lengths keep logits/cache writes at the real positions
        qc = max(self.cfg.q_chunk, 1)
        longest = max(len(e) for e in enc)
        pad_len = min(-(-longest // qc) * qc, self.max_seq)
        tokens, lengths = self.tok.pad_batch(enc, pad_len)
        tokens = jnp.asarray(tokens)
        lengths = jnp.asarray(lengths)

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, tokens, None, lengths)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        out_ids = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        positions = np.asarray(lengths)
        t0 = time.perf_counter()
        cur = self._sample(logits, requests)
        for step in range(max_new):
            for i in range(B):
                if not done[i]:
                    tid = int(cur[i])
                    if tid == self.tok.eos_id:
                        done[i] = True
                    else:
                        out_ids[i].append(tid)
            if done.all():
                break
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(cur)[:, None],
                                         jnp.asarray(positions, jnp.int32))
            positions = positions + 1
            cur = self._sample(logits, requests)
        t_decode = time.perf_counter() - t0

        texts = [self.tok.decode(ids) for ids in out_ids]
        stats = GenStats(
            prompt_tokens=int(np.asarray(lengths).sum()),
            new_tokens=sum(len(i) for i in out_ids),
            prefill_s=t_prefill, decode_s=t_decode,
        )
        return texts, stats

    def _sample(self, logits, requests) -> np.ndarray:
        temps = np.array([r.temperature for r in requests], np.float32)
        greedy = np.asarray(jnp.argmax(logits, -1))
        if (temps <= 0).all():
            return greedy
        self._key, sub = jax.random.split(self._key)
        t = jnp.maximum(jnp.asarray(temps), 1e-4)[:, None]
        sampled = np.asarray(jax.random.categorical(sub, logits / t, axis=-1))
        return np.where(temps > 0, sampled, greedy)


def make_edge_engine(*, max_seq: int = 512, seed: int = 0) -> ServingEngine:
    """Default edge SLM: reduced qwen2-0.5b (byte vocab capable)."""
    from repro.configs import get_config
    cfg = get_config("qwen2-0.5b", reduced=True)
    return ServingEngine(cfg, max_seq=max_seq, seed=seed)


__all__ = ["ServingEngine", "Request", "GenStats", "make_edge_engine"]
