"""Continuous-batching serving engine with a slot-based KV-cache pool.

This is the engine that runs at edge nodes (reduced SLM) and — in pod
deployment — behind the cloud tier. It replaces the old static-batch path
(pad a batch, block until every sequence finishes, re-trace per batch
shape) with a fixed-capacity slot pool:

* ``max_batch`` slots, each owning one lane of a persistent KV-cache pool
  (allocated once at ``[max_batch, max_seq, ...]`` per layer), a position
  counter, and per-request sampling state (temperature, pending token).
* Requests are admitted into free slots at step boundaries via per-slot
  prefill-into-cache: a batch-1 prefill (chunk-padded to a ``q_chunk``
  multiple) produces a cache already padded to ``max_seq``, which a single
  fixed-shape scatter writes into the slot's lane.
* ``step()`` runs ONE fused decode for all slots at the fixed shape
  ``[max_batch, 1]`` with an active-slot mask on the host side; finished
  sequences free their slot mid-decode so the scheduler can admit queued
  work without waiting for the rest of the batch.

All jitted functions therefore run at fixed shapes — decode, sampling and
slot-insert compile exactly once per engine config; prefill compiles once
per ``q_chunk`` bucket. ``trace_counts`` exposes the per-function trace
counters so tests and benchmarks can assert compile stability.

Decode budgets are per-slot: each request may emit up to
``min(max_new_tokens, max_seq - prompt_len)`` tokens — a short prompt in a
mixed batch is no longer clamped by the longest prompt (the old
static-batch bug), nor stretched to the batch-max ``max_new_tokens``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import ByteTokenizer
from repro.models.api import Model, build_model
from repro.models.pdefs import is_pdef


@dataclass
class GenStats:
    prompt_tokens: int
    new_tokens: int
    prefill_s: float
    decode_s: float

    @property
    def tokens_per_s(self) -> float:
        return self.new_tokens / self.decode_s if self.decode_s > 0 else 0.0


@dataclass
class Request:
    prompt: str
    max_new_tokens: int = 32
    temperature: float = 0.0     # 0 = greedy


@dataclass
class EngineCompletion:
    """Per-request result carried out of the slot pool."""
    req_id: int
    request: Request
    text: str
    token_ids: List[int]
    prompt_tokens: int
    new_tokens: int
    time_in_engine_s: float      # admit -> finish (prefill + resident decode)


@dataclass
class _Slot:
    req_id: int
    request: Request
    budget: int                  # per-slot decode budget (satellite fix)
    prompt_tokens: int
    pending: int                 # sampled, not yet emitted/fed token
    admitted_at: float
    out_ids: List[int] = field(default_factory=list)


class ServingEngine:
    """One model instance serving a continuously-batched slot pool."""

    def __init__(self, cfg: ModelConfig, *, max_seq: int = 512,
                 max_batch: int = 8, seed: int = 0,
                 params=None):
        self.cfg = cfg
        self.max_seq = max_seq
        self.max_batch = max_batch
        self.tok = ByteTokenizer()
        assert cfg.vocab >= self.tok.vocab_size, "vocab must cover bytes"
        self.model = build_model(cfg, max_seq=max_seq)
        self.params = params if params is not None else self.model.init(
            jax.random.PRNGKey(seed))
        self._key = jax.random.PRNGKey(seed + 1)

        # ---- persistent KV-cache pool: one lane per slot ------------------
        pool_defs = self.model.cache_defs(max_batch)
        self._batch_ax = jax.tree_util.tree_map(
            lambda d: d.axes.index("batch"), pool_defs, is_leaf=is_pdef)
        self._cache = jax.tree_util.tree_map(
            lambda d: jnp.zeros(d.shape, d.dtype), pool_defs, is_leaf=is_pdef)

        # ---- host-side slot state -----------------------------------------
        self._slots: List[Optional[_Slot]] = [None] * max_batch
        self._tokens = np.full(max_batch, self.tok.pad_id, np.int32)
        self._positions = np.zeros(max_batch, np.int32)
        self._temps = np.zeros(max_batch, np.float32)
        self._next_req_id = 0
        self.prefill_s = 0.0      # cumulative engine-lifetime timers
        self.decode_s = 0.0

        # ---- fixed-shape jitted functions with trace instrumentation ------
        # the counters increment only when JAX (re)traces a function, so a
        # stable engine shows exactly one decode/sample/insert trace no
        # matter how many streams of differing batch mix it serves.
        self.trace_counts: Dict[str, int] = {
            "prefill": 0, "decode": 0, "sample": 0, "insert": 0}

        def _prefill_fn(params, tokens, lengths):
            self.trace_counts["prefill"] += 1
            return self.model.prefill(params, tokens, None, lengths)

        def _decode_fn(params, cache, tokens1, positions):
            self.trace_counts["decode"] += 1
            return self.model.decode_step(params, cache, tokens1, positions)

        def _sample_fn(logits, temps, key):
            self.trace_counts["sample"] += 1
            greedy = jnp.argmax(logits, -1).astype(jnp.int32)
            t = jnp.maximum(temps, 1e-4)[:, None]
            sampled = jax.random.categorical(key, logits / t, axis=-1)
            return jnp.where(temps > 0, sampled.astype(jnp.int32), greedy)

        def _insert_fn(pool, one, slot):
            self.trace_counts["insert"] += 1

            def put(big, small, ax):
                big_m = jnp.moveaxis(big, ax, 0)
                row = jnp.moveaxis(small, ax, 0)[0].astype(big_m.dtype)
                big_m = jax.lax.dynamic_update_index_in_dim(
                    big_m, row, slot, 0)
                return jnp.moveaxis(big_m, 0, ax)

            return jax.tree_util.tree_map(put, pool, one, self._batch_ax)

        # donate the cache pool through decode/insert so XLA updates it in
        # place instead of copying [layers, max_batch, max_seq, ...] per
        # token (CPU doesn't implement donation and would warn)
        donate = jax.default_backend() != "cpu"
        self._prefill = jax.jit(_prefill_fn)
        self._decode = jax.jit(_decode_fn,
                               donate_argnums=(1,) if donate else ())
        self._sample = jax.jit(_sample_fn)
        self._insert = jax.jit(_insert_fn,
                               donate_argnums=(0,) if donate else ())

    # ------------------------------------------------------------------
    # Slot-pool introspection
    # ------------------------------------------------------------------
    @property
    def free_slots(self) -> int:
        return sum(s is None for s in self._slots)

    @property
    def active_slots(self) -> int:
        return self.max_batch - self.free_slots

    @property
    def has_active(self) -> bool:
        return any(s is not None for s in self._slots)

    @property
    def decode_traces(self) -> int:
        return self.trace_counts["decode"]

    # ------------------------------------------------------------------
    # Continuous-batching API: admit / step
    # ------------------------------------------------------------------
    def admit(self, request: Request) -> int:
        """Prefill one request into a free slot's cache lane. Returns the
        engine-local request id used in :class:`EngineCompletion`."""
        slot = next((i for i, s in enumerate(self._slots) if s is None), None)
        if slot is None:
            raise RuntimeError("no free slot; check free_slots before admit")
        enc = self.tok.encode(request.prompt)[: self.max_seq - 1]
        L = len(enc)
        budget = max(0, min(request.max_new_tokens, self.max_seq - L))
        qc = max(self.cfg.q_chunk, 1)
        pad_len = min(-(-L // qc) * qc, self.max_seq)
        tokens, lengths = self.tok.pad_batch([enc], pad_len)

        t0 = time.perf_counter()
        logits, lane = self._prefill(self.params, jnp.asarray(tokens),
                                     jnp.asarray(lengths))
        self._cache = self._insert(self._cache, lane, np.int32(slot))
        self._key, sub = jax.random.split(self._key)
        first = self._sample(logits,
                             jnp.asarray([request.temperature], jnp.float32),
                             sub)
        pending = int(jax.block_until_ready(first)[0])
        self.prefill_s += time.perf_counter() - t0

        rid = self._next_req_id
        self._next_req_id += 1
        self._slots[slot] = _Slot(rid, request, budget, L, pending,
                                  admitted_at=time.perf_counter())
        self._tokens[slot] = pending
        self._positions[slot] = L
        self._temps[slot] = request.temperature
        return rid

    def step(self) -> List[EngineCompletion]:
        """One pump of the pool: harvest pending tokens (retiring finished
        sequences, freeing their slots), then run ONE fixed-shape decode
        for whatever remains active."""
        done: List[EngineCompletion] = []
        now = time.perf_counter()
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            finished = (s.pending == self.tok.eos_id
                        or len(s.out_ids) >= s.budget)
            if not finished:
                s.out_ids.append(s.pending)
                finished = len(s.out_ids) >= s.budget
            if finished:
                done.append(EngineCompletion(
                    s.req_id, s.request, self.tok.decode(s.out_ids),
                    s.out_ids, s.prompt_tokens, len(s.out_ids),
                    time_in_engine_s=max(now - s.admitted_at, 0.0)))
                self._free(i)

        if self.has_active:
            t0 = time.perf_counter()
            logits, self._cache = self._decode(
                self.params, self._cache,
                jnp.asarray(self._tokens)[:, None],
                jnp.asarray(self._positions))
            self._key, sub = jax.random.split(self._key)
            nxt = np.asarray(jax.block_until_ready(
                self._sample(logits, jnp.asarray(self._temps), sub)))
            self.decode_s += time.perf_counter() - t0
            for i, s in enumerate(self._slots):
                if s is None:
                    continue
                s.pending = int(nxt[i])
                self._tokens[i] = s.pending
                self._positions[i] += 1
        return done

    def _free(self, slot: int) -> None:
        self._slots[slot] = None
        self._tokens[slot] = self.tok.pad_id
        self._positions[slot] = 0     # inactive lanes park at position 0
        self._temps[slot] = 0.0

    # ------------------------------------------------------------------
    # Batch conveniences on top of the pool
    # ------------------------------------------------------------------
    def generate(self, requests: Sequence[Request]
                 ) -> Tuple[List[str], GenStats]:
        """Continuously-batched generation: requests are admitted as slots
        free up, so any number of requests stream through ``max_batch``
        lanes. Output order matches input order."""
        return self._pump_all(requests, continuous=True)

    def generate_static(self, requests: Sequence[Request]
                        ) -> Tuple[List[str], GenStats]:
        """Static-batch baseline: admit one batch (<= max_batch), then block
        until EVERY sequence finishes — no mid-decode admission. Kept for
        benchmarking and equivalence testing against the continuous path."""
        assert 0 < len(requests) <= self.max_batch
        return self._pump_all(requests, continuous=False)

    def _pump_all(self, requests: Sequence[Request], *, continuous: bool
                  ) -> Tuple[List[str], GenStats]:
        assert not self.has_active, "engine already has resident requests"
        p0, d0 = self.prefill_s, self.decode_s
        queue = list(requests)
        rid_to_idx: Dict[int, int] = {}
        comps: Dict[int, EngineCompletion] = {}
        if not continuous:                      # one up-front batch, no more
            for i, r in enumerate(queue):
                rid_to_idx[self.admit(r)] = i
            queue = []
        while queue or self.has_active:
            while continuous and queue and self.free_slots:
                req = queue.pop(0)
                rid_to_idx[self.admit(req)] = len(requests) - len(queue) - 1
            for ec in self.step():
                comps[rid_to_idx[ec.req_id]] = ec
        ordered = [comps[i] for i in range(len(requests))]
        stats = GenStats(
            prompt_tokens=sum(c.prompt_tokens for c in ordered),
            new_tokens=sum(c.new_tokens for c in ordered),
            prefill_s=self.prefill_s - p0, decode_s=self.decode_s - d0)
        return [c.text for c in ordered], stats

    # ------------------------------------------------------------------
    def warmup(self, prompt_lens: Iterable[int] = (1,)) -> None:
        """Pre-compile every fixed-shape function (decode, sample, insert)
        and the prefill bucket for each given prompt length, leaving the
        pool idle. Lets benchmarks separate compile from serve time."""
        assert not self.has_active
        qc = max(self.cfg.q_chunk, 1)
        buckets = sorted({min(-(-max(n, 1) // qc) * qc, self.max_seq)
                          for n in prompt_lens})
        key = jax.random.PRNGKey(0)
        # rebind the pool at every call: the cache argument is donated, so
        # the old buffer is dead after each decode/insert (pool is idle —
        # lanes are rewritten on admission, scribbles don't matter)
        for pad_len in buckets:
            toks = jnp.zeros((1, pad_len), jnp.int32)
            logits, lane = self._prefill(self.params, toks,
                                         jnp.asarray([pad_len], jnp.int32))
            self._cache = self._insert(self._cache, lane, np.int32(0))
            self._sample(logits, jnp.asarray([0.0], jnp.float32), key)
        _, self._cache = self._decode(self.params, self._cache,
                                      jnp.asarray(self._tokens)[:, None],
                                      jnp.asarray(self._positions))
        self._sample(jnp.zeros((self.max_batch, self.cfg.vocab), jnp.float32),
                     jnp.asarray(self._temps), key)


def make_edge_engine(*, max_seq: int = 512, max_batch: int = 8,
                     seed: int = 0) -> ServingEngine:
    """Default edge SLM: reduced qwen2-0.5b (byte vocab capable)."""
    from repro.configs import get_config
    cfg = get_config("qwen2-0.5b", reduced=True)
    return ServingEngine(cfg, max_seq=max_seq, max_batch=max_batch, seed=seed)


__all__ = ["ServingEngine", "Request", "GenStats", "EngineCompletion",
           "make_edge_engine"]
