"""Request scheduler: arrival queues -> continuous slot-pool admission,
with SLO-aware preemption, load shedding, and stuck-work timeouts.

Per-tier priority heaps (edge engines + cloud engine) feed the engines'
slot pools. ``pump()`` runs one scheduling round: for every tier it admits
queued requests into whatever slots just freed, then advances that tier's
engines by one fused decode step each, harvesting per-request completions
mid-stream. The gate decides the tier; the scheduler keeps the lanes full.

A tier may be backed by a POOL of engines (``{"edge": [e0, e1], "cloud":
e2}``): the tier shares one queue and the head request is admitted into the
first pool member with a free slot (and, paged, enough pages).

**Queue order** is ``(SLO rank, deadline, arrival seq)``: every
``interactive`` request sorts ahead of every ``batch`` request, and within
a class the earliest deadline wins. If the head doesn't fit on ANY pool
member, later requests wait behind it rather than jumping the queue, so a
big request can't be starved by a stream of small ones.

**The overload state machine** (every transition is a typed outcome,
never a silent drop)::

    submit ──fits no pool member──────────────────────> SchedulerError
    submit ──batch + saturation >= overload_watermark──> Shed("overload")
    queued ──shed_overdue and deadline <= now──────────> Shed("deadline")
    queued ──head outranks a resident, no slot anywhere─> resident PREEMPTED
                 (engine snapshot -> re-enqueued -> resumes via prefix
                  cache, greedy token-identical)
    resident ──no engine progress for request_timeout_s─> Shed("timeout")
    resident ──finished────────────────────────────────> Completion

- *Preemption* (``preempt=True``, the default): when the head cannot be
  admitted anywhere, the WORST resident of the same tier — largest
  ``(rank, deadline)`` — is reclaimed iff it is STRICTLY lower priority
  than the head (so uniform-priority workloads never preempt and behave
  exactly as before). The engine returns a resumable snapshot; the victim
  re-enters the queue carrying its emitted tokens and resumes as a new
  admission of ``prompt_ids = enc + emitted``, hitting the prefix cache on
  its original prompt pages. Greedy resume is token-identical.
- *Shedding* (``shed_overdue=True``; off by default because wall-clock
  callers submit with sentinel deadlines): queued requests whose hard
  deadline has already passed are dropped as ``Shed("deadline")`` before
  admission — capacity goes to requests that can still meet their SLO.
- *Timeouts* (``request_timeout_s``): a resident whose engine has made no
  scheduling progress for that long (e.g. a stalled engine, see the
  ``stalled`` hook on :meth:`pump`) is preempted off the engine — freeing
  its slot and pages — and emitted as ``Shed("timeout")``; a cluster layer
  may then fail it over to another tier.
- *Admission-time overload shed* (``overload_watermark``): batch-class
  submissions are shed immediately when the tier's saturation (queued +
  resident over total slot capacity) is at/above the watermark;
  interactive submissions always enqueue.

Every terminal outcome is counted (``counters``) and conservation —
``submitted == completed + shed + timed_out + overload_shed + queued +
resident`` — is checkable at any time via :meth:`conservation_ok`, so work
can never vanish. ``drain()`` detects wedges (no admission, step, shed, or
preemption progress while work remains) and raises :class:`SchedulerError`
instead of spinning forever.

All timings run on an injectable ``clock`` (any zero-arg callable returning
seconds; default ``time.perf_counter``). ``submit(now=...)`` and
``pump(now=...)`` override the clock per call, so a simulator driving the
scheduler with logical event time gets exact logical queue waits and
service times — never a mix of event time and wall time.
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field
from typing import (
    Callable, Dict, List, Optional, Sequence, Tuple, Union,
)

from repro.serving.engine import Request, ServingEngine

# lower rank = higher priority; unknown classes schedule as batch
SLO_RANK: Dict[str, int] = {"interactive": 0, "batch": 1}


class SchedulerError(RuntimeError):
    """Caller-facing scheduler invariant violation: a request that can
    never fit any pool member of its tier (rejected at ``submit`` so the
    deadline-ordered queue can't wedge behind it), or a drain that stopped
    making progress. A real exception — survives ``python -O``."""


def _rank(request: Request) -> int:
    return SLO_RANK.get(request.slo, SLO_RANK["batch"])


@dataclass(order=True)
class _Item:
    rank: int                    # SLO class rank (compare key 1)
    deadline: float              # hard deadline, scheduler clock (key 2)
    seq: int                     # arrival tiebreak (key 3)
    request: Request = field(compare=False)
    tier: str = field(compare=False, default="edge")
    enqueued_at: float = field(compare=False, default=0.0)
    admitted_at: float = field(compare=False, default=0.0)
    queue_wait_s: float = field(compare=False, default=0.0)   # accumulated
    resident_s: float = field(compare=False, default=0.0)     # accumulated
    # ---- preemption/resume state --------------------------------------
    run_request: Optional[Request] = field(compare=False, default=None)
    enc: Optional[List[int]] = field(compare=False, default=None)
    emitted: List[int] = field(compare=False, default_factory=list)
    preemptions: int = field(compare=False, default=0)
    last_progress_at: float = field(compare=False, default=0.0)


@dataclass
class Completion:
    request: Request
    text: str
    tier: str
    queue_wait_s: float          # submit -> slot admission (scheduler clock)
    time_in_engine_s: float      # resident time, summed across preemptions
    prompt_tokens: int = 0
    new_tokens: int = 0
    engine_index: int = 0        # which pool member finished it
    engine_wall_s: float = 0.0   # engine-measured wall time (last residency)
    slo: str = "batch"
    preemptions: int = 0         # times this request was preempted


@dataclass
class Shed:
    """Typed terminal outcome for work the scheduler gave up on — the
    request was NOT served and the caller must decide (fail over to
    another tier, return an error upstream, ...). Never a silent drop:
    every Shed is counted and queued on :meth:`TierScheduler.pop_sheds`."""
    request: Request
    tier: str
    reason: str                  # "deadline" | "timeout" | "overload"
    t: float                     # scheduler-clock time of the shed
    slo: str = "batch"
    queue_wait_s: float = 0.0
    emitted_tokens: int = 0      # tokens generated before a timeout shed
    preemptions: int = 0


_SHED_COUNTER = {"deadline": "shed", "timeout": "timed_out",
                 "overload": "overload_shed"}


class TierScheduler:
    """SLO- and deadline-ordered continuous scheduler over named
    engine-pool tiers, with preemption / shedding / timeouts (see module
    docstring for the full state machine).

    Defaults preserve pre-overload behavior exactly: ``preempt=True``
    never fires under a uniform SLO class with monotone deadlines (it
    requires STRICT priority dominance), and shedding / timeouts /
    watermarks are opt-in.
    """

    def __init__(self, engines: Dict[str, Union[ServingEngine,
                                                Sequence[ServingEngine]]],
                 clock: Optional[Callable[[], float]] = None, *,
                 preempt: bool = True,
                 shed_overdue: bool = False,
                 request_timeout_s: Optional[float] = None,
                 overload_watermark: Optional[float] = None):
        self.pools: Dict[str, List[ServingEngine]] = {}
        for tier, pool in engines.items():
            members = list(pool) if isinstance(pool, (list, tuple)) else [pool]
            if not members:
                raise ValueError(f"tier {tier!r} has an empty engine pool")
            self.pools[tier] = members
        self.engines = engines
        self.clock: Callable[[], float] = (time.perf_counter
                                           if clock is None else clock)
        self.preempt = preempt
        self.shed_overdue = shed_overdue
        self.request_timeout_s = request_timeout_s
        self.overload_watermark = overload_watermark
        self._queues: Dict[str, List[_Item]] = {t: [] for t in self.pools}
        self._inflight: Dict[Tuple[str, int, int], _Item] = {}
        self._seq = itertools.count()
        self.counters: Dict[str, int] = {
            "submitted": 0, "completed": 0, "shed": 0, "timed_out": 0,
            "overload_shed": 0, "preempted": 0, "resumed": 0}
        self.sheds: List[Shed] = []

    # ------------------------------------------------------------------
    # Introspection / accounting
    # ------------------------------------------------------------------
    def pending(self, tier: Optional[str] = None) -> int:
        """Queued requests not yet admitted into a slot."""
        if tier:
            return len(self._queues[tier])
        return sum(len(q) for q in self._queues.values())

    def in_flight(self, tier: Optional[str] = None) -> int:
        """Requests resident in an engine slot, still decoding."""
        if tier:
            return sum(t == tier for t, _, _ in self._inflight)
        return len(self._inflight)

    def capacity(self, tier: str) -> int:
        """Total slot capacity of a tier's pool."""
        return sum(e.max_batch for e in self.pools[tier])

    def saturation(self, tier: str) -> float:
        """Outstanding work over slot capacity: ``(queued + resident) /
        capacity``. >= 1.0 means every slot is full AND work is queued —
        the overload watermark and cluster failover key off this."""
        return (self.pending(tier) + self.in_flight(tier)) / max(
            self.capacity(tier), 1)

    @property
    def shed_total(self) -> int:
        return (self.counters["shed"] + self.counters["timed_out"]
                + self.counters["overload_shed"])

    def conservation_ok(self) -> bool:
        """Every submitted request is accounted for: completed, shed (any
        reason), still queued, or resident. The invariant future PRs must
        not break — work never silently vanishes."""
        return self.counters["submitted"] == (
            self.counters["completed"] + self.shed_total
            + self.pending() + self.in_flight())

    def pop_sheds(self) -> List[Shed]:
        """Drain the typed shed outcomes accumulated since the last call
        (callers that fail work over to another tier consume these)."""
        out, self.sheds = self.sheds, []
        return out

    # ------------------------------------------------------------------
    # Submission
    # ------------------------------------------------------------------
    def submit(self, request: Request, tier: str,
               deadline_s: float = 1e9, now: Optional[float] = None) -> None:
        """Enqueue a request on a tier.

        Raises :class:`SchedulerError` when no pool member could EVER
        admit the request (prompt too long for every engine's ``max_seq``)
        — without this, the deadline-ordered queue would wedge behind an
        inadmissible head and ``drain()`` would spin forever. Batch-class
        requests are shed immediately (``Shed("overload")``) when the
        tier's saturation is at/above ``overload_watermark``."""
        if tier not in self._queues:
            raise KeyError(f"unknown tier {tier!r}")
        if not any(e.fits(request) for e in self.pools[tier]):
            raise SchedulerError(
                f"request can never be admitted on tier {tier!r}: prompt "
                f"exceeds every pool member's max_seq "
                f"({[e.max_seq for e in self.pools[tier]]})")
        now = self.clock() if now is None else now
        self.counters["submitted"] += 1
        item = _Item(_rank(request), deadline_s, next(self._seq), request,
                     tier, enqueued_at=now, last_progress_at=now)
        if (self.overload_watermark is not None
                and item.rank >= SLO_RANK["batch"]
                and self.saturation(tier) >= self.overload_watermark):
            self._record_shed(item, "overload", now)
            return
        heapq.heappush(self._queues[tier], item)

    # ------------------------------------------------------------------
    # The pump
    # ------------------------------------------------------------------
    def pump(self, now: Optional[float] = None,
             stalled: Optional[Callable[[str, int], bool]] = None
             ) -> List[Completion]:
        """One scheduling round across every tier: shed overdue queued
        work, time out stuck residents, fill free slots from the priority
        heap (preempting strictly-lower-priority residents for a head that
        fits nowhere), advance each engine one decode step, and return the
        requests that finished this round.

        Admission asks the engines via ``can_admit`` — a free slot AND,
        for a paged KV-cache, enough free pages for the request's prompt +
        decode budget. Admission stays strictly priority-ordered within a
        tier (see module docstring for the queue key).

        ``now`` pins the whole round to one logical timestamp
        (simulators); without it the injected clock is read as events
        happen, so wall-mode completions still include the round's
        measured compute. ``stalled(tier, engine_index) -> bool`` marks
        pool members the fault layer has frozen: they are skipped for
        admission and stepping this round, their residents accrue no
        progress, and — with ``request_timeout_s`` — eventually time out
        and free their slots."""
        t_round = self.clock() if now is None else now
        out: List[Completion] = []
        for tier, pool in self.pools.items():
            q = self._queues[tier]

            def is_stalled(i: int, _tier: str = tier) -> bool:
                return stalled is not None and bool(stalled(_tier, i))

            if self.shed_overdue:
                self._shed_overdue_queued(q, t_round)
            if self.request_timeout_s is not None:
                self._timeout_stuck(tier, pool, t_round)
            while q:
                head = q[0]
                run_req = self._run_request(head)
                eng_i = next(
                    (i for i, e in enumerate(pool)
                     if not is_stalled(i) and e.can_admit(run_req)), None)
                if eng_i is None:
                    if self.preempt and self._preempt_for(tier, pool, head,
                                                          t_round):
                        continue      # a slot/pages just freed; retry head
                    break
                item = heapq.heappop(q)
                item.queue_wait_s += max(t_round - item.enqueued_at, 0.0)
                item.admitted_at = t_round
                item.last_progress_at = t_round
                rid = pool[eng_i].admit(run_req)
                if item.emitted or item.preemptions:
                    self.counters["resumed"] += 1
                self._inflight[(tier, eng_i, rid)] = item
            for eng_i, eng in enumerate(pool):
                if is_stalled(eng_i) or not eng.has_active:
                    continue
                for ec in eng.step():
                    item = self._inflight.pop((tier, eng_i, ec.req_id))
                    t_done = self.clock() if now is None else now
                    ids = item.emitted + ec.token_ids
                    self.counters["completed"] += 1
                    out.append(Completion(
                        request=item.request,
                        text=eng.tok.decode(ids), tier=tier,
                        queue_wait_s=item.queue_wait_s,
                        time_in_engine_s=item.resident_s
                        + max(t_done - item.admitted_at, 0.0),
                        prompt_tokens=(len(item.enc) if item.enc is not None
                                       else ec.prompt_tokens),
                        new_tokens=len(ids),
                        engine_index=eng_i,
                        engine_wall_s=ec.time_in_engine_s,
                        slo=item.request.slo,
                        preemptions=item.preemptions))
                # residents on an engine that just stepped made progress
                for key, it in self._inflight.items():
                    if key[0] == tier and key[1] == eng_i:
                        it.last_progress_at = t_round
        return out

    # one pump used to serve a whole batch; keep the name as an alias for
    # callers that just want "advance the scheduler"
    step = pump

    def drain(self) -> List[Completion]:
        """Pump until no work remains. Raises :class:`SchedulerError` if a
        round makes NO progress (no admission, decode step, completion,
        shed, or preemption) while work is still outstanding — a wedged
        scheduler fails loudly instead of spinning forever."""
        out: List[Completion] = []
        while self.pending() or self.in_flight():
            before = self._progress_fingerprint()
            out.extend(self.pump())
            if (self._progress_fingerprint() == before
                    and (self.pending() or self.in_flight())):
                raise SchedulerError(
                    f"scheduler wedged: {self.pending()} queued, "
                    f"{self.in_flight()} resident, and a full pump made no "
                    "progress (no admission, step, completion, shed, or "
                    "preemption)")
        return out

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------
    def _progress_fingerprint(self) -> tuple:
        work = sum(e.prefill_tokens + e.decode_rounds
                   for pool in self.pools.values() for e in pool)
        return (self.pending(), self.in_flight(), work,
                tuple(self.counters.values()))

    def _run_request(self, item: _Item) -> Request:
        """The request actually handed to engines: the original on first
        admission, the resume request (``prompt_ids = enc + emitted``)
        after a preemption. Kept on the item so engine plan memos stay
        effective across ``can_admit`` probes."""
        if item.run_request is None:
            item.run_request = item.request
        return item.run_request

    def _record_shed(self, item: _Item, reason: str, now: float,
                     queued: bool = True) -> None:
        self.counters[_SHED_COUNTER[reason]] += 1
        wait = item.queue_wait_s
        if queued:
            wait += max(now - item.enqueued_at, 0.0)
        self.sheds.append(Shed(
            request=item.request, tier=item.tier, reason=reason, t=now,
            slo=item.request.slo, queue_wait_s=wait,
            emitted_tokens=len(item.emitted),
            preemptions=item.preemptions))

    def _shed_overdue_queued(self, q: List[_Item], now: float) -> None:
        """Drop queued items whose hard deadline already passed — they can
        no longer meet their SLO, so capacity goes to ones that can. Only
        QUEUED work sheds on deadline; residents hold reserved pages and
        finishing them is cheaper than wasting the work (they time out via
        ``request_timeout_s`` if truly stuck)."""
        if not any(it.deadline <= now for it in q):
            return
        keep = [it for it in q if it.deadline > now]
        dead = [it for it in q if it.deadline <= now]
        q[:] = keep
        heapq.heapify(q)
        for it in dead:
            self._record_shed(it, "deadline", now)

    def _timeout_stuck(self, tier: str, pool: List[ServingEngine],
                       now: float) -> None:
        """Reclaim residents whose engine made no progress for
        ``request_timeout_s`` (stalled engine / wedged decode): preempt
        them off the engine — host-side bookkeeping that works even when
        the engine itself is frozen — and emit ``Shed("timeout")``."""
        for key in [k for k in self._inflight if k[0] == tier]:
            it = self._inflight[key]
            if now - it.last_progress_at <= self.request_timeout_s:
                continue
            _, eng_i, rid = key
            snap = pool[eng_i].preempt(rid)
            del self._inflight[key]
            it.resident_s += max(now - it.admitted_at, 0.0)
            it.emitted.extend(snap.emitted_ids)
            self._record_shed(it, "timeout", now, queued=False)

    def _preempt_for(self, tier: str, pool: List[ServingEngine],
                     head: _Item, now: float) -> bool:
        """Reclaim a slot for a queued head that fits nowhere: pick the
        WORST resident of the tier — largest ``(rank, deadline)`` — and
        preempt it iff it is STRICTLY lower priority than the head.
        The victim's snapshot (emitted tokens) folds into its item and it
        re-enters the queue; its next admission resumes via the prefix
        cache (original prompt pages are still indexed) and recomputes
        only the generated suffix, token-identical under greedy decode.
        Returns True when a victim was reclaimed (the caller retries
        admission), False when nobody is strictly below the head."""
        head_key = (head.rank, head.deadline)
        worst_key: Optional[Tuple[int, float]] = None
        worst: Optional[Tuple[Tuple[str, int, int], _Item]] = None
        for key, it in self._inflight.items():
            if key[0] != tier:
                continue
            k = (it.rank, it.deadline)
            if k <= head_key:
                continue
            if worst_key is None or k > worst_key:
                worst_key, worst = k, (key, it)
        if worst is None:
            return False
        (_, eng_i, rid), it = worst
        snap = pool[eng_i].preempt(rid)
        del self._inflight[(tier, eng_i, rid)]
        if it.enc is None:
            it.enc = list(snap.prompt_ids)    # original prompt encoding
        it.emitted.extend(snap.emitted_ids)
        it.preemptions += 1
        it.resident_s += max(now - it.admitted_at, 0.0)
        it.enqueued_at = now
        it.last_progress_at = now
        it.run_request = Request(
            prompt=it.request.prompt,
            prompt_ids=it.enc + it.emitted,
            max_new_tokens=it.request.max_new_tokens - len(it.emitted),
            temperature=it.request.temperature,
            slo=it.request.slo)
        heapq.heappush(self._queues[tier], it)
        self.counters["preempted"] += 1
        return True


__all__ = ["TierScheduler", "Completion", "Shed", "SchedulerError",
           "SLO_RANK"]
