"""Request scheduler: arrival queues -> continuous slot-pool admission,
with SLO-aware preemption, load shedding, and stuck-work timeouts.

Per-tier priority heaps (edge engines + cloud engine) feed the engines'
slot pools. ``pump()`` runs one scheduling round: for every tier it admits
queued requests into whatever slots just freed, harvests per-request
completions, and *dispatches* each engine's next step — one fused decode,
or (engines built with ``step_token_budget``) one fused chunked-prefill +
decode step whose budget split the engine steers by SLO rank (interactive
first-token work ahead of batch). Dispatch is asynchronous: the pump
enqueues every engine's step and only blocks at the very end of the round
(``collect``), so host-side scheduling overlaps device compute. The gate
decides the tier; the scheduler keeps the lanes full.

A tier may be backed by a POOL of engines (``{"edge": [e0, e1], "cloud":
e2}``): the tier shares one queue and the head request is admitted into the
first pool member with a free slot (and, paged, enough pages).

**Queue order** is ``(SLO rank, deadline, arrival seq)``: every
``interactive`` request sorts ahead of every ``batch`` request, and within
a class the earliest deadline wins. If the head doesn't fit on ANY pool
member, later requests wait behind it rather than jumping the queue, so a
big request can't be starved by a stream of small ones.

**The overload + hard-failure state machine** (every transition is a typed
outcome, never a silent drop)::

    submit ──fits no pool member──────────────────────> SchedulerError
    submit ──batch + saturation >= overload_watermark──> Shed("overload")
    queued ──shed_overdue and deadline <= now──────────> Shed("deadline")
    queued ──head outranks a resident, no slot anywhere─> resident PREEMPTED
                 (engine snapshot -> re-enqueued -> resumes via prefix
                  cache, greedy token-identical)
    resident ──no engine progress for request_timeout_s─> Shed("timeout")
    resident ──engine crashed / restarted under it──────> REAPED: re-enqueued
                 from its original prompt (requeue_lost=True, default) or
                 emitted as Shed("engine_lost") for the caller's failover
    resident ──finished────────────────────────────────> Completion

- *Preemption* (``preempt=True``, the default): when the head cannot be
  admitted anywhere, the WORST resident of the same tier — largest
  ``(rank, deadline)`` — is reclaimed iff it is STRICTLY lower priority
  than the head (so uniform-priority workloads never preempt and behave
  exactly as before). The engine returns a resumable snapshot; the victim
  re-enters the queue carrying its emitted tokens and resumes as a new
  admission of ``prompt_ids = enc + emitted``, hitting the prefix cache on
  its original prompt pages. Greedy resume is token-identical.
- *Shedding* (``shed_overdue=True``; off by default because wall-clock
  callers submit with sentinel deadlines): queued requests whose hard
  deadline has already passed are dropped as ``Shed("deadline")`` before
  admission — capacity goes to requests that can still meet their SLO.
- *Timeouts* (``request_timeout_s``): a resident whose engine has made no
  scheduling progress for that long (e.g. a stalled engine, see the
  ``stalled`` hook on :meth:`pump`) is preempted off the engine — freeing
  its slot and pages — and emitted as ``Shed("timeout")``; a cluster layer
  may then fail it over to another tier.
- *Admission-time overload shed* (``overload_watermark``): batch-class
  submissions are shed immediately when the tier's saturation (queued +
  resident over total slot capacity) is at/above the watermark;
  interactive submissions always enqueue.
- *Engine-loss reaping*: every resident records the ``engine_generation``
  it was admitted under. At the top of each pump, residents whose engine
  is dead — or restarted since admission (generation mismatch) — are
  reaped: their in-engine tokens died with the device state, but tokens
  banked by an EARLIER preemption (already in ``item.emitted``) survive
  in the control plane. With ``requeue_lost=True`` the reaped item
  re-enters the queue through the same resume path preemption uses (the
  restarted engine's prefix cache is cold, so the whole prompt reruns —
  still token-identical under greedy decode); with ``requeue_lost=False``
  it is emitted as ``Shed("engine_lost")`` so a cluster layer can apply
  its own failover policy (backoff, tier escalation).
- *Circuit breakers* (``breaker_threshold``): each pool member gets a
  :class:`~repro.serving.health.CircuitBreaker`. Reaped residents and
  stuck-resident timeouts count as failures against the engine they were
  on; completions count as successes. An engine whose breaker won't
  ``allow()`` is skipped at admission exactly like a stalled one — so a
  flaky node stops receiving fresh work until a half-open probe (one
  request, marked via ``begin_probe``) proves it healthy.
- *Hedging* (``hedge_s``): an interactive request still unfinished
  ``hedge_s`` after submission to ``hedge_from`` fires ONE backup
  submission of the same prompt on ``hedge_to``; first completion wins
  and the loser is cancelled (removed from its queue, or preempted off
  its engine with the snapshot discarded). The pair shares one logical
  request: the winner's :class:`Completion` always carries the PRIMARY
  ``Request`` object so callers can join on identity, and the losing leg
  retires as ``cancelled`` — never a Shed, never a second completion.
  ``hedge_gate`` (a ``now -> bool`` callable) can veto hedge firing, e.g.
  while the edge<->cloud link is partitioned.

Every terminal outcome is counted (``counters``) and hedge-aware
conservation — ``submitted + hedged == completed + shed_total + cancelled
+ queued + resident`` — is checkable at any time via
:meth:`conservation_ok`, so work can never vanish. ``drain()`` detects
wedges (no admission, step, shed, or preemption progress while work
remains) and raises :class:`SchedulerError` carrying a full
:meth:`debug_state` dump — queue depths, per-engine residents, breaker
states — instead of spinning forever.

All timings run on an injectable ``clock`` (any zero-arg callable returning
seconds; default ``time.perf_counter``). ``submit(now=...)`` and
``pump(now=...)`` override the clock per call, so a simulator driving the
scheduler with logical event time gets exact logical queue waits and
service times — never a mix of event time and wall time.
"""
from __future__ import annotations

import heapq
import itertools
import json
import time
from dataclasses import dataclass, field
from typing import (
    Callable, Dict, List, Optional, Sequence, Tuple, Union,
)

from repro.serving.engine import Request, ServingEngine
from repro.serving.health import CircuitBreaker

# lower rank = higher priority; unknown classes schedule as batch
SLO_RANK: Dict[str, int] = {"interactive": 0, "batch": 1}


class SchedulerError(RuntimeError):
    """Caller-facing scheduler invariant violation: a request that can
    never fit any pool member of its tier (rejected at ``submit`` so the
    deadline-ordered queue can't wedge behind it), or a drain that stopped
    making progress. A real exception — survives ``python -O``."""


def _rank(request: Request) -> int:
    return SLO_RANK.get(request.slo, SLO_RANK["batch"])


@dataclass(order=True)
class _Item:
    rank: int                    # SLO class rank (compare key 1)
    deadline: float              # hard deadline, scheduler clock (key 2)
    seq: int                     # arrival tiebreak (key 3)
    request: Request = field(compare=False)
    tier: str = field(compare=False, default="edge")
    enqueued_at: float = field(compare=False, default=0.0)
    admitted_at: float = field(compare=False, default=0.0)
    queue_wait_s: float = field(compare=False, default=0.0)   # accumulated
    resident_s: float = field(compare=False, default=0.0)     # accumulated
    # ---- preemption/resume state --------------------------------------
    run_request: Optional[Request] = field(compare=False, default=None)
    enc: Optional[List[int]] = field(compare=False, default=None)
    emitted: List[int] = field(compare=False, default_factory=list)
    preemptions: int = field(compare=False, default=0)
    last_progress_at: float = field(compare=False, default=0.0)
    # ---- crash-reaping / hedging state --------------------------------
    admit_gen: int = field(compare=False, default=0)   # engine_generation
    #                                                    at admission time
    submitted_at: float = field(compare=False, default=0.0)
    partner: Optional["_Item"] = field(compare=False, default=None)
    is_hedge: bool = field(compare=False, default=False)
    done: bool = field(compare=False, default=False)


@dataclass
class Completion:
    request: Request
    text: str
    tier: str
    queue_wait_s: float          # submit -> slot admission (scheduler clock)
    time_in_engine_s: float      # resident time, summed across preemptions
    prompt_tokens: int = 0
    new_tokens: int = 0
    engine_index: int = 0        # which pool member finished it
    engine_wall_s: float = 0.0   # engine-measured wall time (last residency)
    slo: str = "batch"
    preemptions: int = 0         # times this request was preempted
    hedged: bool = False         # served by the backup (hedge) submission
    ttft_s: float = 0.0          # submit -> first token (scheduler clock):
    #                              queue wait + prior residencies + the
    #                              engine-side first-token delay of the
    #                              final admission (an upper bound for
    #                              preempted-then-resumed requests, whose
    #                              true first token came even earlier)


@dataclass
class Shed:
    """Typed terminal outcome for work the scheduler gave up on — the
    request was NOT served and the caller must decide (fail over to
    another tier, return an error upstream, ...). Never a silent drop:
    every Shed is counted and queued on :meth:`TierScheduler.pop_sheds`."""
    request: Request
    tier: str
    reason: str         # "deadline" | "timeout" | "overload" | "engine_lost"
    t: float                     # scheduler-clock time of the shed
    slo: str = "batch"
    queue_wait_s: float = 0.0
    emitted_tokens: int = 0      # tokens generated before a timeout shed
    preemptions: int = 0


_SHED_COUNTER = {"deadline": "shed", "timeout": "timed_out",
                 "overload": "overload_shed", "engine_lost": "engine_lost"}


class TierScheduler:
    """SLO- and deadline-ordered continuous scheduler over named
    engine-pool tiers, with preemption / shedding / timeouts (see module
    docstring for the full state machine).

    Defaults preserve pre-overload behavior exactly: ``preempt=True``
    never fires under a uniform SLO class with monotone deadlines (it
    requires STRICT priority dominance), and shedding / timeouts /
    watermarks are opt-in.
    """

    def __init__(self, engines: Dict[str, Union[ServingEngine,
                                                Sequence[ServingEngine]]],
                 clock: Optional[Callable[[], float]] = None, *,
                 preempt: bool = True,
                 shed_overdue: bool = False,
                 request_timeout_s: Optional[float] = None,
                 overload_watermark: Optional[float] = None,
                 requeue_lost: bool = True,
                 breaker_threshold: Optional[int] = None,
                 breaker_reset_s: float = 5.0,
                 hedge_s: Optional[float] = None,
                 hedge_from: str = "edge",
                 hedge_to: str = "cloud",
                 hedge_gate: Optional[Callable[[float], bool]] = None):
        self.pools: Dict[str, List[ServingEngine]] = {}
        for tier, pool in engines.items():
            members = list(pool) if isinstance(pool, (list, tuple)) else [pool]
            if not members:
                raise ValueError(f"tier {tier!r} has an empty engine pool")
            self.pools[tier] = members
        self.engines = engines
        self.clock: Callable[[], float] = (time.perf_counter
                                           if clock is None else clock)
        self.preempt = preempt
        self.shed_overdue = shed_overdue
        self.request_timeout_s = request_timeout_s
        self.overload_watermark = overload_watermark
        self.requeue_lost = requeue_lost
        self.hedge_s = hedge_s
        self.hedge_from = hedge_from
        self.hedge_to = hedge_to
        self.hedge_gate = hedge_gate
        self._queues: Dict[str, List[_Item]] = {t: [] for t in self.pools}
        self._inflight: Dict[Tuple[str, int, int], _Item] = {}
        self._seq = itertools.count()
        self.breakers: Dict[Tuple[str, int], CircuitBreaker] = {}
        if breaker_threshold is not None:
            for tier, pool in self.pools.items():
                for i in range(len(pool)):
                    self.breakers[(tier, i)] = CircuitBreaker(
                        breaker_threshold, breaker_reset_s)
        self.counters: Dict[str, int] = {
            "submitted": 0, "completed": 0, "shed": 0, "timed_out": 0,
            "overload_shed": 0, "preempted": 0, "resumed": 0,
            "engine_lost": 0, "requeued_lost": 0, "hedged": 0,
            "cancelled": 0}
        self.sheds: List[Shed] = []

    # ------------------------------------------------------------------
    # Introspection / accounting
    # ------------------------------------------------------------------
    def pending(self, tier: Optional[str] = None) -> int:
        """Queued requests not yet admitted into a slot."""
        if tier:
            return len(self._queues[tier])
        return sum(len(q) for q in self._queues.values())

    def in_flight(self, tier: Optional[str] = None) -> int:
        """Requests resident in an engine slot, still decoding."""
        if tier:
            return sum(t == tier for t, _, _ in self._inflight)
        return len(self._inflight)

    def capacity(self, tier: str) -> int:
        """Total slot capacity of a tier's pool."""
        return sum(e.max_batch for e in self.pools[tier])

    def saturation(self, tier: str) -> float:
        """Outstanding work over slot capacity: ``(queued + resident) /
        capacity``. >= 1.0 means every slot is full AND work is queued —
        the overload watermark and cluster failover key off this."""
        return (self.pending(tier) + self.in_flight(tier)) / max(
            self.capacity(tier), 1)

    @property
    def shed_total(self) -> int:
        return (self.counters["shed"] + self.counters["timed_out"]
                + self.counters["overload_shed"]
                + self.counters["engine_lost"])

    def conservation_ok(self) -> bool:
        """Every submission — original or hedge leg — is accounted for:
        completed, shed (any reason), cancelled (the losing leg of a hedge
        pair), still queued, or resident. The invariant future PRs must
        not break — work never silently vanishes."""
        return self.counters["submitted"] + self.counters["hedged"] == (
            self.counters["completed"] + self.shed_total
            + self.counters["cancelled"]
            + self.pending() + self.in_flight())

    def pop_sheds(self) -> List[Shed]:
        """Drain the typed shed outcomes accumulated since the last call
        (callers that fail work over to another tier consume these)."""
        out, self.sheds = self.sheds, []
        return out

    # ------------------------------------------------------------------
    # Submission
    # ------------------------------------------------------------------
    def submit(self, request: Request, tier: str,
               deadline_s: float = 1e9, now: Optional[float] = None) -> None:
        """Enqueue a request on a tier.

        Raises :class:`SchedulerError` when no pool member could EVER
        admit the request (prompt too long for every engine's ``max_seq``)
        — without this, the deadline-ordered queue would wedge behind an
        inadmissible head and ``drain()`` would spin forever. Batch-class
        requests are shed immediately (``Shed("overload")``) when the
        tier's saturation is at/above ``overload_watermark``."""
        if tier not in self._queues:
            raise KeyError(f"unknown tier {tier!r}")
        if not any(e.fits(request) for e in self.pools[tier]):
            raise SchedulerError(
                f"request can never be admitted on tier {tier!r}: prompt "
                f"exceeds every pool member's max_seq "
                f"({[e.max_seq for e in self.pools[tier]]})")
        now = self.clock() if now is None else now
        self.counters["submitted"] += 1
        item = _Item(_rank(request), deadline_s, next(self._seq), request,
                     tier, enqueued_at=now, last_progress_at=now,
                     submitted_at=now)
        if (self.overload_watermark is not None
                and item.rank >= SLO_RANK["batch"]
                and self.saturation(tier) >= self.overload_watermark):
            self._record_shed(item, "overload", now)
            return
        heapq.heappush(self._queues[tier], item)

    # ------------------------------------------------------------------
    # The pump
    # ------------------------------------------------------------------
    def pump(self, now: Optional[float] = None,
             stalled: Optional[Callable[[str, int], bool]] = None
             ) -> List[Completion]:
        """One scheduling round across every tier: shed overdue queued
        work, time out stuck residents, fill free slots from the priority
        heap (preempting strictly-lower-priority residents for a head that
        fits nowhere), advance each engine one decode step, and return the
        requests that finished this round.

        Admission asks the engines via ``can_admit`` — a free slot AND,
        for a paged KV-cache, enough free pages for the request's prompt +
        decode budget. Admission stays strictly priority-ordered within a
        tier (see module docstring for the queue key).

        ``now`` pins the whole round to one logical timestamp
        (simulators); without it the injected clock is read as events
        happen, so wall-mode completions still include the round's
        measured compute. ``stalled(tier, engine_index) -> bool`` marks
        pool members the fault layer has frozen: they are skipped for
        admission and stepping this round, their residents accrue no
        progress, and — with ``request_timeout_s`` — eventually time out
        and free their slots. Dead engines (crashed, not yet restarted) are
        likewise skipped, after their lost residents are reaped."""
        t_round = self.clock() if now is None else now
        out: List[Completion] = []
        for tier, pool in self.pools.items():
            self._reap_lost(tier, pool, t_round)
        if self.hedge_s is not None:
            self._fire_hedges(t_round)
        for tier, pool in self.pools.items():
            q = self._queues[tier]

            def is_stalled(i: int, _tier: str = tier) -> bool:
                return stalled is not None and bool(stalled(_tier, i))

            if self.shed_overdue:
                self._shed_overdue_queued(q, t_round)
            if self.request_timeout_s is not None:
                self._timeout_stuck(tier, pool, t_round)
            while q:
                head = q[0]
                run_req = self._run_request(head)
                eng_i = next(
                    (i for i, e in enumerate(pool)
                     if not is_stalled(i)
                     and self._breaker_allows(tier, i, t_round)
                     and e.can_admit(run_req)), None)
                if eng_i is None:
                    if self.preempt and self._preempt_for(tier, pool, head,
                                                          t_round):
                        continue      # a slot/pages just freed; retry head
                    break
                item = heapq.heappop(q)
                item.queue_wait_s += max(t_round - item.enqueued_at, 0.0)
                item.admitted_at = t_round
                item.last_progress_at = t_round
                rid = pool[eng_i].admit(run_req)
                item.admit_gen = pool[eng_i].engine_generation
                b = self.breakers.get((tier, eng_i))
                if b is not None:
                    b.begin_probe(t_round)   # no-op unless half-open
                if item.emitted or item.preemptions:
                    self.counters["resumed"] += 1
                self._inflight[(tier, eng_i, rid)] = item
            for eng_i, eng in enumerate(pool):
                if is_stalled(eng_i) or eng.dead or not eng.has_active:
                    continue
                for ec in eng.harvest():
                    item = self._inflight.pop((tier, eng_i, ec.req_id))
                    item.done = True
                    b = self.breakers.get((tier, eng_i))
                    if b is not None:
                        b.record_success(t_round)
                    t_done = self.clock() if now is None else now
                    partner = item.partner
                    if partner is not None and not partner.done:
                        self._cancel_item(partner, t_done)
                    # the winner's Completion always carries the PRIMARY
                    # request so callers can join on object identity
                    primary = (partner if item.is_hedge
                               and partner is not None else item)
                    ids = item.emitted + ec.token_ids
                    self.counters["completed"] += 1
                    out.append(Completion(
                        request=primary.request,
                        text=eng.tok.decode(ids), tier=tier,
                        queue_wait_s=item.queue_wait_s,
                        time_in_engine_s=item.resident_s
                        + max(t_done - item.admitted_at, 0.0),
                        prompt_tokens=(len(item.enc) if item.enc is not None
                                       else ec.prompt_tokens),
                        new_tokens=len(ids),
                        engine_index=eng_i,
                        engine_wall_s=ec.time_in_engine_s,
                        slo=primary.request.slo,
                        preemptions=item.preemptions,
                        hedged=item.is_hedge,
                        ttft_s=item.queue_wait_s + item.resident_s
                        + ec.ttft_s))
                eng.dispatch()
                # residents on an engine that just stepped made progress
                for key, it in self._inflight.items():
                    if key[0] == tier and key[1] == eng_i:
                        it.last_progress_at = t_round
        # collect AFTER every engine has dispatched: host-side scheduling
        # (planning, page mapping, queue work) for engine N+1 overlapped
        # the device compute of engine N — JAX async dispatch means nothing
        # above blocked on a result; only here do we fetch sampled tokens
        for pool in self.pools.values():
            for eng in pool:
                if not eng.dead:
                    eng.collect()
        return out

    # one pump used to serve a whole batch; keep the name as an alias for
    # callers that just want "advance the scheduler"
    step = pump

    def drain(self) -> List[Completion]:
        """Pump until no work remains. Raises :class:`SchedulerError` if a
        round makes NO progress (no admission, decode step, completion,
        shed, or preemption) while work is still outstanding — a wedged
        scheduler fails loudly instead of spinning forever, and the error
        carries a :meth:`debug_state` dump so the wedge is diagnosable
        from the message alone."""
        out: List[Completion] = []
        while self.pending() or self.in_flight():
            before = self._progress_fingerprint()
            out.extend(self.pump())
            if (self._progress_fingerprint() == before
                    and (self.pending() or self.in_flight())):
                raise SchedulerError(
                    f"scheduler wedged: {self.pending()} queued, "
                    f"{self.in_flight()} resident, and a full pump made no "
                    "progress (no admission, step, completion, shed, or "
                    f"preemption)\n{self.debug_state()}")
        return out

    def debug_state_dict(self, now: Optional[float] = None) -> dict:
        """Machine-readable diagnostic snapshot — the same information
        :meth:`debug_state` renders for humans, as a JSON-serializable
        dict, so wedge dumps and DST trace artifacts share one format.
        Per-tier queue depth and head deadline, per-engine residents /
        free slots / liveness / generation / breaker snapshot, and the
        full counter map. Pure introspection — never mutates anything
        (breaker state promotion open -> half_open on read is the
        breaker's own documented clock behavior)."""
        now = self.clock() if now is None else now
        tiers = {}
        for tier, pool in self.pools.items():
            q = self._queues[tier]
            engines = []
            for i, e in enumerate(pool):
                res = sum(1 for k in self._inflight
                          if k[0] == tier and k[1] == i)
                b = self.breakers.get((tier, i))
                engines.append({
                    "residents": res, "free_slots": e.free_slots,
                    "dead": bool(e.dead),
                    "generation": e.engine_generation,
                    "breaker": b.snapshot(now) if b is not None else None,
                    # fused-step telemetry (all zero off budget mode)
                    "prefilling": e.prefilling_slots,
                    "mixed_steps": e.mixed_steps,
                    "prefill_chunks": e.prefill_chunks,
                    "budget_utilization": round(e.budget_utilization, 4),
                })
            tiers[tier] = {
                "queued": len(q),
                "head_deadline": q[0].deadline if q else None,
                "engines": engines,
            }
        return {"t": now, "tiers": tiers, "counters": dict(self.counters),
                "conservation_ok": self.conservation_ok(),
                "fences": self.resident_fences()}

    def debug_state(self, now: Optional[float] = None) -> str:
        """Multi-line diagnostic snapshot for wedge reports, rendered from
        :meth:`debug_state_dict` with the raw JSON appended on the last
        line (grep for ``json=``) so a pasted wedge dump is also machine
        readable."""
        now = self.clock() if now is None else now
        d = self.debug_state_dict(now)
        lines = []
        for tier, td in d["tiers"].items():
            head = ("-" if td["head_deadline"] is None
                    else f"{td['head_deadline']:.3f}")
            lines.append(f"tier {tier!r}: queued={td['queued']} "
                         f"head_deadline={head}")
            for i, ed in enumerate(td["engines"]):
                bs = (ed["breaker"]["state"] if ed["breaker"] is not None
                      else "none")
                lines.append(
                    f"  engine[{i}]: residents={ed['residents']} "
                    f"free_slots={ed['free_slots']} dead={ed['dead']} "
                    f"generation={ed['generation']} breaker={bs}")
        lines.append(f"counters={self.counters}")
        lines.append(f"json={json.dumps(d, sort_keys=True)}")
        return "\n".join(lines)

    def resident_fences(self) -> List[dict]:
        """Raw material for the DST generation-fence oracle: one record
        per resident ``(tier, engine index, admit-time generation,
        engine's current generation, dead flag)``. A legal scheduler
        never holds a resident whose engine is dead or whose generation
        moved past the admit fence — :meth:`pump` reaps those before
        anything else runs."""
        out: List[dict] = []
        for (tier, i, rid), it in self._inflight.items():
            e = self.pools[tier][i]
            out.append({"tier": tier, "engine": i, "req_id": rid,
                        "admit_gen": it.admit_gen,
                        "engine_gen": e.engine_generation,
                        "dead": bool(e.dead)})
        return out

    def fences_ok(self) -> bool:
        """Generation-fence legality: no resident maps to a dead engine or
        to a generation other than the one it was admitted under."""
        return all(not f["dead"] and f["admit_gen"] == f["engine_gen"]
                   for f in self.resident_fences())

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------
    def _progress_fingerprint(self) -> tuple:
        work = sum(e.prefill_tokens + e.decode_rounds
                   for pool in self.pools.values() for e in pool)
        return (self.pending(), self.in_flight(), work,
                tuple(self.counters.values()))

    def _run_request(self, item: _Item) -> Request:
        """The request actually handed to engines: the original on first
        admission, the resume request (``prompt_ids = enc + emitted``)
        after a preemption. Kept on the item so engine plan memos stay
        effective across ``can_admit`` probes."""
        if item.run_request is None:
            item.run_request = item.request
        return item.run_request

    def _record_shed(self, item: _Item, reason: str, now: float,
                     queued: bool = True) -> None:
        item.done = True
        if item.partner is not None and not item.partner.done:
            # the other leg of the hedge pair is still live and carries
            # the request — this leg just retires as a cancelled duplicate
            self.counters["cancelled"] += 1
            return
        primary = (item.partner if item.is_hedge
                   and item.partner is not None else item)
        self.counters[_SHED_COUNTER[reason]] += 1
        wait = item.queue_wait_s
        if queued:
            wait += max(now - item.enqueued_at, 0.0)
        self.sheds.append(Shed(
            request=primary.request, tier=item.tier, reason=reason, t=now,
            slo=primary.request.slo, queue_wait_s=wait,
            emitted_tokens=len(item.emitted),
            preemptions=item.preemptions))

    def _shed_overdue_queued(self, q: List[_Item], now: float) -> None:
        """Drop queued items whose hard deadline already passed — they can
        no longer meet their SLO, so capacity goes to ones that can. Only
        QUEUED work sheds on deadline; residents hold reserved pages and
        finishing them is cheaper than wasting the work (they time out via
        ``request_timeout_s`` if truly stuck)."""
        if not any(it.deadline <= now for it in q):
            return
        keep = [it for it in q if it.deadline > now]
        dead = [it for it in q if it.deadline <= now]
        q[:] = keep
        heapq.heapify(q)
        for it in dead:
            self._record_shed(it, "deadline", now)

    def _timeout_stuck(self, tier: str, pool: List[ServingEngine],
                       now: float) -> None:
        """Reclaim residents whose engine made no progress for
        ``request_timeout_s`` (stalled engine / wedged decode): preempt
        them off the engine — host-side bookkeeping that works even when
        the engine itself is frozen — and emit ``Shed("timeout")``."""
        for key in [k for k in self._inflight if k[0] == tier]:
            it = self._inflight[key]
            if now - it.last_progress_at <= self.request_timeout_s:
                continue
            _, eng_i, rid = key
            snap = pool[eng_i].preempt(rid)
            del self._inflight[key]
            it.resident_s += max(now - it.admitted_at, 0.0)
            it.emitted.extend(snap.emitted_ids)
            self._breaker_fail(tier, eng_i, now)
            self._record_shed(it, "timeout", now, queued=False)

    def _preempt_for(self, tier: str, pool: List[ServingEngine],
                     head: _Item, now: float) -> bool:
        """Reclaim a slot for a queued head that fits nowhere: pick the
        WORST resident of the tier — largest ``(rank, deadline)`` — and
        preempt it iff it is STRICTLY lower priority than the head.
        The victim's snapshot (emitted tokens) folds into its item and it
        re-enters the queue; its next admission resumes via the prefix
        cache (original prompt pages are still indexed) and recomputes
        only the generated suffix, token-identical under greedy decode.
        Returns True when a victim was reclaimed (the caller retries
        admission), False when nobody is strictly below the head."""
        head_key = (head.rank, head.deadline)
        worst_key: Optional[Tuple[int, float]] = None
        worst: Optional[Tuple[Tuple[str, int, int], _Item]] = None
        for key, it in self._inflight.items():
            if key[0] != tier:
                continue
            k = (it.rank, it.deadline)
            if k <= head_key:
                continue
            if worst_key is None or k > worst_key:
                worst_key, worst = k, (key, it)
        if worst is None:
            return False
        (_, eng_i, rid), it = worst
        snap = pool[eng_i].preempt(rid)
        del self._inflight[(tier, eng_i, rid)]
        if it.enc is None:
            it.enc = list(snap.prompt_ids)    # original prompt encoding
        it.emitted.extend(snap.emitted_ids)
        it.preemptions += 1
        it.resident_s += max(now - it.admitted_at, 0.0)
        it.enqueued_at = now
        it.last_progress_at = now
        it.run_request = self._resume_request(it)
        heapq.heappush(self._queues[tier], it)
        self.counters["preempted"] += 1
        return True

    def _resume_request(self, it: _Item) -> Request:
        """The request for a fresh admission after the current residency
        ended early (preemption or engine loss): the original prompt plus
        whatever tokens the CONTROL PLANE has banked in ``it.emitted``.
        After a crash that is only tokens saved by an earlier preemption —
        in-engine progress died with the device state."""
        if it.enc is None or not it.emitted:
            return it.request
        return Request(
            prompt=it.request.prompt,
            prompt_ids=it.enc + it.emitted,
            max_new_tokens=it.request.max_new_tokens - len(it.emitted),
            temperature=it.request.temperature,
            slo=it.request.slo)

    # ------------------------------------------------------------------
    # Crash reaping / breakers / hedging
    # ------------------------------------------------------------------
    def _breaker_allows(self, tier: str, eng_i: int, now: float) -> bool:
        b = self.breakers.get((tier, eng_i))
        return b is None or b.allow(now)

    def _breaker_fail(self, tier: str, eng_i: int, now: float) -> None:
        b = self.breakers.get((tier, eng_i))
        if b is not None:
            b.record_failure(now)

    def _reap_lost(self, tier: str, pool: List[ServingEngine],
                   now: float) -> None:
        """Reclaim residents whose engine crashed — or crashed AND
        restarted — since they were admitted (``engine_generation``
        mismatch catches a full crash/restart cycle between pumps).
        Device-side progress is gone; each lost resident either re-enters
        the queue from its original prompt (+ any tokens banked by an
        earlier preemption) or becomes a typed ``Shed("engine_lost")``
        for the caller's failover. Every loss counts against the engine's
        breaker."""
        for key in [k for k in self._inflight if k[0] == tier]:
            _, eng_i, rid = key
            it = self._inflight[key]
            e = pool[eng_i]
            if not e.dead and e.engine_generation == it.admit_gen:
                continue
            del self._inflight[key]
            it.resident_s += max(now - it.admitted_at, 0.0)
            self._breaker_fail(tier, eng_i, now)
            if it.partner is not None and not it.partner.done:
                it.done = True
                self.counters["cancelled"] += 1
            elif self.requeue_lost:
                it.run_request = self._resume_request(it)
                it.enqueued_at = now
                it.last_progress_at = now
                heapq.heappush(self._queues[tier], it)
                self.counters["requeued_lost"] += 1
            else:
                self._record_shed(it, "engine_lost", now, queued=False)

    def _fire_hedges(self, now: float) -> None:
        """Interactive requests still unfinished ``hedge_s`` after
        submission to ``hedge_from`` get ONE backup submission of the
        same original prompt on ``hedge_to``. First completion wins; the
        loser is cancelled by the completion/shedding paths via the
        ``partner`` link."""
        if (self.hedge_to not in self.pools
                or self.hedge_from not in self.pools
                or self.hedge_to == self.hedge_from):
            return
        if self.hedge_gate is not None and not self.hedge_gate(now):
            return
        cands = list(self._queues[self.hedge_from]) + [
            it for (t, _, _), it in self._inflight.items()
            if t == self.hedge_from]
        for it in cands:
            if (it.is_hedge or it.partner is not None or it.done
                    or it.rank != SLO_RANK["interactive"]
                    or now - it.submitted_at < self.hedge_s):
                continue
            r = it.request
            hedge_req = Request(
                prompt=r.prompt, prompt_ids=r.prompt_ids,
                max_new_tokens=r.max_new_tokens,
                temperature=r.temperature, slo=r.slo)
            h = _Item(it.rank, it.deadline, next(self._seq), hedge_req,
                      self.hedge_to, enqueued_at=now, last_progress_at=now,
                      submitted_at=now, is_hedge=True, partner=it)
            it.partner = h
            heapq.heappush(self._queues[self.hedge_to], h)
            self.counters["hedged"] += 1

    def _cancel_item(self, it: _Item, now: float) -> None:
        """Retire the losing leg of a hedge pair: remove it from its
        queue, or preempt it off its engine with the snapshot discarded.
        Counted ``cancelled`` — never a Shed, never a completion — so
        hedge-aware conservation stays exact."""
        it.done = True
        q = self._queues.get(it.tier)
        if q is not None and it in q:
            q.remove(it)
            heapq.heapify(q)
            self.counters["cancelled"] += 1
            return
        key = next((k for k, v in self._inflight.items() if v is it), None)
        if key is not None:
            tier, eng_i, rid = key
            eng = self.pools[tier][eng_i]
            if not eng.dead:
                eng.preempt(rid)     # free slot + pages; progress dropped
            del self._inflight[key]
        self.counters["cancelled"] += 1


__all__ = ["TierScheduler", "Completion", "Shed", "SchedulerError",
           "SLO_RANK"]
