"""Request scheduler: arrival queue -> max-batch dispatch with per-tier
queues (edge engines + cloud engine), FIFO within a tier, oldest-deadline
first across tiers. This is the host-side batching layer the engines serve
under; the gate decides the tier, the scheduler packs the batches.
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.serving.engine import GenStats, Request, ServingEngine


@dataclass(order=True)
class _Item:
    deadline: float
    seq: int
    request: Request = field(compare=False)
    tier: str = field(compare=False, default="edge")
    enqueued_at: float = field(compare=False, default=0.0)


@dataclass
class Completion:
    request: Request
    text: str
    tier: str
    queue_wait_s: float
    batch_size: int


class TierScheduler:
    """Batched FIFO scheduler over named engine tiers."""

    def __init__(self, engines: Dict[str, ServingEngine],
                 max_wait_s: float = 0.05):
        self.engines = engines
        self.max_wait_s = max_wait_s
        self._queues: Dict[str, List[_Item]] = {t: [] for t in engines}
        self._seq = itertools.count()

    def submit(self, request: Request, tier: str,
               deadline_s: float = 1e9, now: Optional[float] = None) -> None:
        if tier not in self._queues:
            raise KeyError(f"unknown tier {tier!r}")
        now = time.perf_counter() if now is None else now
        heapq.heappush(self._queues[tier],
                       _Item(deadline_s, next(self._seq), request, tier, now))

    def pending(self, tier: Optional[str] = None) -> int:
        if tier:
            return len(self._queues[tier])
        return sum(len(q) for q in self._queues.values())

    def step(self) -> List[Completion]:
        """Serve one batch from the most-urgent non-empty tier."""
        tiers = [t for t, q in self._queues.items() if q]
        if not tiers:
            return []
        tier = min(tiers, key=lambda t: self._queues[t][0].deadline)
        eng = self.engines[tier]
        q = self._queues[tier]
        items = [heapq.heappop(q) for _ in range(min(eng.max_batch, len(q)))]
        now = time.perf_counter()
        texts, stats = eng.generate([it.request for it in items])
        return [
            Completion(it.request, text, tier,
                       queue_wait_s=max(now - it.enqueued_at, 0.0),
                       batch_size=len(items))
            for it, text in zip(items, texts)
        ]

    def drain(self) -> List[Completion]:
        out: List[Completion] = []
        while self.pending():
            out.extend(self.step())
        return out


__all__ = ["TierScheduler", "Completion"]
