"""Request scheduler: arrival queues -> continuous slot-pool admission.

Per-tier deadline heaps (edge engines + cloud engine) feed the engines'
slot pools. Instead of the old "pop one rigid batch, block on it" loop,
``pump()`` runs one scheduling round: for every tier it admits queued
requests (oldest deadline first) into whatever slots just freed, then
advances that tier's engine by one fused decode step, harvesting
per-request completions mid-stream. The gate decides the tier; the
scheduler keeps the lanes full.
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.serving.engine import Request, ServingEngine


@dataclass(order=True)
class _Item:
    deadline: float
    seq: int
    request: Request = field(compare=False)
    tier: str = field(compare=False, default="edge")
    enqueued_at: float = field(compare=False, default=0.0)
    queue_wait_s: float = field(compare=False, default=0.0)


@dataclass
class Completion:
    request: Request
    text: str
    tier: str
    queue_wait_s: float          # submit -> slot admission
    time_in_engine_s: float      # admission -> finish
    prompt_tokens: int = 0
    new_tokens: int = 0


class TierScheduler:
    """Deadline-ordered continuous scheduler over named engine tiers."""

    def __init__(self, engines: Dict[str, ServingEngine]):
        self.engines = engines
        self._queues: Dict[str, List[_Item]] = {t: [] for t in engines}
        self._inflight: Dict[tuple, _Item] = {}
        self._seq = itertools.count()

    def submit(self, request: Request, tier: str,
               deadline_s: float = 1e9, now: Optional[float] = None) -> None:
        if tier not in self._queues:
            raise KeyError(f"unknown tier {tier!r}")
        now = time.perf_counter() if now is None else now
        heapq.heappush(self._queues[tier],
                       _Item(deadline_s, next(self._seq), request, tier, now))

    def pending(self, tier: Optional[str] = None) -> int:
        """Queued requests not yet admitted into a slot."""
        if tier:
            return len(self._queues[tier])
        return sum(len(q) for q in self._queues.values())

    def in_flight(self, tier: Optional[str] = None) -> int:
        """Requests resident in an engine slot, still decoding."""
        if tier:
            return sum(t == tier for t, _ in self._inflight)
        return len(self._inflight)

    def pump(self) -> List[Completion]:
        """One scheduling round across every tier: fill free slots from the
        deadline heap, advance each engine one decode step, and return the
        requests that finished this round.

        Admission asks the engine via ``can_admit`` — a free slot AND, for a
        paged KV-cache, enough free pages for the request's prompt + decode
        budget. Admission stays strictly deadline-ordered: if the head
        request doesn't fit, later (larger-deadline) requests wait behind it
        rather than jumping the queue, so a big request can't be starved by
        a stream of small ones."""
        out: List[Completion] = []
        for tier, eng in self.engines.items():
            q = self._queues[tier]
            while q and eng.can_admit(q[0].request):
                item = heapq.heappop(q)
                item.queue_wait_s = time.perf_counter() - item.enqueued_at
                rid = eng.admit(item.request)
                self._inflight[(tier, rid)] = item
            if not eng.has_active:
                continue
            for ec in eng.step():
                item = self._inflight.pop((tier, ec.req_id))
                out.append(Completion(
                    request=item.request, text=ec.text, tier=tier,
                    queue_wait_s=max(item.queue_wait_s, 0.0),
                    time_in_engine_s=ec.time_in_engine_s,
                    prompt_tokens=ec.prompt_tokens,
                    new_tokens=ec.new_tokens))
        return out

    # one pump used to serve a whole batch; keep the name as an alias for
    # callers that just want "advance the scheduler"
    step = pump

    def drain(self) -> List[Completion]:
        out: List[Completion] = []
        while self.pending() or self.in_flight():
            out.extend(self.pump())
        return out


__all__ = ["TierScheduler", "Completion"]
