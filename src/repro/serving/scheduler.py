"""Request scheduler: arrival queues -> continuous slot-pool admission.

Per-tier deadline heaps (edge engines + cloud engine) feed the engines'
slot pools. Instead of the old "pop one rigid batch, block on it" loop,
``pump()`` runs one scheduling round: for every tier it admits queued
requests (oldest deadline first) into whatever slots just freed, then
advances that tier's engines by one fused decode step each, harvesting
per-request completions mid-stream. The gate decides the tier; the
scheduler keeps the lanes full.

A tier may be backed by a POOL of engines (``{"edge": [e0, e1], "cloud":
e2}``): the tier shares one deadline queue and the head request is admitted
into the first pool member with a free slot (and, paged, enough pages).

All timings run on an injectable ``clock`` (any zero-arg callable returning
seconds; default ``time.perf_counter``). ``submit(now=...)`` and
``pump(now=...)`` override the clock per call, so a simulator driving the
scheduler with logical event time gets exact logical queue waits and
service times — never a mix of event time and wall time.
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro.serving.engine import Request, ServingEngine


@dataclass(order=True)
class _Item:
    deadline: float
    seq: int
    request: Request = field(compare=False)
    tier: str = field(compare=False, default="edge")
    enqueued_at: float = field(compare=False, default=0.0)
    admitted_at: float = field(compare=False, default=0.0)
    queue_wait_s: float = field(compare=False, default=0.0)


@dataclass
class Completion:
    request: Request
    text: str
    tier: str
    queue_wait_s: float          # submit -> slot admission (scheduler clock)
    time_in_engine_s: float      # admission -> finish (scheduler clock)
    prompt_tokens: int = 0
    new_tokens: int = 0
    engine_index: int = 0        # which pool member served it
    engine_wall_s: float = 0.0   # engine-measured wall time (admit -> finish)


class TierScheduler:
    """Deadline-ordered continuous scheduler over named engine-pool tiers."""

    def __init__(self, engines: Dict[str, Union[ServingEngine,
                                                Sequence[ServingEngine]]],
                 clock: Optional[Callable[[], float]] = None):
        self.pools: Dict[str, List[ServingEngine]] = {}
        for tier, pool in engines.items():
            members = list(pool) if isinstance(pool, (list, tuple)) else [pool]
            if not members:
                raise ValueError(f"tier {tier!r} has an empty engine pool")
            self.pools[tier] = members
        self.engines = engines
        self.clock: Callable[[], float] = (time.perf_counter
                                           if clock is None else clock)
        self._queues: Dict[str, List[_Item]] = {t: [] for t in self.pools}
        self._inflight: Dict[Tuple[str, int, int], _Item] = {}
        self._seq = itertools.count()

    def submit(self, request: Request, tier: str,
               deadline_s: float = 1e9, now: Optional[float] = None) -> None:
        if tier not in self._queues:
            raise KeyError(f"unknown tier {tier!r}")
        now = self.clock() if now is None else now
        heapq.heappush(self._queues[tier],
                       _Item(deadline_s, next(self._seq), request, tier, now))

    def pending(self, tier: Optional[str] = None) -> int:
        """Queued requests not yet admitted into a slot."""
        if tier:
            return len(self._queues[tier])
        return sum(len(q) for q in self._queues.values())

    def in_flight(self, tier: Optional[str] = None) -> int:
        """Requests resident in an engine slot, still decoding."""
        if tier:
            return sum(t == tier for t, _, _ in self._inflight)
        return len(self._inflight)

    def pump(self, now: Optional[float] = None) -> List[Completion]:
        """One scheduling round across every tier: fill free slots from the
        deadline heap, advance each engine one decode step, and return the
        requests that finished this round.

        Admission asks the engines via ``can_admit`` — a free slot AND, for
        a paged KV-cache, enough free pages for the request's prompt +
        decode budget. Admission stays strictly deadline-ordered: if the
        head request doesn't fit on ANY pool member, later (larger-deadline)
        requests wait behind it rather than jumping the queue, so a big
        request can't be starved by a stream of small ones.

        ``now`` pins the whole round to one logical timestamp (simulators);
        without it the injected clock is read as events happen, so wall-mode
        completions still include the round's measured compute."""
        t_round = self.clock() if now is None else now
        out: List[Completion] = []
        for tier, pool in self.pools.items():
            q = self._queues[tier]
            while q:
                eng_i = next((i for i, e in enumerate(pool)
                              if e.can_admit(q[0].request)), None)
                if eng_i is None:
                    break
                item = heapq.heappop(q)
                item.queue_wait_s = max(t_round - item.enqueued_at, 0.0)
                item.admitted_at = t_round
                rid = pool[eng_i].admit(item.request)
                self._inflight[(tier, eng_i, rid)] = item
            for eng_i, eng in enumerate(pool):
                if not eng.has_active:
                    continue
                for ec in eng.step():
                    item = self._inflight.pop((tier, eng_i, ec.req_id))
                    t_done = self.clock() if now is None else now
                    out.append(Completion(
                        request=item.request, text=ec.text, tier=tier,
                        queue_wait_s=item.queue_wait_s,
                        time_in_engine_s=max(t_done - item.admitted_at, 0.0),
                        prompt_tokens=ec.prompt_tokens,
                        new_tokens=ec.new_tokens,
                        engine_index=eng_i,
                        engine_wall_s=ec.time_in_engine_s))
        return out

    # one pump used to serve a whole batch; keep the name as an alias for
    # callers that just want "advance the scheduler"
    step = pump

    def drain(self) -> List[Completion]:
        out: List[Completion] = []
        while self.pending() or self.in_flight():
            out.extend(self.pump())
        return out


__all__ = ["TierScheduler", "Completion"]
