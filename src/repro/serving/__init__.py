from repro.serving.engine import (
    EngineCompletion, GenStats, Request, ServingEngine, make_edge_engine,
)
from repro.serving.scheduler import Completion, TierScheduler

__all__ = ["ServingEngine", "Request", "GenStats", "EngineCompletion",
           "make_edge_engine", "TierScheduler", "Completion"]
