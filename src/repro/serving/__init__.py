from repro.serving.engine import (
    EngineCompletion, EngineError, GenStats, PreemptedRequest, Request,
    ServingEngine, make_cloud_engine, make_edge_engine,
)
from repro.serving.health import CircuitBreaker, breaker_states
from repro.serving.paging import (
    PageAllocator, PagingError, PrefixCache, pages_needed,
)
from repro.serving.scheduler import (
    Completion, SchedulerError, Shed, TierScheduler,
)

__all__ = ["ServingEngine", "Request", "GenStats", "EngineCompletion",
           "EngineError", "PreemptedRequest",
           "make_edge_engine", "make_cloud_engine",
           "TierScheduler", "Completion", "SchedulerError", "Shed",
           "CircuitBreaker", "breaker_states",
           "PageAllocator", "PrefixCache", "PagingError", "pages_needed"]
