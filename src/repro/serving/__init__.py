from repro.serving.engine import GenStats, Request, ServingEngine, make_edge_engine
from repro.serving.scheduler import Completion, TierScheduler

__all__ = ["ServingEngine", "Request", "GenStats", "make_edge_engine",
           "TierScheduler", "Completion"]
