from repro.serving.engine import (
    EngineCompletion, GenStats, Request, ServingEngine, make_edge_engine,
)
from repro.serving.paging import (
    PageAllocator, PagingError, PrefixCache, pages_needed,
)
from repro.serving.scheduler import Completion, TierScheduler

__all__ = ["ServingEngine", "Request", "GenStats", "EngineCompletion",
           "make_edge_engine", "TierScheduler", "Completion",
           "PageAllocator", "PrefixCache", "PagingError", "pages_needed"]
