"""Roofline-term derivation from a compiled dry-run artifact.

Terms (seconds, PER-DEVICE — the post-SPMD HLO module is the per-device
program):

  compute term    = device_FLOPs / peak_FLOP/s
  memory term     = device bytes accessed / HBM bw
  collective term = device collective bytes / link bw (ICI and DCN separate)

Costs come from :mod:`repro.launch.hlo_cost`, which (unlike XLA's
``cost_analysis()``) multiplies while-loop bodies by their trip counts —
essential for scan-over-layers models. The raw XLA numbers are retained as
``xla_flops_unrolled`` for cross-checking.

Bytes are counted at fusion boundaries (operands + outputs), an upper-bound
proxy for HBM traffic. All-reduce bytes get a 2x ring factor.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

from repro.launch.hlo_cost import Cost, analyze_hlo
from repro.launch.mesh import DCN_BW, HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclass
class Roofline:
    # per-device quantities
    flops: float
    bytes_accessed: float
    transcendentals: float
    ici_bytes: float
    dcn_bytes: float
    chips: int
    model_flops: float = 0.0          # analytic useful FLOPs (GLOBAL)
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    xla_flops_unrolled: float = -1.0  # XLA cost_analysis (loops counted once)
    per_device_peak_memory: float = -1.0

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_ici(self) -> float:
        return self.ici_bytes / ICI_BW

    @property
    def t_dcn(self) -> float:
        return self.dcn_bytes / DCN_BW

    @property
    def t_collective(self) -> float:
        return self.t_ici + self.t_dcn

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def step_time_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu_bound(self) -> float:
        """MFU if the step ran exactly at the roofline bound."""
        t = self.step_time_bound
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS_BF16 * t)

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_ici=self.t_ici, t_dcn=self.t_dcn,
                 t_collective=self.t_collective, dominant=self.dominant,
                 useful_ratio=self.useful_ratio,
                 step_time_bound=self.step_time_bound,
                 mfu_bound=self.mfu_bound)
        return d


def roofline_from_compiled(compiled, chips: int, model_flops: float,
                           pod_size: int = 256) -> Roofline:
    cost = analyze_hlo(compiled.as_text(), pod_size=pod_size)
    xla_flops = -1.0
    try:
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, list):
            ca = ca[0] if ca else {}
        xla_flops = float(ca.get("flops", -1.0))
    except Exception:
        pass
    peak = -1.0
    try:
        ma = compiled.memory_analysis()
        peak = float(getattr(ma, "temp_size_in_bytes", 0)
                     + getattr(ma, "argument_size_in_bytes", 0)
                     + getattr(ma, "output_size_in_bytes", 0))
    except Exception:
        pass
    return Roofline(
        flops=cost.flops, bytes_accessed=cost.bytes,
        transcendentals=cost.transcendentals,
        ici_bytes=cost.ici_bytes, dcn_bytes=cost.dcn_bytes, chips=chips,
        model_flops=model_flops, coll_by_kind=dict(cost.coll_by_kind),
        xla_flops_unrolled=xla_flops, per_device_peak_memory=peak,
    )


def model_flops_estimate(cfg, shape) -> float:
    """Analytic 'useful' FLOPs (GLOBAL): 6·N_active·T train, 2·N_active·T
    prefill (+ causal attention term), decode adds KV-cache attention."""
    n_active = cfg.n_active_params()
    hd = cfg.resolved_head_dim
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 6.0 * n_active * tokens
        if cfg.n_heads:
            # causal attention: 2(qk)+2(av), fwd+bwd(x2) halves for causality
            att = 6.0 * cfg.n_layers * cfg.n_heads * hd * shape.seq_len * tokens / 2
            base += att
        return base
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        base = 2.0 * n_active * tokens
        if cfg.n_heads:
            att = 2.0 * cfg.n_layers * cfg.n_heads * hd * shape.seq_len * tokens
            base += att / 2
        return base
    tokens = shape.global_batch
    base = 2.0 * n_active * tokens
    if cfg.n_heads:
        att = 4.0 * cfg.n_heads * hd * shape.seq_len * cfg.n_layers * tokens
        base += att
    return base


__all__ = ["Roofline", "roofline_from_compiled", "model_flops_estimate"]
