import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) on
the production meshes, print memory/cost analysis, and derive roofline terms.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  python -m repro.launch.dryrun --all                  # every combo, 1-pod
  python -m repro.launch.dryrun --all --multipod       # every combo, 2 pods
Results are cached as JSON under results/dryrun/ (skip with --force).
"""
import argparse
import gzip
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs import ARCHS, INPUT_SHAPES, get_config, shape_applicable
from repro.launch.mesh import HBM_PER_CHIP, make_production_mesh, rules_for
from repro.launch.roofline import model_flops_estimate, roofline_from_compiled
from repro.launch.specs import abstract_state, token_pspecs, token_specs
from repro.models.api import build_model
from repro.models.pdefs import pspecs_from_defs
from repro.models.shardctx import activation_sharding
from repro.training.optimizer import AdamWConfig
from repro.training.steps import make_decode_step, make_prefill_step, make_train_step

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"
HLO_DIR = Path(__file__).resolve().parents[3] / "results" / "hlo"


def _tag(arch, shape_name, multi_pod, variant):
    tag = f"{arch}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"
    if variant != "base":
        tag += f"__{variant}"
    return tag


def _named(tree_pspecs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_pspecs,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


def apply_variant(cfg, variant: str):
    """§Perf variants (comma-combinable): config-level changes per
    optimization hypothesis."""
    import dataclasses
    parts = set(variant.split("+"))
    if "moe_ep" in parts and cfg.moe.n_experts:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, shard_mode="ep"))
    if "rwkv_chunk" in parts and cfg.family == "ssm":
        cfg = dataclasses.replace(cfg, rwkv_chunk=64)
    if "kv_int8" in parts:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    return cfg


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool,
                variant: str = "base", cfg_override=None):
    """Build + lower + compile one (arch, shape, mesh). Returns result dict."""
    shape = INPUT_SHAPES[shape_name]
    cfg = cfg_override or get_config(arch)
    cfg = apply_variant(cfg, variant)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rules = rules_for(shape, variant)
    model = build_model(cfg, max_seq=shape.seq_len)

    state = abstract_state(model, shape, with_opt=(shape.kind == "train"))
    p_specs = pspecs_from_defs(model.param_defs(), mesh, rules)
    data = token_specs(cfg, shape)
    d_specs = token_pspecs(cfg, shape, mesh, rules)
    d_shard = {k: NamedSharding(mesh, v) for k, v in d_specs.items()}

    t0 = time.time()
    with mesh, activation_sharding(mesh, rules):
        if shape.kind == "train":
            step = make_train_step(model, AdamWConfig())
            opt_specs = {
                "mu": p_specs, "nu": p_specs, "step": PartitionSpec(),
            }
            batch = {k: data[k] for k in data}
            lowered = jax.jit(
                step,
                in_shardings=(_named(p_specs, mesh), _named(opt_specs, mesh),
                              d_shard),
            ).lower(state["params"], state["opt_state"], batch)
        elif shape.kind == "prefill":
            step = make_prefill_step(model)
            args = [state["params"], data["tokens"]]
            shards = [_named(p_specs, mesh), d_shard["tokens"]]
            if "memory" in data:
                args.append(data["memory"])
                shards.append(d_shard["memory"])
            lowered = jax.jit(step, in_shardings=tuple(shards)).lower(*args)
        else:  # decode
            step = make_decode_step(model)
            c_specs = pspecs_from_defs(state["cache_defs"], mesh, rules)
            lowered = jax.jit(
                step,
                in_shardings=(_named(p_specs, mesh), _named(c_specs, mesh),
                              d_shard["tokens1"], d_shard["positions"]),
                donate_argnums=(1,),   # in-place KV-cache update
            ).lower(state["params"], state["cache"], data["tokens1"],
                    data["positions"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    # archive the post-SPMD HLO so the roofline can be re-derived without
    # recompiling (analysis-model improvements, §Perf comparisons)
    HLO_DIR.mkdir(parents=True, exist_ok=True)
    hlo_path = HLO_DIR / (_tag(arch, shape_name, multi_pod, variant) + ".txt.gz")
    with gzip.open(hlo_path, "wt") as f:
        f.write(compiled.as_text())

    mf = model_flops_estimate(cfg, shape)
    rl = roofline_from_compiled(compiled, chips, mf,
                                pod_size=256 if multi_pod else chips)
    mem_txt = ""
    try:
        mem_txt = str(compiled.memory_analysis())
    except Exception as e:  # pragma: no cover
        mem_txt = f"<unavailable: {e}>"

    res = {
        "status": "ok",
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": variant,
        "chips": chips,
        "n_params": model.n_params(),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem_txt,
        "roofline": rl.to_dict(),
        "fits_hbm": (rl.per_device_peak_memory < 0
                     or rl.per_device_peak_memory <= HBM_PER_CHIP),
    }
    return res


def reanalyze(arch, shape_name, multi_pod, variant):
    """Recompute roofline terms from the archived HLO (no recompilation)."""
    tag = _tag(arch, shape_name, multi_pod, variant)
    out = RESULTS / f"{tag}.json"
    hlo_path = HLO_DIR / (tag + ".txt.gz")
    if not (out.exists() and hlo_path.exists()):
        return None
    res = json.loads(out.read_text())
    if res.get("status") != "ok":
        return res
    from repro.launch.hlo_cost import analyze_hlo
    from repro.launch.roofline import Roofline
    shape = INPUT_SHAPES[shape_name]
    cfg = apply_variant(get_config(arch), variant)
    with gzip.open(hlo_path, "rt") as f:
        text = f.read()
    cost = analyze_hlo(text, pod_size=256 if multi_pod else 10 ** 9)
    old = res["roofline"]
    rl = Roofline(
        flops=cost.flops, bytes_accessed=cost.bytes,
        transcendentals=cost.transcendentals, ici_bytes=cost.ici_bytes,
        dcn_bytes=cost.dcn_bytes, chips=res["chips"],
        model_flops=model_flops_estimate(cfg, shape),
        coll_by_kind=dict(cost.coll_by_kind),
        xla_flops_unrolled=old.get("xla_flops_unrolled", -1.0),
        per_device_peak_memory=old.get("per_device_peak_memory", -1.0),
    )
    res["roofline"] = rl.to_dict()
    out.write_text(json.dumps(res, indent=1))
    return res


def run_one(arch, shape_name, multi_pod, variant, force=False, quiet=False,
            reanalyze_only=False):
    RESULTS.mkdir(parents=True, exist_ok=True)
    tag = _tag(arch, shape_name, multi_pod, variant)
    out = RESULTS / f"{tag}.json"
    if reanalyze_only:
        res = reanalyze(arch, shape_name, multi_pod, variant)
        if res is not None:
            if not quiet and res["status"] == "ok":
                rl = res["roofline"]
                print(f"[reanalyzed] {tag}: dominant={rl['dominant']} "
                      f"t=(c {rl['t_compute']:.3e}, m {rl['t_memory']:.3e}, "
                      f"coll {rl['t_collective']:.3e})")
            return res
        # fall through to a fresh compile when no archive exists
    if out.exists() and not force and not reanalyze_only:
        res = json.loads(out.read_text())
        if not quiet:
            print(f"[cached] {tag}: {res['status']}")
        return res
    try:
        res = lower_combo(arch, shape_name, multi_pod=multi_pod, variant=variant)
    except Exception as e:
        res = {"status": "error", "arch": arch, "shape": shape_name,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    out.write_text(json.dumps(res, indent=1))
    if not quiet:
        if res["status"] == "ok":
            rl = res["roofline"]
            print(f"[ok] {tag}: compile={res['compile_s']}s "
                  f"dominant={rl['dominant']} "
                  f"t=(c {rl['t_compute']:.3e}, m {rl['t_memory']:.3e}, "
                  f"coll {rl['t_collective']:.3e}) useful={rl['useful_ratio']:.2f}")
        else:
            print(f"[{res['status']}] {tag}: {res.get('reason', res.get('error'))}")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute roofline from archived HLO")
    args = ap.parse_args()

    assert jax.device_count() >= 512, "dry-run needs the 512 fake devices"
    combos = []
    if args.all:
        for a in ARCHS:
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape
        combos = [(args.arch, args.shape)]

    n_ok = n_skip = n_err = 0
    for a, s in combos:
        r = run_one(a, s, args.multipod, args.variant, args.force,
                    reanalyze_only=args.reanalyze)
        n_ok += r["status"] == "ok"
        n_skip += r["status"] == "skipped"
        n_err += r["status"] == "error"
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")


if __name__ == "__main__":
    main()
