"""Serving launcher: batched generation on a (reduced) arch, or the full
tiered EACO cluster demo (examples/serve_cluster.py drives the latter).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
      --prompts "hello world" "what is rag"
"""
from __future__ import annotations

import argparse

from repro.configs import get_config
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--prompts", nargs="+",
                    default=["What is the capital of France?"])
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    if cfg.vocab < 300:
        raise SystemExit("arch vocab too small for byte tokenizer")
    eng = ServingEngine(cfg, max_seq=args.max_seq, max_batch=len(args.prompts))
    print(f"serving {cfg.arch_id} (reduced, {eng.model.n_params():,} params, "
          f"random weights — output is noise; the engine is real)")
    reqs = [Request(p, max_new_tokens=args.max_new,
                    temperature=args.temperature) for p in args.prompts]
    texts, stats = eng.generate(reqs)
    for p, t in zip(args.prompts, texts):
        print(f"> {p!r}\n  -> {t!r}")
    print(f"prefill {stats.prefill_s*1e3:.0f}ms, "
          f"{stats.new_tokens} tokens at {stats.tokens_per_s:.1f} tok/s")


if __name__ == "__main__":
    main()
