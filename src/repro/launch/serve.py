"""Serving launcher: continuous-batching generation on a (reduced) arch, or
the full tiered EACO cluster demo (examples/serve_cluster.py drives the
latter).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
      --prompts "hello world" "what is rag"

The engine streams any number of prompts through a fixed pool of
``--max-batch`` slots backed by a block-granular paged KV-cache (page
arena + per-slot page tables) wherever the arch supports it, with a
prefix cache on top: prompts sharing a page-aligned prefix (RAG context
reuse at an edge node) map the same physical pages and only their unique
suffix is prefilled. Pass ``--no-prefix-cache`` to disable the sharing,
``--kv-layout contiguous`` for the worst-case per-slot lanes,
``--page-size`` / ``--num-pages`` to shape the page pool, and ``--static``
to run the blocking static-batch baseline (one padded batch at a time).

The continuous path runs through the SLO-aware :class:`TierScheduler`:
``--slo-class`` tags every prompt (interactive sorts ahead of batch and
may preempt resident batch work when slots run out), ``--no-preemption``
disables resident reclaim, and ``--overload-watermark`` sheds batch-class
submissions (typed, reported per prompt) once queued + resident work
reaches that multiple of slot capacity.

Crash-tolerance knobs (the health layer, all optional):
``--breaker-threshold N`` arms a per-engine circuit breaker — N
consecutive losses (crash reaps, stuck-resident timeouts) quarantine the
engine until a timed half-open probe; ``--hedge-ms M`` spawns a second
"cloud" engine and fires a backup submission for any interactive prompt
still waiting after M milliseconds (first completion wins, the loser is
cancelled); ``--chaos`` hard-crashes the edge engine mid-run — all
device state is lost, the engine restarts cold, and the scheduler
re-enqueues the dead engine's residents (banked tokens resume via the
prefix cache), demonstrating that no prompt is lost.
"""
from __future__ import annotations

import argparse
import time

from repro.configs import get_config
from repro.serving.engine import Request, ServingEngine
from repro.serving.scheduler import TierScheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--static", action="store_true",
                    help="static-batch baseline instead of continuous")
    ap.add_argument("--kv-layout", default="auto",
                    choices=["auto", "paged", "contiguous"],
                    help="KV-cache layout (auto: paged where supported)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged layout)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page-pool size (default: worst case, "
                         "max_batch * max_seq / page_size)")
    ap.add_argument("--prefix-cache", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="share KV pages across common prompt prefixes "
                         "(paged layout only; --no-prefix-cache disables)")
    ap.add_argument("--step-token-budget", type=int, default=None,
                    help="fused chunked-prefill + decode: per-step token "
                         "budget mixing every resident decode row with one "
                         "bounded prefill chunk (paged layout only; "
                         "default: whole-suffix admission)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="max prompt tokens prefilled per fused step "
                         "(with --step-token-budget)")
    ap.add_argument("--slo-class", default="interactive",
                    choices=["interactive", "batch"],
                    help="SLO class tagged on every prompt (interactive "
                         "sorts ahead of batch and may preempt it)")
    ap.add_argument("--preemption", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="let the scheduler reclaim strictly-lower-"
                         "priority residents when slots run out")
    ap.add_argument("--overload-watermark", type=float, default=None,
                    help="shed batch-class submissions (typed) once "
                         "(queued + resident) / slot capacity reaches "
                         "this value")
    ap.add_argument("--breaker-threshold", type=int, default=None,
                    help="per-engine circuit breaker: quarantine an "
                         "engine after this many consecutive losses "
                         "(crash reaps / stuck-resident timeouts) until "
                         "a timed half-open probe succeeds")
    ap.add_argument("--hedge-ms", type=float, default=None,
                    help="fire a backup submission on a second 'cloud' "
                         "engine for interactive prompts still waiting "
                         "after this many ms; first completion wins and "
                         "the loser is cancelled")
    ap.add_argument("--chaos", action="store_true",
                    help="hard-crash the edge engine mid-run (all device "
                         "state lost) and restart it cold; the scheduler "
                         "re-enqueues the lost residents — demonstrates "
                         "zero-loss crash recovery")
    ap.add_argument("--prompts", nargs="+",
                    default=["What is the capital of France?"])
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    if cfg.vocab < 300:
        raise SystemExit("arch vocab too small for byte tokenizer")
    if args.static and (args.chaos or args.hedge_ms is not None
                        or args.breaker_threshold is not None):
        raise SystemExit("--chaos/--hedge-ms/--breaker-threshold need the "
                         "scheduler: drop --static")
    if args.static and args.step_token_budget is not None:
        raise SystemExit("--step-token-budget is a continuous-serving "
                         "feature: drop --static")
    eng = ServingEngine(cfg, max_seq=args.max_seq, max_batch=args.max_batch,
                        kv_layout=args.kv_layout, page_size=args.page_size,
                        num_pages=args.num_pages,
                        prefix_cache=args.prefix_cache,
                        step_token_budget=args.step_token_budget,
                        prefill_chunk=args.prefill_chunk)
    kv = (f"paged KV: {eng.num_pages} x {eng.page_size}-token pages, "
          f"prefix cache {'on' if eng.prefix_cache_enabled else 'off'}"
          if eng.kv_layout == "paged" else "contiguous KV lanes")
    print(f"serving {cfg.arch_id} (reduced, {eng.model.n_params():,} params, "
          f"{kv}; random weights — output is noise; the engine is real)")
    reqs = [Request(p, max_new_tokens=args.max_new,
                    temperature=args.temperature, slo=args.slo_class)
            for p in args.prompts]
    if args.static:
        from repro.serving.engine import GenStats
        texts, chunks = [], []
        for i in range(0, len(reqs), eng.max_batch):
            ts, st = eng.generate_static(reqs[i:i + eng.max_batch])
            texts.extend(ts)
            chunks.append(st)
        stats = GenStats(sum(s.prompt_tokens for s in chunks),
                         sum(s.new_tokens for s in chunks),
                         sum(s.prefill_s for s in chunks),
                         sum(s.decode_s for s in chunks),
                         prefill_traces=sum(s.prefill_traces for s in chunks),
                         prefix_hits=sum(s.prefix_hits for s in chunks),
                         prefix_misses=sum(s.prefix_misses for s in chunks),
                         prefix_tokens_shared=sum(s.prefix_tokens_shared
                                                  for s in chunks))
        for p, t in zip(args.prompts, texts):
            print(f"> {p!r}\n  -> {t!r}")
        print(f"[static] prefill {stats.prefill_s*1e3:.0f}ms, "
              f"{stats.new_tokens} tokens at {stats.tokens_per_s:.1f} "
              f"tok/s; traces: {eng.trace_counts}")
    else:
        pools = {"edge": eng}
        hedge_s = None
        if args.hedge_ms is not None:
            # hedging needs somewhere to hedge TO: a second engine
            # standing in for the cloud tier (same reduced arch)
            pools["cloud"] = ServingEngine(
                cfg, max_seq=args.max_seq, max_batch=args.max_batch,
                seed=1, kv_layout=args.kv_layout,
                page_size=args.page_size, num_pages=args.num_pages,
                prefix_cache=args.prefix_cache,
                step_token_budget=args.step_token_budget,
                prefill_chunk=args.prefill_chunk)
            hedge_s = args.hedge_ms / 1e3
        sched = TierScheduler(pools, preempt=args.preemption,
                              overload_watermark=args.overload_watermark,
                              breaker_threshold=args.breaker_threshold,
                              hedge_s=hedge_s, hedge_from="edge",
                              hedge_to="cloud")
        t0 = time.perf_counter()
        for r in reqs:
            sched.submit(r, "edge")
        comps = {}
        if args.chaos:
            # let work land, then kill the engine under it: every
            # device-side byte is gone; the reap + requeue path must
            # re-serve the lost residents after the cold restart
            for _ in range(3):
                comps.update({id(c.request): c for c in sched.pump()})
            lost = eng.crash()
            eng.restart()
            print(f"[chaos] edge engine crashed with {len(lost)} "
                  f"resident(s); restarted cold (generation "
                  f"{eng.engine_generation})")
        comps.update({id(c.request): c for c in sched.drain()})
        wall = time.perf_counter() - t0
        sheds = {id(s.request): s for s in sched.pop_sheds()}
        for p, r in zip(args.prompts, reqs):
            if id(r) in comps:
                c = comps[id(r)]
                tag = (f"  [preempted x{c.preemptions}, resumed]"
                       if c.preemptions else "")
                if c.hedged:
                    tag += f"  [hedged -> {c.tier}]"
                print(f"> {p!r}\n  -> {c.text!r}{tag}")
            else:
                s = sheds[id(r)]
                print(f"> {p!r}\n  -> SHED({s.reason}) after "
                      f"{s.queue_wait_s:.2f}s queued")
        tokens = sum(c.new_tokens for c in comps.values())
        sc = sched.counters
        print(f"[continuous] {len(comps)}/{len(reqs)} served, {tokens} "
              f"tokens at {tokens / max(wall, 1e-9):.1f} tok/s; "
              f"preempted {sc['preempted']}, resumed {sc['resumed']}, "
              f"shed {sched.shed_total}; traces: {eng.trace_counts}")
        if eng.budget_mode:
            ttfts = sorted(c.ttft_s for c in comps.values())
            p95 = ttfts[min(len(ttfts) - 1,
                            int(0.95 * len(ttfts)))] if ttfts else 0.0
            print(f"[fused-step] budget {eng.step_token_budget} tok/step, "
                  f"chunk {eng.prefill_chunk}: {eng.mixed_steps} mixed "
                  f"steps, {eng.prefill_chunks} chunks, budget utilization "
                  f"{eng.budget_utilization:.0%}, p95 TTFT "
                  f"{p95 * 1e3:.0f}ms")
        if args.chaos or args.breaker_threshold is not None or hedge_s:
            from repro.serving.health import breaker_states
            br = (breaker_states(sched.breakers, sched.clock())
                  if sched.breakers else {})
            print(f"[health] crashes {eng.crashes}, lost-to-crash "
                  f"{sc['engine_lost'] + sc['requeued_lost']}, requeued "
                  f"{sc['requeued_lost']}, hedged {sc['hedged']}, "
                  f"cancelled {sc['cancelled']}"
                  + (f"; breakers {br}" if br else ""))
    if eng.kv_layout == "paged" and eng.prefix_cache_enabled:
        print(f"[prefix-cache] {eng.prefix_hits} hits / "
              f"{eng.prefix_misses} misses, "
              f"{eng.prefix_tokens_shared} prompt tokens served from "
              f"shared pages")


if __name__ == "__main__":
    main()
