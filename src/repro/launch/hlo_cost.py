"""HLO-text cost analyzer with correct loop accounting.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, which makes
it useless for scan-over-layers models (a 80-layer scanned transformer would
report ~1/80th of its FLOPs). This analyzer walks the optimized (post-SPMD,
per-device) HLO text and:

  * multiplies while bodies by their ``known_trip_count`` backend config
    (fallback: the constant in the condition's compare),
  * recurses into fusion/call/conditional sub-computations for FLOPs,
  * counts dot FLOPs exactly (2 * prod(result dims) * prod(contracting dims)),
    elementwise ops at 1 FLOP/element,
  * estimates bytes accessed at fusion boundaries (operands + result),
  * accumulates collective bytes (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), with loop multipliers applied, split
    into ICI vs DCN by whether a replica group spans pods.

All numbers are PER-DEVICE (the SPMD module is the per-device program).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z]\d*[a-z0-9]*)\[([0-9,]*)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# opcodes that are free (layout/indexing only)
_FREE = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "iota", "after-all", "partition-id", "replica-id", "bitcast-convert",
}

_ELEMENTWISE_HEAVY = {"exponential", "tanh", "log", "power", "rsqrt", "sqrt",
                      "divide", "sine", "cosine", "logistic", "expm1",
                      "log1p", "erf", "cbrt", "atan2"}


def _type_info(type_str: str) -> Tuple[int, int]:
    """(elements, bytes) summed over all array literals in a type string."""
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    ici_bytes: float = 0.0
    dcn_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.transcendentals += o.transcendentals
        self.ici_bytes += o.ici_bytes
        self.dcn_bytes += o.dcn_bytes
        for k, v in o.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m, self.transcendentals * m,
                    self.ici_bytes * m, self.dcn_bytes * m,
                    {k: v * m for k, v in self.coll_by_kind.items()})


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-_]+)\s*\(.*\)\s*->.*\{")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-_]+)\s*=\s*(.*)$")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-_]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-_]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-_]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-_]+)")
_TRIP_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-_]+)")


def _split_type_op(rest: str) -> Tuple[str, str, str]:
    """rest = 'TYPE opcode(...), attrs' -> (type_str, opcode, tail)."""
    rest = rest.strip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str = rest[: i + 1]
        tail = rest[i + 1:].strip()
    else:
        sp = rest.find(" ")
        type_str = rest[:sp]
        tail = rest[sp + 1:].strip()
    m = re.match(r"([\w\-]+)", tail)
    opcode = m.group(1) if m else ""
    return type_str, opcode, tail


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and "{" in line:
                cur = Computation(m.group(1))
                if line.strip().startswith("ENTRY"):
                    entry = cur.name
                # parse parameter types from the header signature
                continue
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        try:
            type_str, opcode, tail = _split_type_op(rest)
        except Exception:
            continue
        # operand names: inside the first (...) after opcode
        p0 = tail.find("(")
        ops: List[str] = []
        if p0 >= 0:
            depth = 0
            for i in range(p0, len(tail)):
                if tail[i] == "(":
                    depth += 1
                elif tail[i] == ")":
                    depth -= 1
                    if depth == 0:
                        break
            ops = _OPERAND_RE.findall(tail[p0 : i + 1])
        cur.types[name] = type_str
        cur.instrs.append(Instr(name, type_str, opcode, ops, line))
    return comps, entry


class HloCostModel:
    def __init__(self, text: str, pod_size: int = 10 ** 9):
        self.comps, self.entry = parse_module(text)
        self.pod_size = pod_size
        self._memo: Dict[str, Cost] = {}

    # ----- helpers ----------------------------------------------------------
    def _operand_bytes(self, comp: Computation, ins: Instr) -> int:
        total = 0
        for o in ins.operands:
            t = comp.types.get(o)
            if t:
                total += _type_info(t)[1]
        return total

    def _trip_count(self, ins: Instr) -> int:
        m = _TRIP_RE.search(ins.line)
        if m:
            return int(m.group(1))
        mc = _COND_RE.search(ins.line)
        if mc and mc.group(1) in self.comps:
            for ci in self.comps[mc.group(1)].instrs:
                m2 = re.search(r"constant\((\d+)\)", ci.line)
                if m2:
                    return int(m2.group(1))
        return 1

    def _is_dcn(self, line: str) -> bool:
        m = re.search(r"replica_groups=\{\{([^}]*(?:\},\{[^}]*)*)\}\}", line)
        if m:
            for grp in m.group(1).split("},{"):
                ids = [int(t) for t in grp.split(",") if t.strip().isdigit()]
                if len({i // self.pod_size for i in ids}) > 1:
                    return True
            return False
        # iota format: replica_groups=[G,g]<=[a,b,...]T(perm)
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\]", line)
        if m:
            g = int(m.group(2))           # group size
            dims = [int(x) for x in m.group(3).split(",")]
            has_t = "T(" in line
            # heuristic: a group crosses pods iff group span exceeds pod
            # size along the major (pod) dimension. Without the transpose,
            # consecutive ids -> crosses pods only if g > pod_size.
            if not has_t:
                return g > self.pod_size
            # with a transpose the group strides across the major dim:
            # ids differ by products of trailing dims -> crosses pods if the
            # stride pattern reaches across pod_size. Conservative: True if
            # total devices > pod_size and the group includes the major dim.
            total = 1
            for d in dims:
                total *= d
            return total > self.pod_size and g >= dims[0]
        return False

    _SLICE_READERS = {"dynamic-slice", "gather"}

    def _fusion_io_bytes(self, comp: Computation, ins: Instr,
                         tname: Optional[str], out_bytes: int) -> int:
        called = self.comps.get(tname) if tname else None
        if called is None:
            return out_bytes + self._operand_bytes(comp, ins)
        # map parameter index -> (consumers, types) in the called computation
        params: Dict[int, str] = {}
        consumers: Dict[str, List[Instr]] = {}
        for ci in called.instrs:
            if ci.opcode == "parameter":
                mo = re.search(r"parameter\((\d+)\)", ci.line)
                if mo:
                    params[int(mo.group(1))] = ci.name
            for o in ci.operands:
                consumers.setdefault(o, []).append(ci)

        total = 0
        for i, oname in enumerate(ins.operands):
            full = _type_info(comp.types.get(oname, ""))[1]
            pname = params.get(i)
            uses = consumers.get(pname, []) if pname else []
            if uses and all(u.opcode in self._SLICE_READERS or
                            (u.opcode == "dynamic-update-slice"
                             and u.operands and u.operands[0] == pname)
                            for u in uses):
                sl = 0
                for u in uses:
                    if u.opcode == "dynamic-update-slice":
                        upd = (called.types.get(u.operands[1], "")
                               if len(u.operands) > 1 else "")
                        sl += _type_info(upd)[1]
                    else:
                        sl += _type_info(u.type_str)[1]
                total += min(sl, full)
            else:
                total += full
        # result: DUS roots alias the big buffer — count update bytes
        root = called.instrs[-1] if called.instrs else None
        if root is not None and root.opcode == "dynamic-update-slice":
            upd = (called.types.get(root.operands[1], "")
                   if len(root.operands) > 1 else "")
            total += min(_type_info(upd)[1] or out_bytes, out_bytes)
        else:
            total += out_bytes
        return total

    # ----- main -------------------------------------------------------------
    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        c = Cost()
        if comp is None:
            return c
        self._memo[comp_name] = c  # guard vs cycles
        for ins in comp.instrs:
            c += self._instr_cost(comp, ins)
        self._memo[comp_name] = c
        return c

    def _instr_cost(self, comp: Computation, ins: Instr) -> Cost:
        op = ins.opcode
        c = Cost()
        if op in _FREE:
            return c
        out_elems, out_bytes = _type_info(ins.type_str)

        if op == "while":
            trip = self._trip_count(ins)
            body = _BODY_RE.search(ins.line)
            cond = _COND_RE.search(ins.line)
            if body:
                c += self.cost_of(body.group(1)).scaled(trip)
            if cond:
                c += self.cost_of(cond.group(1)).scaled(trip + 1)
            return c

        if op == "conditional":
            mb = _BRANCHES_RE.search(ins.line)
            if mb:
                branches = [b.strip().lstrip("%") for b in mb.group(1).split(",")]
                costs = [self.cost_of(b) for b in branches if b in self.comps]
                if costs:
                    # assume the most expensive branch runs
                    best = max(costs, key=lambda x: x.flops + x.bytes)
                    c += best
            c.bytes += out_bytes + self._operand_bytes(comp, ins)
            return c

        if op in ("fusion", "call"):
            target = _CALLS_RE.search(ins.line) or _TO_APPLY_RE.search(ins.line)
            tname = target.group(1) if target else None
            if tname:
                sub = self.cost_of(tname)
                c.flops += sub.flops
                c.transcendentals += sub.transcendentals
                c.ici_bytes += sub.ici_bytes
                c.dcn_bytes += sub.dcn_bytes
                for k, v in sub.coll_by_kind.items():
                    c.coll_by_kind[k] = c.coll_by_kind.get(k, 0.0) + v
            # bytes at the fusion boundary, slice-aware: a fusion operand
            # consumed only via dynamic-slice/gather reads slice bytes, not
            # the whole array; a root that is a dynamic-update-slice writes
            # update bytes (the big buffer is aliased in place).
            c.bytes += self._fusion_io_bytes(comp, ins, tname, out_bytes)
            return c

        if op in ("dynamic-slice", "gather", "slice"):
            # reads a slice of the big operand, not all of it
            c.bytes += 2 * out_bytes
            return c
        if op == "dynamic-update-slice":
            upd_t = (comp.types.get(ins.operands[1], "")
                     if len(ins.operands) > 1 else "")
            ub = _type_info(upd_t)[1] if upd_t else out_bytes
            c.bytes += 2 * ub
            return c

        is_coll = any(op.startswith(k) for k in COLLECTIVES)
        if is_coll:
            kind = next(k for k in COLLECTIVES if op.startswith(k))
            factor = 2.0 if kind == "all-reduce" else 1.0
            moved = out_bytes * factor
            c.coll_by_kind[kind] = moved
            if self._is_dcn(ins.line):
                c.dcn_bytes += moved
            else:
                c.ici_bytes += moved
            c.bytes += out_bytes + self._operand_bytes(comp, ins)
            return c

        if op == "dot":
            lhs_t = comp.types.get(ins.operands[0], "") if ins.operands else ""
            mdim = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
            contract = 1
            if lhs_t and mdim and mdim.group(1):
                dims_m = _SHAPE_RE.search(lhs_t)
                if dims_m and dims_m.group(2):
                    ldims = [int(x) for x in dims_m.group(2).split(",")]
                    for idx in mdim.group(1).split(","):
                        i = int(idx)
                        if i < len(ldims):
                            contract *= ldims[i]
            c.flops += 2.0 * out_elems * contract
            c.bytes += out_bytes + self._operand_bytes(comp, ins)
            return c

        if op in ("convolution",):
            # not used by our models; fall through to elementwise estimate
            pass

        if op in ("reduce", "reduce-window", "scatter", "select-and-scatter",
                  "sort", "map"):
            # count operand traffic; flops ~ operand elements
            opb = self._operand_bytes(comp, ins)
            c.bytes += out_bytes + opb
            c.flops += sum(_type_info(comp.types.get(o, ""))[0]
                           for o in ins.operands)
            return c

        # generic elementwise / data movement
        c.bytes += out_bytes + self._operand_bytes(comp, ins)
        c.flops += out_elems
        if op in _ELEMENTWISE_HEAVY:
            c.transcendentals += out_elems
        return c

    def total(self) -> Cost:
        if not self.entry:
            return Cost()
        return self.cost_of(self.entry)


def analyze_hlo(text: str, pod_size: int = 10 ** 9) -> Cost:
    return HloCostModel(text, pod_size=pod_size).total()


__all__ = ["analyze_hlo", "HloCostModel", "Cost", "parse_module"]
