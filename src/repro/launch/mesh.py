"""Production meshes and sharding rules.

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches jax device state.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh

from repro.configs.base import InputShape
from repro.models.pdefs import DEFAULT_RULES


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh() -> Mesh:
    """1-device mesh for tests/examples on CPU."""
    return jax.make_mesh((1, 1), ("data", "model"))


def rules_for(shape: Optional[InputShape] = None, variant: str = "base"):
    """Sharding rules per input shape.

    long_500k (global_batch=1) cannot use batch parallelism, so the decode KV
    cache is *sequence-sharded* over the data axis (context parallelism —
    XLA SPMD partitions the attention contraction and inserts the softmax
    all-reduce).
    """
    rules = dict(DEFAULT_RULES)
    if shape is not None and shape.name == "long_500k":
        rules["cache_seq"] = ("data",)
        rules["frames"] = ("data",)
    if "seqcache" in variant.split("+"):
        # §Perf variant: decode KV caches sequence-sharded over the model
        # axis — for archs whose kv_heads don't divide the model axis the
        # cache is otherwise fully replicated there (16x memory).
        rules["cache_seq"] = ("model",)
        rules["kv_heads"] = ()
    return rules


# TPU v5e hardware constants (per chip) — roofline denominators
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link (intra-pod)
DCN_BW = 25e9                   # B/s (across pods)
HBM_PER_CHIP = 16e9             # bytes


__all__ = [
    "make_production_mesh", "make_local_mesh", "rules_for",
    "PEAK_FLOPS_BF16", "HBM_BW", "ICI_BW", "DCN_BW", "HBM_PER_CHIP",
]
