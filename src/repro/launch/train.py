"""Distributed training launcher.

On real hardware this runs the pjit train step over the production mesh;
on this CPU container use --mesh local (1 device) with a reduced arch, or
--mesh pod/multipod purely to lower+compile (the dry-run path with real
data shapes).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs import get_config
from repro.data.corpus import wiki_like
from repro.data.pipeline import PackedLMDataset
from repro.launch.mesh import make_local_mesh, make_production_mesh, rules_for
from repro.models import build_model
from repro.models.pdefs import pspecs_from_defs
from repro.models.shardctx import activation_sharding
from repro.training.checkpointing import save_checkpoint
from repro.training.optimizer import AdamWConfig
from repro.training.steps import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default="local", choices=["local", "pod", "multipod"])
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg, max_seq=args.seq)
    print(f"arch={cfg.arch_id} reduced={args.reduced} "
          f"params={model.n_params():,}")

    if args.mesh == "local":
        mesh = make_local_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
    rules = rules_for(None)
    p_specs = pspecs_from_defs(model.param_defs(), mesh, rules)
    named = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t,
        is_leaf=lambda x: isinstance(x, PartitionSpec))
    opt_specs = {"mu": p_specs, "nu": p_specs, "step": PartitionSpec()}
    batch_sharding = NamedSharding(mesh, PartitionSpec(
        "data" if args.batch % mesh.shape.get("data", 1) == 0 else None))

    ds = PackedLMDataset(wiki_like(0), seq_len=args.seq, batch=args.batch,
                         vocab_cap=cfg.vocab)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=5, total_steps=args.steps)

    with mesh, activation_sharding(mesh, rules):
        step_fn = jax.jit(
            make_train_step(model, opt_cfg),
            in_shardings=(named(p_specs), named(opt_specs), None),
        )
        params, opt_state = init_train_state(model, jax.random.PRNGKey(0))
        it = iter(ds)
        for step in range(args.steps):
            x, y = next(it)
            batch = {"tokens": jnp.asarray(x), "targets": jnp.asarray(y)}
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            print(f"step {step:4d} loss={loss:8.4f} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"({time.time()-t0:.2f}s)")
    if args.ckpt:
        save_checkpoint(args.ckpt, params, opt_state,
                        meta={"arch": cfg.arch_id, "steps": args.steps})
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
