"""Render the §Dry-run / §Roofline markdown tables from cached dry-run JSONs.

  PYTHONPATH=src python -m repro.launch.report [--mesh 16x16] [--variant base]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

ARCH_ORDER = [
    "llama-3.2-vision-11b", "deepseek-v2-lite-16b", "whisper-base",
    "qwen1.5-32b", "qwen2-0.5b", "zamba2-2.7b", "rwkv6-3b", "gemma3-4b",
    "olmoe-1b-7b", "qwen2-72b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_t(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def fmt_b(x: float) -> str:
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def load(mesh: str, variant: str = "base"):
    rows = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            tag = f"{arch}__{shape}__{mesh}"
            if variant != "base":
                tag += f"__{variant}"
            p = RESULTS / f"{tag}.json"
            if not p.exists():
                continue
            rows.append(json.loads(p.read_text()))
    return rows


def roofline_table(mesh: str, variant: str = "base") -> str:
    rows = load(mesh, variant)
    out = ["| arch | shape | dominant | t_comp | t_mem | t_coll (ici/dcn) | "
           "useful | MFU@bound | mem/dev | compile |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r.get('arch','?')} | {r.get('shape','?')} | "
                       f"SKIP: {r['reason'][:50]} | | | | | | | |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r.get('arch','?')} | {r.get('shape','?')} | "
                       f"ERROR | | | | | | | |")
            continue
        rl = r["roofline"]
        mem = rl.get("per_device_peak_memory", -1)
        out.append(
            f"| {r['arch']} | {r['shape']} | **{rl['dominant']}** "
            f"| {fmt_t(rl['t_compute'])} | {fmt_t(rl['t_memory'])} "
            f"| {fmt_t(rl['t_ici'])}/{fmt_t(rl['t_dcn'])} "
            f"| {rl['useful_ratio']:.3f} | {rl['mfu_bound']*100:.1f}% "
            f"| {fmt_b(mem) if mem > 0 else 'n/a'} "
            f"| {r['compile_s']}s |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--variant", default="base")
    args = ap.parse_args()
    print(roofline_table(args.mesh, args.variant))


if __name__ == "__main__":
    main()
