"""input_specs(): ShapeDtypeStruct stand-ins for every model input — weak-type
correct, shardable, no device allocation. The dry-run lowers against these.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import InputShape, ModelConfig
from repro.models.api import Model, build_model
from repro.models.pdefs import (
    ParamDef, abstract_from_defs, pspecs_from_defs, resolve_axes,
)
from repro.training.optimizer import adamw_init


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def token_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Abstract data inputs for a given input shape."""
    B, S = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        out["tokens"] = _sds((B, S), jnp.int32)
        out["targets"] = _sds((B, S), jnp.int32)
    elif shape.kind == "prefill":
        out["tokens"] = _sds((B, S), jnp.int32)
    else:  # decode: ONE new token against a seq_len cache
        out["tokens1"] = _sds((B, 1), jnp.int32)
        out["positions"] = _sds((B,), jnp.int32)
    if cfg.family in ("vlm", "encdec") and shape.kind != "decode":
        n_mem = cfg.n_image_tokens if cfg.family == "vlm" else cfg.n_frames
        out["memory"] = _sds((B, n_mem, cfg.d_model), cfg.activation_dtype)
    return out


def token_pspecs(cfg: ModelConfig, shape: InputShape, mesh: Mesh, rules):
    B = shape.global_batch
    batch_spec = resolve_axes(("batch",), (B,), mesh, rules)
    bs = batch_spec[0] if len(batch_spec) else None
    out = {}
    if shape.kind == "train":
        out["tokens"] = PartitionSpec(bs, None)
        out["targets"] = PartitionSpec(bs, None)
    elif shape.kind == "prefill":
        out["tokens"] = PartitionSpec(bs, None)
    else:
        out["tokens1"] = PartitionSpec(bs, None)
        out["positions"] = PartitionSpec(bs)
    if "memory" in token_specs(cfg, shape):
        out["memory"] = PartitionSpec(bs, None, None)
    return out


def abstract_state(model: Model, shape: InputShape, with_opt: bool):
    """Abstract params (+ optimizer state for train, + cache for decode)."""
    params = model.abstract_params()
    out = {"params": params}
    if with_opt:
        out["opt_state"] = jax.eval_shape(adamw_init, params)
    if shape.kind == "decode":
        cd = model.cache_defs(shape.global_batch)
        out["cache"] = abstract_from_defs(cd)
        out["cache_defs"] = cd
    return out


__all__ = ["token_specs", "token_pspecs", "abstract_state"]
