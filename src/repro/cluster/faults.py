"""Deterministic fault injection on the virtual clock.

Overload robustness is only proven if the loop survives the ugly cases:
an engine that stops making progress, a network path whose latency spikes,
a completion that never reaches the caller. :class:`FaultInjector` models
all three as PURE functions of virtual time (plus one seeded RNG for
completion drops), so a faulted simulation is exactly reproducible under a
fixed seed — the same property the rest of the cluster keeps
(``engine_time="modeled"``).

* **Engine stalls** — periodic windows: within each ``stall_period_s``
  cycle, one pool member of each listed tier is frozen for
  ``stall_duration_s`` (the victim rotates through the pool across
  cycles). The scheduler's ``stalled`` hook skips the frozen engine for
  admission and stepping; its residents stop accruing progress and — with
  ``request_timeout_s`` set — are timed out, freeing slot and pages.
* **Network delay spikes** — within each ``net_spike_period_s`` cycle the
  first ``net_spike_duration_s`` adds ``net_spike_extra_s`` to the transit
  delay of any completion finalized in the window (a congested uplink).
* **Dropped completions** — each harvested completion is lost with
  probability ``drop_completion_p`` (seeded RNG, one draw per completion):
  the caller never sees the result and must treat the request like a shed
  (retry / fail over), exercising the same recovery path as a lost RPC.
* **Engine crashes** — periodic windows like stalls, but HARD: within each
  ``crash_period_s`` cycle one pool member of each listed tier is dead for
  ``crash_duration_s``. The cluster calls :meth:`ServingEngine.crash` on
  window entry (all device state gone — slots, arena, prefix index) and
  :meth:`restart` on exit (cold engine, bumped ``engine_generation``);
  the scheduler reaps the lost residents as typed ``engine_lost``
  outcomes. ``crash_rotate=False`` pins every crash on pool member 0 —
  the "one flaky node" pattern circuit breakers exist for.
* **Partitions** — within each ``partition_period_s`` cycle the
  edge<->cloud link is down for ``partition_duration_s``: knowledge
  updates cannot ship (they defer and reconcile via anti-entropy on
  heal), failover cannot escalate edge->cloud, and the gate's
  availability mask excludes cloud-dependent arms. Edges keep serving,
  degraded, with ``stale_epoch`` flags.

The stall/spike injectors never touch engine internals — a "stalled"
engine's KV and slot state stay intact, which is exactly what makes
timeout-preemption (host-side bookkeeping) the right recovery tool. A
*crash* is the opposite contract: nothing survives, and recovery is
restart + re-serve, not preemption.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass
class FaultConfig:
    stall_period_s: float = 0.0       # 0 disables engine stalls
    stall_duration_s: float = 1.0     # frozen window at each cycle start
    stall_start_s: float = 0.0        # no stalls before this instant (lets
    #                                   callers land the first window once
    #                                   work is actually resident)
    stall_tiers: Tuple[str, ...] = ("edge",)
    net_spike_period_s: float = 0.0   # 0 disables delay spikes
    net_spike_duration_s: float = 0.5
    net_spike_extra_s: float = 0.5
    drop_completion_p: float = 0.0    # 0 disables completion drops
    # ---- hard failures ------------------------------------------------
    crash_period_s: float = 0.0       # 0 disables engine crashes
    crash_duration_s: float = 1.0     # dead window at each cycle start
    crash_start_s: float = 0.0        # no crashes before this instant
    crash_tiers: Tuple[str, ...] = ("edge",)
    crash_rotate: bool = True         # False: member 0 is the flaky node
    partition_period_s: float = 0.0   # 0 disables edge<->cloud partitions
    partition_duration_s: float = 1.0
    partition_start_s: float = 0.0
    seed: int = 0


class FaultInjector:
    """Deterministic fault schedule (see module docstring)."""

    def __init__(self, cfg: FaultConfig = None):
        self.cfg = FaultConfig() if cfg is None else cfg
        self._rng = np.random.default_rng(self.cfg.seed)
        self.stall_hits = 0       # times a stalled engine was consulted
        self.spiked = 0           # completions that got a delay spike
        self.dropped = 0          # completions dropped
        self.crash_hits = 0       # times a crashed engine was consulted
        self.partition_hits = 0   # times a live partition was consulted

    def stalled(self, tier: str, engine_index: int, now: float,
                pool_size: int = 1) -> bool:
        """Is this pool member frozen at virtual time ``now``? One victim
        per cycle, rotating through the pool so every member gets its turn
        to fail."""
        c = self.cfg
        if c.stall_period_s <= 0 or tier not in c.stall_tiers:
            return False
        if now < c.stall_start_s:
            return False
        cycle, phase = divmod(now - c.stall_start_s, c.stall_period_s)
        if phase >= c.stall_duration_s:
            return False
        hit = int(cycle) % max(pool_size, 1) == engine_index
        if hit:
            self.stall_hits += 1
        return hit

    def crashed(self, tier: str, engine_index: int, now: float,
                pool_size: int = 1) -> bool:
        """Should this pool member be DEAD at virtual time ``now``? Same
        windowing as :meth:`stalled`, but the victim is either rotating
        (``crash_rotate=True``) or pinned to member 0 (the one flaky node
        that keeps failing — the case circuit breakers pay for)."""
        c = self.cfg
        if c.crash_period_s <= 0 or tier not in c.crash_tiers:
            return False
        if now < c.crash_start_s:
            return False
        cycle, phase = divmod(now - c.crash_start_s, c.crash_period_s)
        if phase >= c.crash_duration_s:
            return False
        victim = (int(cycle) % max(pool_size, 1)) if c.crash_rotate else 0
        hit = victim == engine_index
        if hit:
            self.crash_hits += 1
        return hit

    def partitioned(self, now: float) -> bool:
        """Is the edge<->cloud link down at virtual time ``now``?"""
        c = self.cfg
        if c.partition_period_s <= 0:
            return False
        if now < c.partition_start_s:
            return False
        phase = (now - c.partition_start_s) % c.partition_period_s
        hit = phase < c.partition_duration_s
        if hit:
            self.partition_hits += 1
        return hit

    def net_spike(self, now: float) -> float:
        """Extra network transit delay at virtual time ``now``."""
        c = self.cfg
        if c.net_spike_period_s <= 0:
            return 0.0
        if now % c.net_spike_period_s < c.net_spike_duration_s:
            self.spiked += 1
            return c.net_spike_extra_s
        return 0.0

    def drop_completion(self, now: float) -> bool:
        """Should this completion be lost in transit? One seeded draw per
        completion — deterministic given the completion order, which the
        virtual clock already fixes."""
        c = self.cfg
        if c.drop_completion_p <= 0:
            return False
        hit = bool(self._rng.random() < c.drop_completion_p)
        if hit:
            self.dropped += 1
        return hit


__all__ = ["FaultInjector", "FaultConfig"]
