"""Deterministic fault injection on the virtual clock.

Overload robustness is only proven if the loop survives the ugly cases:
an engine that stops making progress, a network path whose latency spikes,
a completion that never reaches the caller, a node that dies outright, a
partitioned edge<->cloud link. All of them are modeled as PURE functions
of virtual time (plus one seeded RNG for completion drops), so a faulted
simulation is exactly reproducible under a fixed seed — the same property
the rest of the cluster keeps (``engine_time="modeled"``).

Representation: an **event timeline**. Every fault is a
:class:`FaultEvent` — ``(t, kind, duration, target, magnitude)`` — and an
injector is just a sorted list of events consulted by the same five query
methods the cluster and benches always used:

* **Engine stalls** (``kind="stall"``) — the targeted pool member of a
  tier is frozen for ``duration``. The scheduler's ``stalled`` hook skips
  the frozen engine for admission and stepping; its residents stop
  accruing progress and — with ``request_timeout_s`` set — are timed out,
  freeing slot and pages.
* **Engine crashes** (``kind="crash"``) — like stalls but HARD: the
  member is dead for the window. The cluster calls
  :meth:`ServingEngine.crash` on window entry (all device state gone —
  slots, arena, prefix index) and :meth:`restart` on exit (cold engine,
  bumped ``engine_generation``); the scheduler reaps the lost residents
  as typed ``engine_lost`` outcomes.
* **Partitions** (``kind="partition"``) — the edge<->cloud link is down
  for the window: knowledge updates cannot ship (they defer and reconcile
  via anti-entropy on heal), failover cannot escalate edge->cloud, and
  the gate's availability mask excludes cloud-dependent arms. Edges keep
  serving, degraded, with ``stale_epoch`` flags.
* **Network delay spikes** (``kind="net_spike"``) — completions finalized
  in the window pay ``magnitude`` extra seconds of transit delay (a
  congested uplink).
* **Dropped completions** (``kind="drop"`` windows and/or a global
  ``drop_completion_p``) — each harvested completion is lost with the
  effective probability (seeded RNG, one draw per completion): the caller
  never sees the result and must treat the request like a shed (retry /
  fail over), exercising the same recovery path as a lost RPC.

Two injectors share the query API:

* :class:`TimelineFaultInjector` — owns an explicit event list. This is
  what the DST layer (:mod:`repro.cluster.dst`) drives with *generated*
  random schedules, and what replay-from-trace rebuilds from JSON.
* :class:`FaultInjector` — the original periodic-window configuration
  (:class:`FaultConfig`), now a thin subclass that lazily COMPILES its
  ``period/duration/start`` formulas into timeline events cycle by cycle.
  The hand-authored ``chaos_bench.py`` schedules are therefore fixed
  points of the same representation the fuzzer samples from, and remain
  behavior-identical (the test suite pins the exact old window/rotation
  semantics).

The stall/spike injectors never touch engine internals — a "stalled"
engine's KV and slot state stay intact, which is exactly what makes
timeout-preemption (host-side bookkeeping) the right recovery tool. A
*crash* is the opposite contract: nothing survives, and recovery is
restart + re-serve, not preemption.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# fault kinds an injector interprets; workload kinds (arrivals, knowledge,
# slo_shift) ride the same FaultEvent/timeline representation but are
# applied by the DST harness, not the injector
FAULT_KINDS = ("stall", "crash", "partition", "net_spike", "drop")


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled event on the virtual clock.

    ``t`` is the window start, ``duration`` its length (instantaneous
    events — e.g. a DST arrival burst — use 0). ``tier``/``engine`` name
    the target for stall/crash: ``engine == -1`` means "rotating victim",
    resolved at query time as ``cycle % pool_size`` (the classic
    FaultConfig rotation); an explicit index pins the victim. ``magnitude``
    is the extra transit seconds for ``net_spike`` and the drop
    probability for ``drop`` windows. ``params`` carries workload payloads
    (request specs, knowledge-burst targets) untouched by the injector."""
    t: float
    kind: str
    duration: float = 0.0
    tier: str = ""
    engine: int = -1
    magnitude: float = 0.0
    cycle: int = 0
    params: Optional[dict] = None

    def active(self, now: float) -> bool:
        return self.t <= now < self.t + self.duration

    def victim(self, pool_size: int) -> int:
        return (self.engine if self.engine >= 0
                else self.cycle % max(pool_size, 1))

    def to_dict(self) -> dict:
        """Compact JSON form (defaults omitted) for trace artifacts."""
        d: dict = {"t": self.t, "kind": self.kind}
        if self.duration:
            d["duration"] = self.duration
        if self.tier:
            d["tier"] = self.tier
        if self.engine != -1:
            d["engine"] = self.engine
        if self.magnitude:
            d["magnitude"] = self.magnitude
        if self.cycle:
            d["cycle"] = self.cycle
        if self.params is not None:
            d["params"] = self.params
        return d

    @staticmethod
    def from_dict(d: dict) -> "FaultEvent":
        return FaultEvent(
            t=float(d["t"]), kind=str(d["kind"]),
            duration=float(d.get("duration", 0.0)),
            tier=str(d.get("tier", "")), engine=int(d.get("engine", -1)),
            magnitude=float(d.get("magnitude", 0.0)),
            cycle=int(d.get("cycle", 0)), params=d.get("params"))


@dataclass
class FaultConfig:
    """Periodic-window fault schedule (compiled to timeline events)."""
    stall_period_s: float = 0.0       # 0 disables engine stalls
    stall_duration_s: float = 1.0     # frozen window at each cycle start
    stall_start_s: float = 0.0        # no stalls before this instant (lets
    #                                   callers land the first window once
    #                                   work is actually resident)
    stall_tiers: Tuple[str, ...] = ("edge",)
    net_spike_period_s: float = 0.0   # 0 disables delay spikes
    net_spike_duration_s: float = 0.5
    net_spike_extra_s: float = 0.5
    drop_completion_p: float = 0.0    # 0 disables completion drops
    # ---- hard failures ------------------------------------------------
    crash_period_s: float = 0.0       # 0 disables engine crashes
    crash_duration_s: float = 1.0     # dead window at each cycle start
    crash_start_s: float = 0.0        # no crashes before this instant
    crash_tiers: Tuple[str, ...] = ("edge",)
    crash_rotate: bool = True         # False: member 0 is the flaky node
    partition_period_s: float = 0.0   # 0 disables edge<->cloud partitions
    partition_duration_s: float = 1.0
    partition_start_s: float = 0.0
    seed: int = 0


class TimelineFaultInjector:
    """Fault injector over an explicit, sorted event timeline.

    Query methods answer "is this fault active at virtual time ``now``"
    and bump the same counters the cluster/bench checks have always read.
    ``drop_completion`` combines a global ``drop_completion_p`` with any
    active ``drop`` window (max wins) and spends one seeded draw per
    consultation while the effective probability is > 0 — deterministic
    given the completion order, which the virtual clock already fixes."""

    def __init__(self, events: Sequence[FaultEvent] = (), *,
                 drop_completion_p: float = 0.0, seed: int = 0):
        self._events: Dict[str, List[FaultEvent]] = {}
        self.drop_completion_p = drop_completion_p
        self._rng = np.random.default_rng(seed)
        self.stall_hits = 0       # times a stalled engine was consulted
        self.spiked = 0           # completions that got a delay spike
        self.dropped = 0          # completions dropped
        self.crash_hits = 0       # times a crashed engine was consulted
        self.partition_hits = 0   # times a live partition was consulted
        for ev in events:
            self.add(ev)

    # ---- timeline maintenance -----------------------------------------
    def add(self, ev: FaultEvent) -> None:
        """Insert an event, keeping the per-kind list sorted by start."""
        lst = self._events.setdefault(ev.kind, [])
        bisect.insort(lst, ev, key=lambda e: e.t)

    def events(self, kind: Optional[str] = None) -> List[FaultEvent]:
        """The timeline (one kind, or all kinds merged in time order)."""
        if kind is not None:
            return list(self._events.get(kind, []))
        out = [ev for lst in self._events.values() for ev in lst]
        out.sort(key=lambda e: (e.t, e.kind))
        return out

    def horizon(self) -> float:
        """Latest window end over all events (0 for an empty timeline)."""
        return max((ev.t + ev.duration for lst in self._events.values()
                    for ev in lst), default=0.0)

    def _active(self, kind: str, now: float) -> List[FaultEvent]:
        self._ensure(now)
        out = []
        for ev in self._events.get(kind, ()):
            if ev.t > now:
                break
            if ev.active(now):
                out.append(ev)
        return out

    def _ensure(self, now: float) -> None:
        """Hook for lazily-generated timelines (see :class:`FaultInjector`
        which expands periodic formulas on demand). Base: no-op."""

    # ---- queries (the stable five-method API) --------------------------
    def stalled(self, tier: str, engine_index: int, now: float,
                pool_size: int = 1) -> bool:
        """Is this pool member frozen at virtual time ``now``?"""
        hit = any(ev.tier == tier and ev.victim(pool_size) == engine_index
                  for ev in self._active("stall", now))
        if hit:
            self.stall_hits += 1
        return hit

    def crashed(self, tier: str, engine_index: int, now: float,
                pool_size: int = 1) -> bool:
        """Should this pool member be DEAD at virtual time ``now``?"""
        hit = any(ev.tier == tier and ev.victim(pool_size) == engine_index
                  for ev in self._active("crash", now))
        if hit:
            self.crash_hits += 1
        return hit

    def partitioned(self, now: float) -> bool:
        """Is the edge<->cloud link down at virtual time ``now``?"""
        hit = bool(self._active("partition", now))
        if hit:
            self.partition_hits += 1
        return hit

    def net_spike(self, now: float) -> float:
        """Extra network transit delay at virtual time ``now`` (max over
        overlapping spike windows)."""
        extra = max((ev.magnitude for ev in self._active("net_spike", now)),
                    default=0.0)
        if extra > 0:
            self.spiked += 1
            return extra
        return 0.0

    def drop_completion(self, now: float) -> bool:
        """Should this completion be lost in transit? One seeded draw per
        consultation while the effective drop probability is > 0."""
        p = self.drop_completion_p
        for ev in self._active("drop", now):
            p = max(p, ev.magnitude)
        if p <= 0:
            return False
        hit = bool(self._rng.random() < p)
        if hit:
            self.dropped += 1
        return hit


class FaultInjector(TimelineFaultInjector):
    """Periodic-window fault schedule (see module docstring), expressed on
    the event timeline: each ``period/duration/start`` formula is expanded
    lazily — cycle by cycle, up to the largest ``now`` ever queried — into
    :class:`FaultEvent` windows. Query semantics are identical to the
    original closed-form implementation (the effective window length is
    ``min(duration, period)``, exactly the reachable phase range)."""

    def __init__(self, cfg: FaultConfig = None):
        self.cfg = FaultConfig() if cfg is None else cfg
        super().__init__(drop_completion_p=self.cfg.drop_completion_p,
                         seed=self.cfg.seed)
        self._next_cycle = {k: 0 for k in
                            ("stall", "crash", "partition", "net_spike")}

    def _ensure(self, now: float) -> None:
        c = self.cfg
        self._expand("stall", c.stall_period_s, c.stall_duration_s,
                     c.stall_start_s, now, tiers=c.stall_tiers, rotate=True)
        self._expand("crash", c.crash_period_s, c.crash_duration_s,
                     c.crash_start_s, now, tiers=c.crash_tiers,
                     rotate=c.crash_rotate)
        self._expand("partition", c.partition_period_s,
                     c.partition_duration_s, c.partition_start_s, now)
        self._expand("net_spike", c.net_spike_period_s,
                     c.net_spike_duration_s, 0.0, now,
                     magnitude=c.net_spike_extra_s)

    def _expand(self, kind: str, period: float, duration: float,
                start: float, now: float, tiers: Tuple[str, ...] = (),
                rotate: bool = True, magnitude: float = 0.0) -> None:
        if period <= 0:
            return
        k = self._next_cycle[kind]
        dur = min(duration, period)
        while start + k * period <= now:
            t = start + k * period
            if tiers:
                for tier in tiers:
                    self.add(FaultEvent(t, kind, dur, tier=tier,
                                        engine=-1 if rotate else 0, cycle=k))
            else:
                self.add(FaultEvent(t, kind, dur, magnitude=magnitude))
            k += 1
        self._next_cycle[kind] = k


__all__ = ["FaultInjector", "FaultConfig", "FaultEvent",
           "TimelineFaultInjector", "FAULT_KINDS"]
