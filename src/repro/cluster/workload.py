"""Query workload with spatial (per-edge topic affinity) and temporal
(interest drift) variation — the paper's Table 2 phenomenology — plus
bursty multi-user arrivals for the engines-backed closed loop.

``stream`` keeps the original one-query-per-step shape (the oracle-backed
simulator and most benchmarks). ``bursts`` models tiered deployment under
load: each step draws a Poisson number of concurrent user queries (capped),
optionally skewed further toward each edge's current hot topic, and stamps
every event from an injectable clock so arrival times live on the same
virtual timeline as queue waits and engine service time. The generator
never advances the clock — whoever owns the timeline (the simulator) does.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.data.corpus import Corpus, QAPair


@dataclass
class WorkloadConfig:
    n_edges: int = 6
    drift_period: float = 250.0     # steps between interest re-draws
    drift_strength: float = 0.6     # 0 = static, 1 = full resample
    concentration: float = 0.5      # Dirichlet alpha (lower = peakier)
    # bursty multi-user arrivals (engines backend)
    mean_arrivals: float = 1.0      # Poisson mean queries per step
    max_arrivals: int = 8           # burst cap per step
    hot_topic_boost: float = 0.0    # extra mass on each edge's top topic


@dataclass
class QueryEvent:
    t: float
    edge_id: str
    qa: QAPair


class WorkloadGenerator:
    """Each edge has a drifting Dirichlet interest vector over topics."""

    def __init__(self, corpus: Corpus, cfg: Optional[WorkloadConfig] = None,
                 seed: int = 0):
        self.corpus = corpus
        self.cfg = WorkloadConfig() if cfg is None else cfg
        self.rng = np.random.default_rng(seed)
        self.edge_ids = [f"edge{i}" for i in range(self.cfg.n_edges)]
        self.qa_by_topic: Dict[str, List[QAPair]] = {}
        for qa in corpus.qa:
            self.qa_by_topic.setdefault(qa.topic, []).append(qa)
        self.topics = [t for t in corpus.topics if t in self.qa_by_topic]
        self._interest = {e: self._draw_interest() for e in self.edge_ids}
        self._last_drift = 0.0

    def _draw_interest(self) -> np.ndarray:
        k = len(self.topics)
        return self.rng.dirichlet(np.full(k, self.cfg.concentration))

    def _maybe_drift(self, t: float):
        if t - self._last_drift >= self.cfg.drift_period:
            self._last_drift = t
            s = self.cfg.drift_strength
            for e in self.edge_ids:
                fresh = self._draw_interest()
                self._interest[e] = (1 - s) * self._interest[e] + s * fresh
                self._interest[e] /= self._interest[e].sum()

    def interest(self, edge_id: str) -> np.ndarray:
        return self._interest[edge_id]

    def popular_topics(self, edge_id: str, k: int = 2) -> List[str]:
        order = np.argsort(-self._interest[edge_id])[:k]
        return [self.topics[int(i)] for i in order]

    def _draw_event(self, t: float) -> QueryEvent:
        edge = self.edge_ids[int(self.rng.integers(len(self.edge_ids)))]
        p = self._interest[edge]
        b = self.cfg.hot_topic_boost
        if b > 0:
            p = p.copy()
            p[int(np.argmax(p))] += b
            p = p / p.sum()
        topic = self.topics[int(self.rng.choice(len(self.topics), p=p))]
        qa_list = self.qa_by_topic[topic]
        qa = qa_list[int(self.rng.integers(len(qa_list)))]
        return QueryEvent(float(t), edge, qa)

    def stream(self, n_steps: int) -> Iterator[QueryEvent]:
        for t in range(n_steps):
            self._maybe_drift(float(t))
            yield self._draw_event(float(t))

    def bursts(self, n_steps: int,
               clock: Optional[Callable[[], float]] = None
               ) -> Iterator[List[QueryEvent]]:
        """Bursty multi-user arrivals: per step, ``K ~ Poisson(
        mean_arrivals)`` (capped at ``max_arrivals``) queries arrive
        together, stamped at ``clock()`` when a clock is injected (step
        index otherwise). Steps may be empty — real traffic has gaps."""
        for step in range(n_steps):
            t = float(clock()) if clock is not None else float(step)
            self._maybe_drift(t)
            k = int(min(self.rng.poisson(self.cfg.mean_arrivals),
                        self.cfg.max_arrivals))
            yield [self._draw_event(t) for _ in range(k)]


__all__ = ["WorkloadGenerator", "WorkloadConfig", "QueryEvent"]
