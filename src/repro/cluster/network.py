"""Edge-cloud network delay model: lognormal jitter around tier baselines
plus slowly-varying congestion (the gate's d_t context)."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict

import numpy as np


@dataclass
class NetworkConfig:
    edge_local_ms: float = 20.0
    inter_edge_ms: float = 32.0
    cloud_ms: float = 300.0
    jitter_sigma: float = 0.25          # lognormal sigma
    congestion_period: float = 400.0    # steps per congestion cycle
    congestion_amp: float = 0.5         # peak multiplier-1 on cloud path


class NetworkModel:
    def __init__(self, cfg: "NetworkConfig | None" = None, seed: int = 0):
        # default built per instance: a module-level default evaluated at
        # ``def`` time would be shared (and mutable) across every caller
        self.cfg = NetworkConfig() if cfg is None else cfg
        self.rng = np.random.default_rng(seed)

    def _jit(self, base_ms: float) -> float:
        return base_ms * float(self.rng.lognormal(0.0, self.cfg.jitter_sigma))

    def edge_local(self, t: float = 0.0) -> float:
        return self._jit(self.cfg.edge_local_ms) / 1000.0

    def inter_edge(self, t: float = 0.0) -> float:
        return self._jit(self.cfg.inter_edge_ms) / 1000.0

    def cloud(self, t: float = 0.0) -> float:
        cong = 1.0 + self.cfg.congestion_amp * 0.5 * (
            1.0 + math.sin(2 * math.pi * t / self.cfg.congestion_period))
        return self._jit(self.cfg.cloud_ms * cong) / 1000.0


__all__ = ["NetworkModel", "NetworkConfig"]
