"""Answer-quality oracle (DESIGN.md §5: the calibrated simulation boundary).

Accuracy per (strategy, query) is a Bernoulli draw whose probability depends
on (a) the serving arm's model capacity, (b) whether retrieval actually
surfaced the gold fact (computed from the real retrieved chunks), and
(c) query complexity. Defaults are calibrated so population marginals match
the paper's Table 4 (3B-only ~29-32%, +NaiveRAG ~52-62%, +GraphRAG ~63-76%,
72B+GraphRAG ~77-94%).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

import numpy as np


@dataclass(frozen=True)
class ArmQuality:
    p_hit: float            # retrieval surfaced the gold fact
    p_miss: float           # it did not (parametric knowledge only)
    multihop_factor: float  # multiplicative penalty on multi-hop queries


# calibrated to Table 4 marginals given typical hit rates in our corpus.
# Note the structure that makes the gate's job non-trivial AND solvable:
# conditional on (retrieval hit, single-hop) the cheap arms are highly
# accurate (>=0.93), while misses and multi-hop queries drag their
# *marginal* accuracy down to the paper's 52-76% band.
DEFAULT_QUALITY: Dict[str, ArmQuality] = {
    "slm-only":      ArmQuality(0.34, 0.34, 0.55),
    "edge-rag+slm":  ArmQuality(0.97, 0.20, 0.42),
    "graphrag+slm":  ArmQuality(0.96, 0.30, 0.75),
    "graphrag+llm":  ArmQuality(0.985, 0.72, 0.92),
}


class AccuracyOracle:
    def __init__(self, quality: Dict[str, ArmQuality] = None, seed: int = 0):
        self.quality = dict(DEFAULT_QUALITY)
        if quality:
            self.quality.update(quality)
        self.rng = np.random.default_rng(seed)

    def p_correct(self, arm_name: str, *, hit: bool, multihop: bool) -> float:
        q = self.quality[arm_name]
        p = q.p_hit if hit else q.p_miss
        if multihop:
            p *= q.multihop_factor
        return min(max(p, 0.0), 1.0)

    def draw(self, arm_name: str, *, hit: bool, multihop: bool) -> bool:
        return bool(self.rng.random() < self.p_correct(
            arm_name, hit=hit, multihop=multihop))


__all__ = ["AccuracyOracle", "ArmQuality", "DEFAULT_QUALITY"]
