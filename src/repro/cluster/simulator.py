"""The EACO-RAG tiered serving simulator: real retrieval + gating + adaptive
knowledge updates over an edge-cloud topology, with the calibrated accuracy
oracle (DESIGN.md §5) and the paper's cost model.

Policies: "eaco" (collaborative gate) or "fixed:<arm_idx>" baselines —
fixed:0 = SLM-only, fixed:1 = naive edge RAG, fixed:2 = 3B+GraphRAG,
fixed:3 = 72B+GraphRAG (the paper's Table 4 rows).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost_model import (
    PAPER_CLOUD, PAPER_EDGE, RETRIEVAL_DELAY_S, CostWeights, TierSpec,
    generation_delay, inference_tflops, time_cost_tflops, total_cost,
)
from repro.core.edge_assist import edge_assisted_search, query_keywords, select_edge
from repro.core.gating import (
    PAPER_ARMS, Arm, CollaborativeGate, Decision, QueryContext,
)
from repro.core.knowledge import AdaptiveKnowledgeUpdater, KnowledgeUpdateConfig
from repro.cluster.network import NetworkConfig, NetworkModel
from repro.cluster.oracle import AccuracyOracle
from repro.cluster.workload import QueryEvent, WorkloadConfig, WorkloadGenerator
from repro.data.corpus import Corpus
from repro.retrieval.graph_rag import KnowledgeGraph
from repro.retrieval.store import VectorStore

# calibration: the paper uses ~500-token chunks; our synthetic chunks are
# ~95 tokens, so prompt sizes are scaled to match Table 1 token statistics.
# The cloud LLM receives a summarized GraphRAG context (the paper's 72B
# prompt is ~4.8k tokens by its cost arithmetic, vs ~9k for the 3B path).
PROMPT_SCALE = {("none", "local"): 1.0, ("edge", "local"): 7.0,
                ("graph", "local"): 8.0, ("graph", "cloud"): 4.4}
OUT_TOKENS = {  # Table 1 output-token distributions (mean, std)
    ("none", "local"): (27.21, 14.83),
    ("edge", "local"): (26.59, 19.81),
    ("graph", "local"): (142.7, 91.58),
    ("graph", "cloud"): (142.7, 91.58),
}


def _count_tokens(text: str) -> float:
    return len(text.split()) * 1.3


@dataclass
class StepLog:
    t: float
    edge_id: str
    arm: int
    arm_name: str
    correct: bool
    delay: float
    cost: float
    u_r: float
    u_d: float
    hit: bool
    overlap: float
    multihop: bool
    in_tokens: float
    out_tokens: float
    phase: str = ""


@dataclass
class SimConfig:
    n_edges: int = 6
    edge_capacity: int = 1000
    retrieval_k: int = 5
    graph_retrieval_k: int = 10
    qos_min_acc: float = 0.9
    qos_max_delay: float = 5.0
    warmup_steps: int = 300
    beta: float = 2.0
    delta1: float = 1.0
    delta2: float = 1.0
    update_trigger: int = 20
    max_chunks_per_update: int = 500
    initial_fill: float = 0.4       # fraction of capacity pre-seeded
    drift_period: float = 250.0
    edge_assist_enabled: bool = True   # False = local-store-only (Fig. 4)
    seed: int = 0


class EACOCluster:
    def __init__(self, corpus: Corpus, cfg: SimConfig = SimConfig(),
                 policy: str = "eaco",
                 edge_tier: TierSpec = PAPER_EDGE,
                 cloud_tier: TierSpec = PAPER_CLOUD,
                 oracle: Optional[AccuracyOracle] = None):
        self.corpus = corpus
        self.cfg = cfg
        self.policy = policy
        self.edge_tier = edge_tier
        self.cloud_tier = cloud_tier
        self.weights = CostWeights(cfg.delta1, cfg.delta2)
        self.rng = np.random.default_rng(cfg.seed)
        self.oracle = oracle or AccuracyOracle(seed=cfg.seed + 1)
        self.net = NetworkModel(seed=cfg.seed + 2)
        self.workload = WorkloadGenerator(
            corpus, WorkloadConfig(n_edges=cfg.n_edges,
                                   drift_period=cfg.drift_period),
            seed=cfg.seed + 3)
        # cloud knowledge graph over the full corpus
        self.graph = KnowledgeGraph(seed=cfg.seed).build(corpus.chunks)
        self.updater = AdaptiveKnowledgeUpdater(
            self.graph, KnowledgeUpdateConfig(
                update_trigger=cfg.update_trigger,
                max_chunks_per_update=cfg.max_chunks_per_update))
        # edge stores seeded with their initially-popular topics
        self.stores: Dict[str, VectorStore] = {}
        for eid in self.workload.edge_ids:
            store = VectorStore(capacity=cfg.edge_capacity)
            budget = int(cfg.edge_capacity * cfg.initial_fill)
            got: List = []
            for topic in self.workload.popular_topics(eid, k=3):
                got.extend(corpus.chunks_for_topic(topic))
            store.add(got[:budget])
            self.stores[eid] = store
        self.gate = CollaborativeGate(
            qos_min_acc=cfg.qos_min_acc, qos_max_delay=cfg.qos_max_delay,
            warmup_steps=cfg.warmup_steps, beta=cfg.beta, seed=cfg.seed,
            n_edges=cfg.n_edges)
        self.logs: List[StepLog] = []

    # ------------------------------------------------------------------
    def _retrieve(self, arm: Arm, ev: QueryEvent):
        """Real retrieval for the chosen source. Returns (texts, hit, sel)."""
        q = ev.qa.question
        if arm.retrieval == "none":
            return [], False, None
        if arm.retrieval == "edge":
            if self.cfg.edge_assist_enabled:
                results, sel = edge_assisted_search(
                    self.stores, q, self.cfg.retrieval_k,
                    local_edge=ev.edge_id)
            else:  # ablation: only the local edge dataset
                results = self.stores[ev.edge_id].search(
                    q, self.cfg.retrieval_k)
                sel = None
            texts = [c.text for c, _ in results]
        else:  # cloud GraphRAG
            results = self.graph.retrieve(q, self.cfg.graph_retrieval_k)
            texts = [c.text for c, _ in results]
            sel = None
        hit = any(ev.qa.answer in t for t in texts)
        return texts, hit, sel

    def _tokens(self, arm: Arm, query: str, texts: List[str]):
        in_t = _count_tokens(query)
        in_t += (sum(_count_tokens(t) for t in texts)
                 * PROMPT_SCALE[(arm.retrieval, arm.generation)])
        mu, sd = OUT_TOKENS[(arm.retrieval, arm.generation)]
        out_t = max(1.0, float(self.rng.normal(mu, sd)))
        return in_t, out_t

    def _execute(self, arm: Arm, ev: QueryEvent, qc: QueryContext,
                 texts: List[str], hit: bool) -> StepLog:
        in_t, out_t = self._tokens(arm, ev.qa.question, texts)
        if arm.generation == "local":
            tier = self.edge_tier
            net_delay = qc.d_edge if arm.retrieval == "edge" else 0.005
            if arm.retrieval == "graph":
                net_delay += qc.d_cloud          # fetch context from cloud
        else:
            tier = self.cloud_tier
            net_delay = qc.d_cloud
        net_delay += RETRIEVAL_DELAY_S[(arm.retrieval, arm.generation)]
        delay = generation_delay(tier, in_t, out_t, net_delay)
        u_r = inference_tflops(tier.model_params_b, in_t, out_t)
        u_d = time_cost_tflops(tier, delay)
        cost = total_cost(u_r, u_d, self.weights)
        correct = self.oracle.draw(arm.name, hit=hit, multihop=ev.qa.multihop)
        return StepLog(
            t=ev.t, edge_id=ev.edge_id, arm=arm.idx, arm_name=arm.name,
            correct=correct, delay=delay, cost=cost, u_r=u_r, u_d=u_d,
            hit=hit, overlap=qc.overlap, multihop=ev.qa.multihop,
            in_tokens=in_t, out_tokens=out_t)

    def _context(self, ev: QueryEvent) -> QueryContext:
        sel = select_edge(self.stores, ev.qa.question, local_edge=ev.edge_id)
        d_cloud = self.net.cloud(ev.t)
        d_edge = (self.net.edge_local(ev.t) if sel.edge_id == ev.edge_id
                  else self.net.inter_edge(ev.t))
        edge_index = self.workload.edge_ids.index(sel.edge_id) \
            if sel.edge_id in self.workload.edge_ids else 0
        return QueryContext.analyze(ev.qa.question, d_cloud, d_edge,
                                    sel.overlap, sel.edge_id, edge_index)

    def step(self, ev: QueryEvent) -> StepLog:
        qc = self._context(ev)
        if self.policy == "eaco":
            decision = self.gate.decide(qc)
            arm = decision.arm
            phase = decision.info.get("phase", "")
        else:
            arm = PAPER_ARMS[int(self.policy.split(":")[1])]
            phase = "fixed"
        texts, hit, _ = self._retrieve(arm, ev)
        log = self._execute(arm, ev, qc, texts, hit)
        log.phase = phase
        if self.policy == "eaco":
            self.gate.update(qc, arm, cost=log.cost,
                             accuracy=1.0 if log.correct else 0.0,
                             delay=log.delay)
        # adaptive knowledge update: cloud observes all served queries
        self.updater.observe_query(ev.edge_id, ev.qa.question,
                                   self.stores[ev.edge_id], now=ev.t)
        self.logs.append(log)
        return log

    def run(self, n_steps: int) -> List[StepLog]:
        for ev in self.workload.stream(n_steps):
            self.step(ev)
        return self.logs

    # ------------------------------------------------------------------
    def metrics(self, skip_warmup: bool = True) -> Dict[str, float]:
        logs = self.logs
        if skip_warmup and self.policy == "eaco":
            logs = [l for l in logs if l.phase != "warmup"]
        if not logs:
            return {}
        acc = float(np.mean([l.correct for l in logs]))
        return {
            "n": len(logs),
            "accuracy": acc,
            "delay_mean": float(np.mean([l.delay for l in logs])),
            "delay_std": float(np.std([l.delay for l in logs])),
            "cost_mean": float(np.mean([l.cost for l in logs])),
            "cost_std": float(np.std([l.cost for l in logs])),
            "u_r_mean": float(np.mean([l.u_r for l in logs])),
            "u_d_mean": float(np.mean([l.u_d for l in logs])),
            "hit_rate": float(np.mean([l.hit for l in logs])),
            "arm_fracs": [float(np.mean([l.arm == a for l in logs]))
                          for a in range(4)],
            "in_tokens_mean": float(np.mean([l.in_tokens for l in logs])),
            "out_tokens_mean": float(np.mean([l.out_tokens for l in logs])),
        }


__all__ = ["EACOCluster", "SimConfig", "StepLog"]
