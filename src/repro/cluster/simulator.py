"""The EACO-RAG tiered serving simulator: real retrieval + gating + adaptive
knowledge updates over an edge-cloud topology.

Two backends:

* ``backend="oracle"`` (default) — the calibrated accuracy oracle
  (DESIGN.md §5) plus the paper's cost model score each gate decision
  analytically; token counts are drawn from Table 1 distributions. This is
  the fast path used by the Table 4/5/6 benchmarks.

* ``backend="engines"`` — the closed loop. Every gate ``Decision`` builds
  the real prompt (query + retrieved context from the edge stores /
  GraphRAG) and submits it through a :class:`TierScheduler` to per-tier
  :class:`ServingEngine` pools: edge SLM engines (reduced qwen2-0.5b,
  paged KV + prefix cache on) and one larger cloud-tier engine (reduced
  qwen2-72b family). Arrivals are bursty multi-user
  (``WorkloadGenerator.bursts``), and everything — arrival stamps, queue
  waits, engine service time, network transit — composes on ONE
  :class:`VirtualClock`: per scheduling round the clock advances by the
  engines' service time, either ``engine_time="modeled"`` (the tier spec's
  prefill/decode rates applied to the REAL token counts the engines
  processed — deterministic under a fixed seed) or ``"wall"`` (the
  measured jit compute time). Completions flow back as measured delay
  (queue wait + time in engine + network transit) and real token counts
  feeding the cost model and the gate's SafeOBO update — replacing the
  drawn ``OUT_TOKENS``.

Policies: "eaco" (collaborative gate) or "fixed:<arm_idx>" baselines —
fixed:0 = SLM-only, fixed:1 = naive edge RAG, fixed:2 = 3B+GraphRAG,
fixed:3 = 72B+GraphRAG (the paper's Table 4 rows).

**Overload robustness (engines backend).** The failover/escalation state
machine sits above the scheduler's preempt/shed/timeout machinery
(:mod:`repro.serving.scheduler`):

* *watermark escalation* — an edge-bound query arriving while the edge
  pool's saturation is at/above ``overload_watermark`` is routed straight
  to the cloud tier (``failed_over`` counter, ``StepLog.rerouted``), and
  ``_finalize`` prices it with the CLOUD tier spec + cloud transit, so the
  cost model and the SafeOBO update see the TRUE cost/delay of the
  re-route, not the arm's nominal tier.
* *retry with bounded exponential backoff* — a scheduler ``Shed``
  (deadline / timeout / overload) or a completion dropped in transit
  (:class:`~repro.cluster.faults.FaultInjector`) re-submits the query —
  edge failures escalate to cloud — after ``failover_backoff_s * 2**n``
  (capped), with a fresh deadline. After ``failover_max_retries``
  resubmissions the query is terminal: ``outcome="shed"`` (gave up on a
  scheduler shed) or ``"failed"`` (lost completion), logged with zero
  cost and ``correct=False``, never silently dropped.
* *conservation* — ``submitted == completed + shed + failed`` over the
  counters, with nothing left pending; :meth:`EACOCluster.conservation_ok`
  checks it and ``benchmarks/cluster_bench.py --check`` gates on it.
* the gate learns only from SERVED completions; terminal drops surface in
  counters/metrics instead of feeding SafeOBO a synthetic reward.

**Hard-failure model (engines backend).** Crashes, partitions, and the
health machinery that keeps the loop serving through them:

* *engine crashes* — ``FaultInjector.crashed`` windows call
  :meth:`ServingEngine.crash` on entry (ALL device state lost: slots,
  arena, prefix index) and :meth:`restart` on exit (cold engine, bumped
  ``engine_generation``). The scheduler is built with
  ``requeue_lost=False`` here, so reaped residents surface as typed
  ``Shed("engine_lost")`` outcomes and flow through the SAME failover
  path as any other shed — bounded backoff, edge->cloud escalation,
  typed terminal outcomes — preserving request conservation. Only
  schedule-driven crashes are schedule-restarted; an engine a test
  crashed by hand stays down.
* *circuit breakers* — two layers. Per-ENGINE breakers inside the
  scheduler (``engine_breaker_threshold``) stop admission onto a flaky
  pool member. Per-TIER breakers here (``breaker_threshold``) gate
  routing: a query bound for a tier whose breaker is open is rerouted to
  the other tier (``breaker_reroutes``), tier failures/successes feed
  the breaker from ``_handle_failure``/``_finalize``.
* *hedging* (``hedge_s``) — the scheduler fires an edge->cloud backup
  for interactive requests past the latency threshold; first completion
  wins. A hedged completion served by the cloud pays cloud transit on
  top of its route (``_finalize``), and hedges are gated off while the
  link is partitioned.
* *partitions* — while ``FaultInjector.partitioned`` holds: the gate's
  arm-availability mask excludes cloud-dependent arms (cloud generation
  AND GraphRAG retrieval), failover retries stay on the edge instead of
  escalating, hedges don't fire, and knowledge updates DEFER (epoch
  advances, nothing ships). Edges keep serving from their last-synced
  chunk set; edge-RAG completions from a store behind the newest epoch
  are flagged ``stale_epoch`` — degraded, never silent. On heal,
  anti-entropy (:meth:`AdaptiveKnowledgeUpdater.sync`) replays deferred
  refreshes and invalidates edge prefix caches. In-flight cloud work
  completes across a partition onset (the link model covers the
  control-plane update path, not queued generations), and fixed:<arm>
  baseline policies ignore the mask — they are the paper's
  non-adaptive comparison points.

**Fault model and deterministic simulation testing.** The full fault
vocabulary above is represented as explicit event timelines
(:class:`~repro.cluster.faults.FaultEvent`): every fault is a record
``(t, kind, duration, victim, magnitude)`` with
``kind in {"stall", "crash", "partition", "net_spike", "drop"}``, active
on the half-open virtual-time window ``[t, t + duration)``. The periodic
``FaultConfig`` formulas used by the hand-authored chaos cases lazily
expand into the same records, so a hand schedule and a fuzzer schedule
are the same object — replayable, serializable, shrinkable.

:mod:`repro.cluster.dst` builds FoundationDB-style deterministic
simulation testing on top: a seeded generator composes overlapping fault
+ workload timelines (arrival bursts, knowledge-update bursts, SLO-mix
shifts on top of the five fault kinds), a harness drives real engine
pools + scheduler + knowledge updater through them on one virtual clock,
and after EVERY pump re-checks the invariant oracles — request
conservation, generation-fence legality, breaker state-machine legality,
monotone knowledge epochs with no unflagged ``stale_epoch`` completion,
page-arena audit (free + cached + active == num_pages; refcount == slot
mappings; zero leaks at quiescence), and greedy token identity for
resumed/hedged work. Failures record a JSON trace that replays
byte-identically and ddmin-shrinks to a minimal event schedule
(``make fuzz`` / ``benchmarks/dst_bench.py``).

All knobs default off (no shedding, no timeout, no watermark, no faults,
no breakers, no hedging), which reproduces the pre-overload closed loop
exactly.
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.clock import VirtualClock
from repro.core.cost_model import (
    PAPER_CLOUD, PAPER_EDGE, RETRIEVAL_DELAY_S, CostWeights, TierSpec,
    generation_delay, inference_tflops, modeled_decode_round_s,
    modeled_prefill_s, time_cost_tflops, total_cost,
)
from repro.core.edge_assist import edge_assisted_search, query_keywords, select_edge
from repro.core.gating import (
    PAPER_ARMS, Arm, CollaborativeGate, Decision, QueryContext,
)
from repro.core.knowledge import AdaptiveKnowledgeUpdater, KnowledgeUpdateConfig
from repro.cluster.faults import FaultConfig, FaultInjector
from repro.cluster.network import NetworkConfig, NetworkModel
from repro.cluster.oracle import AccuracyOracle
from repro.cluster.workload import QueryEvent, WorkloadConfig, WorkloadGenerator
from repro.data.corpus import Corpus
from repro.retrieval.graph_rag import KnowledgeGraph
from repro.retrieval.store import VectorStore
from repro.serving.engine import (
    Request, ServingEngine, make_cloud_engine, make_edge_engine,
)
from repro.serving.health import CircuitBreaker
from repro.serving.scheduler import Completion, TierScheduler

# calibration: the paper uses ~500-token chunks; our synthetic chunks are
# ~95 tokens, so prompt sizes are scaled to match Table 1 token statistics.
# The cloud LLM receives a summarized GraphRAG context (the paper's 72B
# prompt is ~4.8k tokens by its cost arithmetic, vs ~9k for the 3B path).
PROMPT_SCALE = {("none", "local"): 1.0, ("edge", "local"): 7.0,
                ("graph", "local"): 8.0, ("graph", "cloud"): 4.4}
OUT_TOKENS = {  # Table 1 output-token distributions (mean, std)
    ("none", "local"): (27.21, 14.83),
    ("edge", "local"): (26.59, 19.81),
    ("graph", "local"): (142.7, 91.58),
    ("graph", "cloud"): (142.7, 91.58),
}


def _count_tokens(text: str) -> float:
    return len(text.split()) * 1.3


@dataclass
class StepLog:
    t: float
    edge_id: str
    arm: int
    arm_name: str
    correct: bool
    delay: float
    cost: float
    u_r: float
    u_d: float
    hit: bool
    overlap: float
    multihop: bool
    in_tokens: float
    out_tokens: float
    phase: str = ""
    retrieved: List[str] = field(default_factory=list)
    tier: str = ""                  # engines backend: serving tier name
    queue_wait_s: float = 0.0       # engines backend: submit -> admission
    engine_s: float = 0.0           # engines backend: admission -> finish
    outcome: str = "ok"             # "ok" | "shed" | "failed" (terminal)
    slo: str = "interactive"        # SLO class the query was served under
    rerouted: bool = False          # escalated off its nominal tier
    attempts: int = 0               # failover resubmissions before terminal
    hedged: bool = False            # served by the backup hedge submission
    epoch: int = 0                  # serving edge's knowledge epoch
    stale_epoch: bool = False       # edge-RAG answer from a stale epoch


@dataclass
class SimConfig:
    n_edges: int = 6
    edge_capacity: int = 1000
    retrieval_k: int = 5
    graph_retrieval_k: int = 10
    qos_min_acc: float = 0.9
    qos_max_delay: float = 5.0
    warmup_steps: int = 300
    beta: float = 2.0
    delta1: float = 1.0
    delta2: float = 1.0
    update_trigger: int = 20
    max_chunks_per_update: int = 500
    initial_fill: float = 0.4       # fraction of capacity pre-seeded
    drift_period: float = 250.0
    edge_assist_enabled: bool = True   # False = local-store-only (Fig. 4)
    seed: int = 0
    # ---- engines backend (backend="engines") --------------------------
    n_edge_engines: int = 2         # pool size behind the "edge" tier
    edge_max_seq: int = 192
    edge_max_batch: int = 4
    cloud_max_seq: int = 256
    cloud_max_batch: int = 4
    engine_page_size: int = 16
    # fused chunked-prefill + decode (None = whole-suffix admission). The
    # virtual-clock pricing below needs no change: decode_rounds / prefill
    # token deltas stay additive under chunking (modeled_mixed_step_s)
    engine_step_token_budget: Optional[int] = None
    engine_prefill_chunk: int = 32
    max_new_slm: int = 16           # decode budget, non-graph arms
    max_new_graph: int = 48         # decode budget, GraphRAG arms
    arrival_period_s: float = 1.0   # virtual seconds between arrival steps
    engine_time: str = "modeled"    # "modeled" (deterministic) | "wall"
    mean_arrivals: float = 1.5      # Poisson mean queries per arrival step
    max_arrivals: int = 6           # burst cap per step
    hot_topic_boost: float = 0.0    # extra interest mass on the hot topic
    # ---- overload robustness (all off by default = pre-overload loop) --
    preemption: bool = True         # scheduler may reclaim residents (only
    #                                 fires across SLO classes, see scheduler)
    shed_overdue: bool = False      # shed queued work past its deadline
    request_timeout_s: Optional[float] = None   # stuck-resident timeout
    overload_watermark: Optional[float] = None  # edge saturation -> cloud
    failover_max_retries: int = 2   # resubmissions before terminal drop
    failover_backoff_s: float = 0.25            # base of 2**n backoff
    failover_backoff_cap_s: float = 2.0
    drain_timeout_s: float = 300.0  # virtual-s wedge guard while draining
    stall_tick_s: float = 0.05      # idle clock step when faults stall all
    # ---- hard failures / health (all off by default) -------------------
    engine_breaker_threshold: Optional[int] = None  # scheduler per-engine
    breaker_threshold: Optional[int] = None         # cluster per-tier
    breaker_reset_s: float = 5.0    # open -> half-open probe delay
    hedge_s: Optional[float] = None  # edge->cloud hedge after this wait


@dataclass
class _Pending:
    """Host-side record of a submitted query, joined to its Completion (or
    carried through failover resubmissions until a terminal outcome)."""
    ev: QueryEvent
    qc: QueryContext
    arm: Arm
    hit: bool
    texts: List[str]
    net_delay_s: float
    phase: str
    request: Request
    tier_name: str = "edge"         # tier currently serving the query
    attempts: int = 0               # resubmissions so far
    rerouted: bool = False          # ever escalated off the nominal tier
    last_reason: str = ""           # last failure reason ("" = none)


class EACOCluster:
    def __init__(self, corpus: Corpus, cfg: Optional[SimConfig] = None,
                 policy: str = "eaco",
                 edge_tier: TierSpec = PAPER_EDGE,
                 cloud_tier: TierSpec = PAPER_CLOUD,
                 oracle: Optional[AccuracyOracle] = None,
                 backend: str = "oracle",
                 engines: Optional[Dict[str, Union[
                     ServingEngine, Sequence[ServingEngine]]]] = None,
                 clock: Optional[VirtualClock] = None,
                 faults: Optional[FaultInjector] = None):
        self.corpus = corpus
        # default built per instance — a shared default SimConfig would let
        # one caller's mutation leak into every later default construction
        self.cfg = cfg = SimConfig() if cfg is None else cfg
        self.policy = policy
        self.edge_tier = edge_tier
        self.cloud_tier = cloud_tier
        if backend not in ("oracle", "engines"):
            raise ValueError(f"unknown backend {backend!r}")
        if cfg.engine_time not in ("modeled", "wall"):
            raise ValueError(f"unknown engine_time {cfg.engine_time!r}")
        self.backend = backend
        self.weights = CostWeights(cfg.delta1, cfg.delta2)
        self.rng = np.random.default_rng(cfg.seed)
        self.oracle = oracle or AccuracyOracle(seed=cfg.seed + 1)
        self.net = NetworkModel(seed=cfg.seed + 2)
        self.workload = WorkloadGenerator(
            corpus, WorkloadConfig(n_edges=cfg.n_edges,
                                   drift_period=cfg.drift_period,
                                   mean_arrivals=cfg.mean_arrivals,
                                   max_arrivals=cfg.max_arrivals,
                                   hot_topic_boost=cfg.hot_topic_boost),
            seed=cfg.seed + 3)
        # cloud knowledge graph over the full corpus
        self.graph = KnowledgeGraph(seed=cfg.seed).build(corpus.chunks)
        self.updater = AdaptiveKnowledgeUpdater(
            self.graph, KnowledgeUpdateConfig(
                update_trigger=cfg.update_trigger,
                max_chunks_per_update=cfg.max_chunks_per_update))
        # edge stores seeded with their initially-popular topics
        self.stores: Dict[str, VectorStore] = {}
        for eid in self.workload.edge_ids:
            store = VectorStore(capacity=cfg.edge_capacity)
            budget = int(cfg.edge_capacity * cfg.initial_fill)
            got: List = []
            for topic in self.workload.popular_topics(eid, k=3):
                got.extend(corpus.chunks_for_topic(topic))
            store.add(got[:budget])
            self.stores[eid] = store
        self.gate = CollaborativeGate(
            qos_min_acc=cfg.qos_min_acc, qos_max_delay=cfg.qos_max_delay,
            warmup_steps=cfg.warmup_steps, beta=cfg.beta, seed=cfg.seed,
            n_edges=cfg.n_edges)
        self.logs: List[StepLog] = []
        # ---- engines backend: one virtual clock, real engine pools -----
        self.clock = VirtualClock() if clock is None else clock
        self.sched: Optional[TierScheduler] = None
        self.faults = faults
        self._pending: Dict[int, _Pending] = {}
        # failover retry queue: (ready_at, seq, pending) — resubmitted once
        # the virtual clock passes ready_at (bounded exponential backoff)
        self._retries: List[Tuple[float, int, _Pending]] = []
        self._retry_seq = itertools.count()
        # request-conservation ledger: submitted == completed + shed +
        # failed once nothing is outstanding (see conservation_ok)
        self.counters: Dict[str, int] = {
            "submitted": 0, "completed": 0, "shed": 0, "failed": 0,
            "failed_over": 0, "retries": 0, "dropped_completions": 0,
            "prefix_invalidations": 0, "engine_crashes": 0,
            "engine_restarts": 0, "breaker_reroutes": 0,
            "anti_entropy_syncs": 0, "hedged_served": 0,
            "stale_served": 0}
        # ---- hard-failure state ----------------------------------------
        self._link_down = False           # edge<->cloud partition active
        self._fault_crashed: set = set()  # (tier, i) crashed BY the schedule
        self.tier_breakers: Dict[str, CircuitBreaker] = {}
        if backend == "engines" and cfg.breaker_threshold is not None:
            self.tier_breakers = {
                t: CircuitBreaker(cfg.breaker_threshold, cfg.breaker_reset_s)
                for t in ("edge", "cloud")}
        if backend == "engines":
            if engines is None:
                engines = self.build_engines()
            self.sched = TierScheduler(
                engines, clock=self.clock, preempt=cfg.preemption,
                shed_overdue=cfg.shed_overdue,
                request_timeout_s=cfg.request_timeout_s,
                # crashes surface as typed engine_lost sheds so the
                # cluster's failover (backoff + escalation) owns recovery
                requeue_lost=False,
                breaker_threshold=cfg.engine_breaker_threshold,
                breaker_reset_s=cfg.breaker_reset_s,
                hedge_s=cfg.hedge_s, hedge_from="edge", hedge_to="cloud",
                hedge_gate=lambda now: not self._link_down)
            if set(self.sched.pools) != {"edge", "cloud"}:
                raise ValueError(
                    f"engines backend needs 'edge' and 'cloud' tiers, got "
                    f"{sorted(self.sched.pools)}")

    # ------------------------------------------------------------------
    def build_engines(self) -> Dict[str, List[ServingEngine]]:
        """Default tier pools: ``n_edge_engines`` reduced-SLM edge engines
        plus one cloud-tier engine, paged KV + prefix cache on."""
        c = self.cfg
        fused = dict(step_token_budget=c.engine_step_token_budget,
                     prefill_chunk=c.engine_prefill_chunk)
        edge = [make_edge_engine(
            max_seq=c.edge_max_seq, max_batch=c.edge_max_batch,
            seed=c.seed + 100 + i, kv_layout="paged",
            page_size=c.engine_page_size, prefix_cache=True, **fused)
            for i in range(c.n_edge_engines)]
        cloud = [make_cloud_engine(
            max_seq=c.cloud_max_seq, max_batch=c.cloud_max_batch,
            seed=c.seed + 200, kv_layout="paged",
            page_size=c.engine_page_size, prefix_cache=True, **fused)]
        return {"edge": edge, "cloud": cloud}

    # ------------------------------------------------------------------
    def _retrieve(self, arm: Arm, ev: QueryEvent):
        """Real retrieval for the chosen source. Returns (texts, hit, sel)."""
        q = ev.qa.question
        if arm.retrieval == "none":
            return [], False, None
        if arm.retrieval == "edge":
            if self.cfg.edge_assist_enabled:
                results, sel = edge_assisted_search(
                    self.stores, q, self.cfg.retrieval_k,
                    local_edge=ev.edge_id)
            else:  # ablation: only the local edge dataset
                results = self.stores[ev.edge_id].search(
                    q, self.cfg.retrieval_k)
                sel = None
            texts = [c.text for c, _ in results]
        else:  # cloud GraphRAG
            results = self.graph.retrieve(q, self.cfg.graph_retrieval_k)
            texts = [c.text for c, _ in results]
            sel = None
        hit = any(ev.qa.answer in t for t in texts)
        return texts, hit, sel

    def _tokens(self, arm: Arm, query: str, texts: List[str]):
        in_t = _count_tokens(query)
        in_t += (sum(_count_tokens(t) for t in texts)
                 * PROMPT_SCALE[(arm.retrieval, arm.generation)])
        mu, sd = OUT_TOKENS[(arm.retrieval, arm.generation)]
        out_t = max(1.0, float(self.rng.normal(mu, sd)))
        return in_t, out_t

    def _tier_and_net(self, arm: Arm, qc: QueryContext
                      ) -> Tuple[TierSpec, float]:
        """Serving tier spec + network transit delay for an (arm, context)."""
        if arm.generation == "local":
            tier = self.edge_tier
            net_delay = qc.d_edge if arm.retrieval == "edge" else 0.005
            if arm.retrieval == "graph":
                net_delay += qc.d_cloud          # fetch context from cloud
        else:
            tier = self.cloud_tier
            net_delay = qc.d_cloud
        net_delay += RETRIEVAL_DELAY_S[(arm.retrieval, arm.generation)]
        return tier, net_delay

    def _execute(self, arm: Arm, ev: QueryEvent, qc: QueryContext,
                 texts: List[str], hit: bool) -> StepLog:
        in_t, out_t = self._tokens(arm, ev.qa.question, texts)
        tier, net_delay = self._tier_and_net(arm, qc)
        delay = generation_delay(tier, in_t, out_t, net_delay)
        u_r = inference_tflops(tier.model_params_b, in_t, out_t)
        u_d = time_cost_tflops(tier, delay)
        cost = total_cost(u_r, u_d, self.weights)
        correct = self.oracle.draw(arm.name, hit=hit, multihop=ev.qa.multihop)
        return StepLog(
            t=ev.t, edge_id=ev.edge_id, arm=arm.idx, arm_name=arm.name,
            correct=correct, delay=delay, cost=cost, u_r=u_r, u_d=u_d,
            hit=hit, overlap=qc.overlap, multihop=ev.qa.multihop,
            in_tokens=in_t, out_tokens=out_t, retrieved=texts)

    def _context(self, ev: QueryEvent) -> QueryContext:
        sel = select_edge(self.stores, ev.qa.question, local_edge=ev.edge_id)
        d_cloud = self.net.cloud(ev.t)
        d_edge = (self.net.edge_local(ev.t) if sel.edge_id == ev.edge_id
                  else self.net.inter_edge(ev.t))
        edge_index = self.workload.edge_ids.index(sel.edge_id) \
            if sel.edge_id in self.workload.edge_ids else 0
        return QueryContext.analyze(ev.qa.question, d_cloud, d_edge,
                                    sel.overlap, sel.edge_id, edge_index)

    def _arm_mask(self) -> Optional[Tuple[bool, ...]]:
        """Arm-availability mask from infrastructure health: a partition
        cuts off every cloud-dependent arm (cloud generation and GraphRAG
        retrieval both need the link), an open tier breaker cuts off the
        arms generating on that tier. ``None`` when everything is
        reachable — which keeps the gate's RNG stream bit-identical to a
        fault-free run — or when NOTHING is (no usable mask: serve on the
        nominal route and let failover handle the outcome)."""
        if self.sched is None:
            return None
        now = self.clock.now()
        edge_b = self.tier_breakers.get("edge")
        cloud_b = self.tier_breakers.get("cloud")
        edge_ok = edge_b is None or edge_b.allow(now)
        cloud_ok = cloud_b is None or cloud_b.allow(now)
        mask = []
        for arm in self.gate.arms:
            ok = True
            if self._link_down and (arm.generation == "cloud"
                                    or arm.retrieval == "graph"):
                ok = False
            if arm.generation == "cloud" and not cloud_ok:
                ok = False
            if arm.generation == "local" and not edge_ok:
                ok = False
            mask.append(ok)
        if all(mask) or not any(mask):
            return None
        return tuple(mask)

    def _decide(self, qc: QueryContext) -> Tuple[Arm, str]:
        if self.policy == "eaco":
            decision = self.gate.decide(qc, available=self._arm_mask())
            return decision.arm, decision.info.get("phase", "")
        return PAPER_ARMS[int(self.policy.split(":")[1])], "fixed"

    def step(self, ev: QueryEvent) -> StepLog:
        """Oracle backend: decide, retrieve ONCE, score analytically. The
        retrieved texts ride on ``StepLog.retrieved`` so callers (and the
        engines backend) never need to re-run retrieval."""
        if self.backend == "engines":
            raise RuntimeError(
                "step() is the oracle path; use submit_query()/run() with "
                "backend='engines'")
        qc = self._context(ev)
        arm, phase = self._decide(qc)
        texts, hit, _ = self._retrieve(arm, ev)
        log = self._execute(arm, ev, qc, texts, hit)
        log.phase = phase
        if self.policy == "eaco":
            self.gate.update(qc, arm, cost=log.cost,
                             accuracy=1.0 if log.correct else 0.0,
                             delay=log.delay)
        # adaptive knowledge update: cloud observes all served queries
        self._observe_and_invalidate(ev)
        self.counters["submitted"] += 1
        self.counters["completed"] += 1
        self.logs.append(log)
        return log

    def _observe_and_invalidate(self, ev: QueryEvent) -> None:
        """Feed the adaptive-knowledge updater; when it SHIPS an update
        (rotating the edge's knowledge chunks), every edge engine's prefix
        cache is invalidated so a stale retrieved-context prefix can never
        serve a post-update query — the next same-context prompt recomputes
        against the rotated knowledge."""
        store = self.stores[ev.edge_id]
        epoch_before = store.epoch
        self.updater.observe_query(
            ev.edge_id, ev.qa.question, store, now=ev.t,
            link_up=not self._link_down)
        # invalidate only when chunks actually SHIPPED (epoch advanced);
        # an update deferred behind a partition changes nothing edge-side
        if store.epoch != epoch_before and self.sched is not None:
            for e in self.sched.pools["edge"]:
                if not e.dead:
                    e.invalidate_prefix_cache()
            self.counters["prefix_invalidations"] += 1

    # ------------------------------------------------------------------
    # Engines backend: gate decision -> real engine -> completion -> update
    # ------------------------------------------------------------------
    def _build_prompt(self, ev: QueryEvent, texts: List[str],
                      max_chars: int) -> str:
        """Retrieved context first (shared across same-topic queries, so the
        prefix cache can share its KV pages), question last; the context is
        truncated to leave room for the question and decode budget."""
        qpart = f"Q: {ev.qa.question}\nA:"
        ctx = " ".join(texts)
        ctx_budget = max(max_chars - len(qpart) - 10, 0)
        if ctx and ctx_budget > 0:
            return f"Context: {ctx[:ctx_budget]}\n{qpart}"
        return qpart[:max_chars]

    def submit_query(self, ev: QueryEvent) -> Request:
        """One gate decision routed to a real engine: decide, retrieve,
        build the prompt, submit to the tier's pool on the virtual clock.
        The SafeOBO update happens when the completion surfaces.

        With ``overload_watermark`` set, an edge-bound query arriving while
        the edge pool's saturation is at/above the watermark escalates
        straight to the cloud tier (recorded as a ``failed_over`` re-route
        with cloud transit added, so cost/delay reflect the true route)."""
        if self.sched is None:
            raise RuntimeError("submit_query() requires backend='engines'")
        cfg = self.cfg
        qc = self._context(ev)
        arm, phase = self._decide(qc)
        texts, hit, _ = self._retrieve(arm, ev)
        tier_name = "edge" if arm.generation == "local" else "cloud"
        _, net_delay = self._tier_and_net(arm, qc)
        rerouted = False
        if (tier_name == "edge" and cfg.overload_watermark is not None
                and self.sched.saturation("edge") >= cfg.overload_watermark):
            tier_name = "cloud"
            rerouted = True
            net_delay += qc.d_cloud          # the re-route pays cloud transit
            self.counters["failed_over"] += 1
        # tier-breaker reroute: an open breaker sheds the whole tier from
        # routing; go to the other tier if ITS breaker allows (when both
        # are open, submit on the nominal tier and let failover recover)
        other = "cloud" if tier_name == "edge" else "edge"
        now_b = self.clock.now()
        b, b_other = (self.tier_breakers.get(tier_name),
                      self.tier_breakers.get(other))
        if (b is not None and not b.allow(now_b)
                and (b_other is None or b_other.allow(now_b))
                and not (other == "cloud" and self._link_down)):
            if other == "cloud":
                net_delay += qc.d_cloud
            tier_name = other
            rerouted = True
            self.counters["breaker_reroutes"] += 1
        max_new = (cfg.max_new_graph if arm.retrieval == "graph"
                   else cfg.max_new_slm)
        max_seq = min(e.max_seq for e in self.sched.pools[tier_name])
        prompt = self._build_prompt(ev, texts, max_seq - max_new - 8)
        req = Request(prompt, max_new_tokens=max_new, slo="interactive")
        now = self.clock.now()
        self.counters["submitted"] += 1
        self._pending[id(req)] = _Pending(ev, qc, arm, hit, texts,
                                          net_delay, phase, req,
                                          tier_name=tier_name,
                                          rerouted=rerouted)
        self.sched.submit(req, tier_name,
                          deadline_s=now + cfg.qos_max_delay, now=now)
        self._observe_and_invalidate(ev)
        return req

    def pump_engines(self) -> List[StepLog]:
        """One scheduling round on the virtual clock: resubmit due failover
        retries, admit + one fused decode step per engine (skipping
        fault-stalled pool members), then advance the clock by the round's
        service time — ``modeled`` (tier rates x real token counts;
        deterministic) or ``wall`` (measured jit seconds). Pools run in
        parallel, so the round costs the SLOWEST engine's time. Completions
        harvested this round close the loop (measured delay and real token
        counts feed the cost model and the gate) unless the fault layer
        drops them in transit; scheduler sheds and dropped completions go
        through the failover path."""
        if self.sched is None:
            raise RuntimeError("pump_engines() requires backend='engines'")
        now = self.clock.now()
        self._apply_fault_transitions(now)
        self._resubmit_ready(now)
        stalled = None
        if self.faults is not None:
            pools = self.sched.pools

            def stalled(t: str, i: int, _now: float = now) -> bool:
                return self.faults.stalled(t, i, _now, len(pools[t]))

        flat = [(t, e) for t, pool in self.sched.pools.items() for e in pool]
        pre = [(e.prefill_tokens, e.decode_rounds, e.prefill_s + e.decode_s)
               for _, e in flat]
        comps = self.sched.pump(now=now, stalled=stalled)
        dt = 0.0
        for (tier_name, e), (p0, r0, w0) in zip(flat, pre):
            if self.cfg.engine_time == "wall":
                dt_e = (e.prefill_s + e.decode_s) - w0
            else:
                spec = (self.edge_tier if tier_name == "edge"
                        else self.cloud_tier)
                # exact under fused chunking too: a budget-mode round is
                # one decode round + its chunk tokens, so this delta form
                # equals summing modeled_mixed_step_s per step
                dt_e = (modeled_prefill_s(spec, e.prefill_tokens - p0)
                        + (e.decode_rounds - r0)
                        * modeled_decode_round_s(spec))
            dt = max(dt, dt_e)
        if dt > 0:
            self.clock.advance(dt)
        t_done = self.clock.now()
        out: List[StepLog] = []
        for c in comps:
            if (self.faults is not None
                    and self.faults.drop_completion(t_done)):
                self.counters["dropped_completions"] += 1
                p = self._pending.pop(id(c.request))
                self._handle_failure(p, "dropped", t_done)
                continue
            out.append(self._finalize(c))
        for s in self.sched.pop_sheds():
            p = self._pending.pop(id(s.request))
            self._handle_failure(p, s.reason, t_done)
        return out

    # ---- hard-failure transitions -------------------------------------
    def _apply_fault_transitions(self, now: float) -> None:
        """Drive the deterministic crash / partition schedules onto real
        state: crash engines entering their dead window, restart them on
        exit (only engines THIS schedule crashed — a manually-crashed
        engine stays down), and on partition heal run anti-entropy so
        deferred knowledge updates ship before the next query is served."""
        if self.faults is None or self.sched is None:
            return
        for tier, pool in self.sched.pools.items():
            for i, e in enumerate(pool):
                want_dead = self.faults.crashed(tier, i, now, len(pool))
                if want_dead and not e.dead:
                    e.crash()
                    self._fault_crashed.add((tier, i))
                    self.counters["engine_crashes"] += 1
                elif (not want_dead and e.dead
                        and (tier, i) in self._fault_crashed):
                    e.restart()
                    self._fault_crashed.discard((tier, i))
                    self.counters["engine_restarts"] += 1
        down = self.faults.partitioned(now)
        if down and not self._link_down:
            self._link_down = True
        elif not down and self._link_down:
            self._link_down = False
            self._anti_entropy(now)

    def _anti_entropy(self, now: float) -> None:
        """Partition healed: replay every deferred knowledge update so the
        affected edges catch up to the newest epoch, and invalidate edge
        prefix caches (their retrieved-context prefixes may now be built
        from rotated chunk sets)."""
        synced_any = False
        for eid in sorted(self.updater.deferred):
            if self.updater.sync(eid, self.stores[eid], now=now):
                synced_any = True
            self.counters["anti_entropy_syncs"] += 1
        if synced_any and self.sched is not None:
            for e in self.sched.pools["edge"]:
                if not e.dead:
                    e.invalidate_prefix_cache()
            self.counters["prefix_invalidations"] += 1

    # ---- failover / escalation ----------------------------------------
    def _handle_failure(self, p: _Pending, reason: str, now: float) -> None:
        """A query failed on its current tier (scheduler shed or dropped
        completion). Retry with bounded exponential backoff — edge
        failures ESCALATE to the cloud tier — until ``failover_max_retries``
        resubmissions, then record the typed terminal outcome."""
        cfg = self.cfg
        p.last_reason = reason
        b = self.tier_breakers.get(p.tier_name)
        if b is not None:
            b.record_failure(now)
        if p.attempts >= cfg.failover_max_retries:
            outcome = "failed" if reason == "dropped" else "shed"
            self.counters[outcome] += 1
            self._log_terminal(p, outcome, now)
            return
        backoff = min(cfg.failover_backoff_s * (2.0 ** p.attempts),
                      cfg.failover_backoff_cap_s)
        p.attempts += 1
        # escalate to the next tier up — unless the link is partitioned,
        # in which case the retry stays on the edge (degraded but serving)
        if p.tier_name == "edge" and not self._link_down:
            p.tier_name = "cloud"
            p.rerouted = True
            p.net_delay_s += p.qc.d_cloud    # true transit of the new route
            self.counters["failed_over"] += 1
        self.counters["retries"] += 1
        heapq.heappush(self._retries,
                       (now + backoff, next(self._retry_seq), p))

    def _resubmit_ready(self, now: float) -> None:
        """Re-enter retry-queue entries whose backoff has expired: rebuild
        the prompt for the (possibly escalated) tier's geometry, register a
        fresh Request, and submit with a fresh deadline."""
        cfg = self.cfg
        while self._retries and self._retries[0][0] <= now:
            _, _, p = heapq.heappop(self._retries)
            max_new = p.request.max_new_tokens
            max_seq = min(e.max_seq for e in self.sched.pools[p.tier_name])
            prompt = self._build_prompt(p.ev, p.texts, max_seq - max_new - 8)
            req = Request(prompt, max_new_tokens=max_new,
                          slo=p.request.slo)
            p.request = req
            self._pending[id(req)] = p
            self.sched.submit(req, p.tier_name,
                              deadline_s=now + cfg.qos_max_delay, now=now)

    def _log_terminal(self, p: _Pending, outcome: str, now: float) -> None:
        """Typed terminal record for a query the cluster gave up on: zero
        cost/tokens, ``correct=False``, age as delay. The gate is NOT
        updated — SafeOBO learns from served completions only; drops
        surface through counters and the conservation gate instead."""
        self.logs.append(StepLog(
            t=p.ev.t, edge_id=p.ev.edge_id, arm=p.arm.idx,
            arm_name=p.arm.name, correct=False,
            delay=max(now - p.ev.t, 0.0), cost=0.0, u_r=0.0, u_d=0.0,
            hit=p.hit, overlap=p.qc.overlap, multihop=p.ev.qa.multihop,
            in_tokens=0.0, out_tokens=0.0, phase=p.phase,
            retrieved=p.texts, tier=p.tier_name, outcome=outcome,
            slo=p.request.slo, rerouted=p.rerouted, attempts=p.attempts))

    def _finalize(self, c: Completion) -> StepLog:
        """Join a Completion back to its query: real token counts -> cost,
        composed virtual-clock delay -> QoS, oracle -> accuracy, and (eaco)
        the SafeOBO update that closes the control loop. The tier spec is
        taken from the tier that ACTUALLY served the completion — a
        watermark or failover re-route prices at the cloud tier, so the
        cost model and the gate see the true cost/delay of the re-route."""
        p = self._pending.pop(id(c.request))
        tier = self.edge_tier if c.tier == "edge" else self.cloud_tier
        b = self.tier_breakers.get(c.tier)
        if b is not None:
            b.record_success(self.clock.now())
        in_t = float(c.prompt_tokens)
        out_t = float(max(c.new_tokens, 1))
        net_delay = p.net_delay_s
        if c.hedged and c.tier == "cloud" and p.tier_name == "edge":
            net_delay += p.qc.d_cloud    # true transit of the backup route
        if self.faults is not None:
            net_delay += self.faults.net_spike(self.clock.now())
        delay = (tier.base_delay_s + net_delay
                 + c.queue_wait_s + c.time_in_engine_s)
        u_r = inference_tflops(tier.model_params_b, in_t, out_t)
        u_d = time_cost_tflops(tier, delay)
        cost = total_cost(u_r, u_d, self.weights)
        correct = self.oracle.draw(p.arm.name, hit=p.hit,
                                   multihop=p.ev.qa.multihop)
        # knowledge-epoch provenance: edge-RAG answers are served from the
        # edge's chunk set; if that set trails the newest epoch (deferred
        # update behind a partition) the answer is flagged — never silent
        store = self.stores[p.ev.edge_id]
        stale = (p.arm.retrieval == "edge"
                 and self.updater.is_stale(store))
        log = StepLog(
            t=p.ev.t, edge_id=p.ev.edge_id, arm=p.arm.idx,
            arm_name=p.arm.name, correct=correct, delay=delay, cost=cost,
            u_r=u_r, u_d=u_d, hit=p.hit, overlap=p.qc.overlap,
            multihop=p.ev.qa.multihop, in_tokens=in_t, out_tokens=out_t,
            phase=p.phase, retrieved=p.texts, tier=c.tier,
            queue_wait_s=c.queue_wait_s, engine_s=c.time_in_engine_s,
            slo=c.slo, rerouted=p.rerouted, attempts=p.attempts,
            hedged=c.hedged, epoch=store.epoch, stale_epoch=stale)
        self.counters["completed"] += 1
        if c.hedged:
            self.counters["hedged_served"] += 1
        if stale:
            self.counters["stale_served"] += 1
        if self.policy == "eaco":
            self.gate.update(p.qc, p.arm, cost=cost,
                             accuracy=1.0 if correct else 0.0, delay=delay)
        self.logs.append(log)
        return log

    def conservation_ok(self) -> bool:
        """The request-conservation law: every submitted query reached a
        terminal state (completed, shed, or failed) and nothing is still
        outstanding. Benchmarks gate on this so future PRs can't silently
        drop work."""
        c = self.counters
        outstanding = len(self._pending) + len(self._retries)
        return (c["submitted"] == c["completed"] + c["shed"] + c["failed"]
                + outstanding)

    def drain_engines(self) -> List[StepLog]:
        """Serve until every outstanding query reaches a terminal state
        (completion, shed, or failed), riding out fault-stalled engines and
        waiting out failover backoffs by idling the virtual clock forward.
        Raises ``RuntimeError`` if no terminal progress happens within
        ``drain_timeout_s`` virtual seconds — a wedge fails loudly instead
        of spinning forever."""
        if self.sched is None:
            raise RuntimeError("drain_engines() requires backend='engines'")
        out: List[StepLog] = []

        def progress() -> tuple:
            # REAL progress only — the clock moving (including our own idle
            # advances below) must not reset the wedge guard
            return (len(self.logs), self.sched.pending(),
                    self.sched.in_flight(), len(self._retries),
                    tuple(self.sched.counters.values()))

        wedge_at = self.clock.now() + self.cfg.drain_timeout_s
        while self._pending or self._retries:
            before = progress()
            t0 = self.clock.now()
            out.extend(self.pump_engines())
            if progress() != before:
                wedge_at = self.clock.now() + self.cfg.drain_timeout_s
                continue
            if self.clock.now() >= wedge_at:
                now_w = self.clock.now()
                ready = ", ".join(f"{r[0]:.3f}" for r in
                                  sorted(self._retries)[:8])
                tb = {t: b.state(now_w)
                      for t, b in self.tier_breakers.items()}
                raise RuntimeError(
                    f"cluster wedged: {self.sched.pending()} queued, "
                    f"{self.sched.in_flight()} resident, "
                    f"{len(self._retries)} awaiting retry with no progress "
                    f"for {self.cfg.drain_timeout_s}s of virtual time\n"
                    f"now={now_w:.3f} link_down={self._link_down} "
                    f"tier_breakers={tb or None} "
                    f"retry_ready_at=[{ready}]\n"
                    f"cluster_counters={self.counters}\n"
                    f"{self.sched.debug_state(now_w)}")
            if self.clock.now() > t0:
                continue      # modeled time moved; let fault windows expire
            # nothing can move until a backoff or stall window expires —
            # idle the clock toward the next actionable instant instead of
            # spinning, bounded by the wedge guard above
            if self._retries and not (self.sched.pending()
                                      or self.sched.in_flight()):
                step = max(self._retries[0][0] - self.clock.now(),
                           self.cfg.stall_tick_s)
            else:
                step = self.cfg.stall_tick_s
            self.clock.advance(step)
        return out

    def run(self, n_steps: int) -> List[StepLog]:
        if self.backend != "engines":
            for ev in self.workload.stream(n_steps):
                self.step(ev)
            return self.logs
        period = self.cfg.arrival_period_s
        for events in self.workload.bursts(n_steps, clock=self.clock):
            for ev in events:
                self.submit_query(ev)
            # serve until the engines' virtual time reaches the next
            # arrival tick, then idle the clock forward to it
            target = self.clock.now() + period
            while ((self.sched.pending() or self.sched.in_flight()
                    or self._retries) and self.clock.now() < target):
                before = self.clock.now()
                self.pump_engines()
                if self.clock.now() <= before:
                    break
            if self.clock.now() < target:
                self.clock.advance(target - self.clock.now())
        self.drain_engines()
        return self.logs

    # ------------------------------------------------------------------
    def metrics(self, skip_warmup: bool = True) -> Dict[str, Any]:
        """Aggregates over SERVED completions (``outcome == "ok"``);
        terminal drops are reported via ``drop_rate`` and ``counters``
        instead of skewing the served-quality means with zero-cost rows."""
        logs = self.logs
        if skip_warmup and self.policy == "eaco":
            logs = [l for l in logs if l.phase != "warmup"]
        dropped = sum(l.outcome != "ok" for l in logs)
        logs = [l for l in logs if l.outcome == "ok"]
        if not logs:
            return {}
        acc = float(np.mean([l.correct for l in logs]))
        n_arms = len(self.gate.arms)
        return {
            "n": len(logs),
            "dropped": dropped,
            "drop_rate": dropped / max(len(logs) + dropped, 1),
            "rerouted": sum(l.rerouted for l in logs),
            "counters": dict(self.counters),
            "accuracy": acc,
            "delay_mean": float(np.mean([l.delay for l in logs])),
            "delay_std": float(np.std([l.delay for l in logs])),
            "cost_mean": float(np.mean([l.cost for l in logs])),
            "cost_std": float(np.std([l.cost for l in logs])),
            "u_r_mean": float(np.mean([l.u_r for l in logs])),
            "u_d_mean": float(np.mean([l.u_d for l in logs])),
            "hit_rate": float(np.mean([l.hit for l in logs])),
            "arm_fracs": [float(np.mean([l.arm == a for l in logs]))
                          for a in range(n_arms)],
            "in_tokens_mean": float(np.mean([l.in_tokens for l in logs])),
            "out_tokens_mean": float(np.mean([l.out_tokens for l in logs])),
            "queue_wait_mean": float(np.mean([l.queue_wait_s for l in logs])),
        }


__all__ = ["EACOCluster", "SimConfig", "StepLog", "FaultInjector",
           "FaultConfig"]
