"""Deterministic simulation testing (DST) for the tiered serving stack.

FoundationDB/TigerBeetle-style correctness machinery: instead of a handful
of hand-authored chaos schedules checked at bench endpoints, a seeded
generator *samples* arbitrary overlapping fault/workload timelines on the
virtual clock, an invariant-oracle layer re-checks the whole stack's
safety contracts after **every** pump, every run records a replayable JSON
trace, and a delta-debugging shrinker minimizes any failing schedule to a
small repro artifact. Four pieces:

1. :func:`generate_schedule` — samples the full fault vocabulary (engine
   crash/restart, partition/heal, stalls, net-delay spikes, completion
   drops, knowledge-update bursts, arrival bursts, SLO-mix shifts) as
   :class:`~repro.cluster.faults.FaultEvent` timelines. Same seed, same
   schedule, byte for byte.
2. :class:`DSTHarness` — drives real :class:`ServingEngine` pools through
   a real :class:`TierScheduler` (preemption, requeue-on-crash, breakers,
   edge->cloud hedging) plus the real epoch-versioned knowledge layer,
   with a :class:`TimelineFaultInjector` applying the schedule — the same
   closed loop the cluster simulator runs, minus the gate. All pool
   members are replicas (same weights seed), so greedy output is
   token-comparable across restarts, hedges and pool members.
3. The oracle layer (checked after every pump): request conservation
   (scheduler counters AND a harness-side ledger), generation-fence
   legality, breaker state-machine legality, monotone knowledge epochs
   with no unflagged ``stale_epoch`` completions, per-engine page-arena
   audit (free + cached + active == ``num_pages``; refcount == slot
   mappings; zero leaks at quiescence), token-identity of every
   completion against the uncontended greedy reference, and a
   virtual-time wedge (liveness) guard.
4. :func:`shrink_schedule` — ddmin over the event list (plus per-burst
   request shrinking), so "seed 1234 fails" becomes "these 2 events
   fail", and :func:`replay_trace` — re-run a recorded trace and demand
   byte-identical oracle snapshots.

Everything downstream (chunked prefill, speculative decoding, multi-host
arena) is expected to run under this fuzzer before it ships: the oracles
are the contracts those PRs must keep. Drive it via
``benchmarks/dst_bench.py`` (``make fuzz SEED=… SEEDS=…``,
``make fuzz-smoke``, ``--replay``/``--shrink`` on saved traces).
"""
from __future__ import annotations

import json
import math
from collections import deque
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.cluster.faults import FAULT_KINDS, FaultEvent, TimelineFaultInjector
from repro.core.clock import VirtualClock
from repro.core.cost_model import (
    PAPER_CLOUD, PAPER_EDGE, modeled_decode_round_s, modeled_prefill_s,
)
from repro.core.knowledge import AdaptiveKnowledgeUpdater, KnowledgeUpdateConfig
from repro.serving import Request, TierScheduler, make_edge_engine
from repro.serving.health import CLOSED, HALF_OPEN, OPEN
from repro.serving.paging import PagingError
from repro.retrieval.store import VectorStore

WORKLOAD_KINDS = ("arrivals", "knowledge", "slo_shift")
TIER_SPEC = {"edge": PAPER_EDGE, "cloud": PAPER_CLOUD}
TRACE_VERSION = 1

# intentionally plantable bugs for fuzzer drills: each must be caught by
# an oracle and shrink to a tiny schedule (the acceptance test for the
# whole DST loop — if the fuzzer can't find a bug we planted, it won't
# find one we didn't)
BUGS = ("leak_page", "epoch_regress", "breaker_jump")


class DSTViolation(RuntimeError):
    """An invariant oracle failed. Carries the oracle's name and the
    snapshot taken at the violating pump (recorded into the trace)."""

    def __init__(self, message: str, oracle: str, snapshot: dict):
        super().__init__(message)
        self.oracle = oracle
        self.snapshot = snapshot


@dataclass
class DSTConfig:
    """Topology + schedule-intensity knobs for one DST universe."""
    horizon_s: float = 24.0           # schedule window on the virtual clock
    # ---- topology ------------------------------------------------------
    n_edge_engines: int = 2
    n_cloud_engines: int = 1
    n_edges: int = 2                  # knowledge stores (edge sites)
    max_seq: int = 128
    max_batch: int = 2
    page_size: int = 16
    num_pages: int = 12               # < max_batch*pages_per_slot: page
    #                                   pressure so CoW/LRU paths execute
    # fused chunked-prefill + decode on by default: DST universes exercise
    # preempt/crash/requeue of HALF-PREFILLED residents, and the identity
    # oracle compares chunked pool output against the whole-suffix
    # ref_engine (None = whole-suffix pools, the pre-chunking behavior)
    step_token_budget: Optional[int] = 24
    prefill_chunk: int = 16
    store_capacity: int = 40
    # ---- scheduler knobs ------------------------------------------------
    breaker_threshold: int = 2
    breaker_reset_s: float = 4.0
    hedge_s: Optional[float] = 2.0
    request_timeout_s: float = 8.0
    interactive_slo_s: float = 20.0
    batch_slo_s: float = 60.0
    # ---- schedule intensity (Poisson means over the horizon) ------------
    mean_arrival_bursts: float = 4.0
    burst_max: int = 3                # requests per burst
    mean_crashes: float = 2.0
    mean_stalls: float = 1.5
    mean_partitions: float = 1.0
    mean_spikes: float = 1.0
    mean_drops: float = 1.0
    mean_knowledge: float = 2.5
    mean_slo_shifts: float = 1.0
    # ---- oracle knobs ---------------------------------------------------
    check_token_identity: bool = True
    wedge_idle_s: float = 40.0        # virtual idle with zero progress


# ---------------------------------------------------------------------------
# 1. Schedule generation
# ---------------------------------------------------------------------------
def generate_schedule(seed: int, cfg: Optional[DSTConfig] = None
                      ) -> List[FaultEvent]:
    """Sample one random schedule: overlapping fault windows + workload
    events over ``cfg.horizon_s`` virtual seconds. Pure function of
    ``(seed, cfg)`` — all draws come from one ``default_rng(seed)`` and
    every value is rounded to plain JSON-exact Python scalars, so the
    schedule regenerates byte-identically and round-trips through trace
    files."""
    cfg = cfg or DSTConfig()
    rng = np.random.default_rng(seed)
    h = cfg.horizon_s
    events: List[FaultEvent] = []

    def U(a: float, b: float) -> float:
        return round(float(rng.uniform(a, b)), 4)

    def N(mean: float) -> int:
        return int(rng.poisson(mean))

    # arrival bursts (at least one — a schedule with no work tests nothing)
    for _ in range(max(1, N(cfg.mean_arrival_bursts))):
        t = U(0.0, 0.8 * h)           # leave tail room to drain
        reqs = []
        for _ in range(int(rng.integers(1, cfg.burst_max + 1))):
            reqs.append({
                "plen": int(rng.integers(12, 40)),
                "new": int(rng.integers(4, 17)),
                "pseed": int(rng.integers(0, 2**31 - 1)),
                # u vs the runtime interactive fraction decides the SLO
                # class at submit time, so slo_shift events stay shrinkable
                "u": round(float(rng.random()), 6),
                "edge": int(rng.integers(0, cfg.n_edges)),
                "tier": "edge" if rng.random() < 0.85 else "cloud",
            })
        events.append(FaultEvent(t, "arrivals", params={"reqs": reqs}))
    for _ in range(N(cfg.mean_crashes)):
        tier = "edge" if rng.random() < 0.8 else "cloud"
        pool = cfg.n_edge_engines if tier == "edge" else cfg.n_cloud_engines
        events.append(FaultEvent(U(0.0, h), "crash", duration=U(0.5, 3.0),
                                 tier=tier,
                                 engine=int(rng.integers(0, pool))))
    for _ in range(N(cfg.mean_stalls)):
        events.append(FaultEvent(
            U(0.0, h), "stall", duration=U(0.5, 3.0), tier="edge",
            engine=int(rng.integers(0, cfg.n_edge_engines))))
    for _ in range(N(cfg.mean_partitions)):
        events.append(FaultEvent(U(0.0, h), "partition",
                                 duration=U(1.0, 5.0)))
    for _ in range(N(cfg.mean_spikes)):
        events.append(FaultEvent(U(0.0, h), "net_spike",
                                 duration=U(0.5, 3.0),
                                 magnitude=U(0.1, 1.0)))
    for _ in range(N(cfg.mean_drops)):
        events.append(FaultEvent(U(0.0, h), "drop", duration=U(0.5, 2.0),
                                 magnitude=float(rng.choice([0.5, 1.0]))))
    for _ in range(N(cfg.mean_knowledge)):
        events.append(FaultEvent(U(0.0, h), "knowledge", params={
            "edge": int(rng.integers(0, cfg.n_edges)),
            "qseed": int(rng.integers(0, 2**31 - 1))}))
    for _ in range(N(cfg.mean_slo_shifts)):
        events.append(FaultEvent(U(0.0, h), "slo_shift",
                                 magnitude=round(float(rng.random()), 4)))
    events.sort(key=lambda e: (e.t, e.kind))
    return events


# ---------------------------------------------------------------------------
# Results / traces
# ---------------------------------------------------------------------------
@dataclass
class DSTResult:
    seed: Optional[int]
    inj_seed: int
    bug: Optional[str]
    events: List[FaultEvent]
    snapshots: List[dict]
    failure: Optional[str]            # human message, None when green
    failure_oracle: Optional[str]     # which oracle fired
    counters: Dict[str, int]          # final scheduler counters
    ledger: Dict[str, int]            # harness-side event/outcome ledger
    makespan_s: float = 0.0
    n_pumps: int = 0

    @property
    def ok(self) -> bool:
        return self.failure is None

    def trace(self) -> dict:
        """JSON-serializable record of the run: the schedule, every oracle
        snapshot, and the outcome — sufficient for byte-identical replay
        (:func:`replay_trace`) and for shrinking."""
        return {
            "version": TRACE_VERSION, "seed": self.seed,
            "inj_seed": self.inj_seed, "bug": self.bug,
            "failure": self.failure, "failure_oracle": self.failure_oracle,
            "events": [e.to_dict() for e in self.events],
            "snapshots": self.snapshots,
            "counters": dict(self.counters), "ledger": dict(self.ledger),
            "makespan_s": self.makespan_s, "n_pumps": self.n_pumps,
        }


def save_trace(result: DSTResult, path: str) -> str:
    import os
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(result.trace(), f, indent=1, sort_keys=True)
    return path


def load_trace(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# 2. The harness
# ---------------------------------------------------------------------------
class DSTHarness:
    """Owns the (expensive) engine pools and replays any schedule through
    a FRESH scheduler + knowledge layer per run. Engines are recycled
    between runs via crash()+restart() — by the crash contract that is a
    cold engine (empty arena, fresh allocator/prefix index, no retrace),
    so run N+1 starts from the same state run N did. Oracle snapshots
    deliberately contain only run-local quantities (no engine-cumulative
    counters, no raw request/generation ids), which is what makes
    replay-from-trace byte-identical on reused pools."""

    def __init__(self, cfg: Optional[DSTConfig] = None, *,
                 pools: Optional[Dict[str, list]] = None):
        self.cfg = cfg or DSTConfig()
        c = self.cfg
        ekw = dict(max_seq=c.max_seq, max_batch=c.max_batch, seed=0,
                   kv_layout="paged", page_size=c.page_size,
                   num_pages=c.num_pages, prefix_cache=True,
                   step_token_budget=c.step_token_budget,
                   prefill_chunk=c.prefill_chunk)
        if pools is not None:
            self.pools = pools
        else:
            # every member (both tiers) is a replica of the same reduced
            # edge SLM: token identity must hold across pool members,
            # restarts and hedged re-serves, which needs identical weights
            self.pools = {
                "edge": [make_edge_engine(**ekw)
                         for _ in range(c.n_edge_engines)],
                "cloud": [make_edge_engine(**ekw)
                          for _ in range(c.n_cloud_engines)],
            }
        # uncontended reference engine for greedy token identity (roomy
        # default page pool: the reference must never preempt or shed)
        self.ref_engine = make_edge_engine(
            max_seq=c.max_seq, max_batch=c.max_batch, seed=0,
            kv_layout="paged", page_size=c.page_size, prefix_cache=True)
        self._ref_cache: Dict[Tuple[str, int], str] = {}
        self._corpus = None
        self._graph = None

    # ---- shared read-only knowledge substrate -------------------------
    def _knowledge_substrate(self):
        if self._graph is None:
            from repro.data.corpus import wiki_like
            from repro.retrieval.graph_rag import KnowledgeGraph
            self._corpus = wiki_like(seed=0)
            self._graph = KnowledgeGraph(seed=0).build(self._corpus.chunks)
        return self._corpus, self._graph

    def _fresh_stores(self) -> Dict[str, VectorStore]:
        corpus, _ = self._knowledge_substrate()
        topics = sorted({c.topic for c in corpus.chunks})
        stores: Dict[str, VectorStore] = {}
        for i in range(self.cfg.n_edges):
            st = VectorStore(capacity=self.cfg.store_capacity)
            st.add(corpus.chunks_for_topic(topics[i % len(topics)])
                   [: self.cfg.store_capacity // 2])
            stores[f"edge{i}"] = st
        return stores

    def _kquery(self, qseed: int) -> str:
        corpus, _ = self._knowledge_substrate()
        ch = corpus.chunks[qseed % len(corpus.chunks)]
        return " ".join(ch.keywords[:4]) if ch.keywords else ch.text[:40]

    # ---- request materialization --------------------------------------
    @staticmethod
    def _prompt(spec: dict) -> str:
        rng = np.random.default_rng(spec["pseed"])
        # per-edge shared header exercises prefix sharing/CoW across the
        # burst; the unique tail forces a suffix prefill
        head = f"site{spec['edge']} ctx " * 2
        tail = "".join(rng.choice(list("abcdefgh "), spec["plen"]))
        return (head + tail)[: 96]

    def _reference_text(self, spec: dict) -> str:
        key = (self._prompt(spec), int(spec["new"]))
        if key not in self._ref_cache:
            texts, _ = self.ref_engine.generate(
                [Request(key[0], max_new_tokens=key[1])])
            self._ref_cache[key] = texts[0]
        return self._ref_cache[key]

    def _reset_pools(self) -> None:
        for pool in self.pools.values():
            for e in pool:
                if not e.dead:
                    e.crash()
                e.restart()

    # ---- bug planting (fuzzer drills) ---------------------------------
    def _install_bug(self, bug: Optional[str]) -> None:
        self._bug_epoch_regress = bug == "epoch_regress"
        self._bug_breaker_jump = bug == "breaker_jump"
        if bug is None or bug in ("epoch_regress", "breaker_jump"):
            return
        if bug != "leak_page":
            raise ValueError(f"unknown bug {bug!r}; known: {BUGS}")
        # skip one refcount decrement on the first free issued by edge
        # engine 0 — the classic leaked-page bug the page-arena oracle
        # exists for. Installed on the run-local allocator (restart()
        # replaces it), so nothing to restore afterwards.
        e = self.pools["edge"][0]
        alloc = e._allocator
        orig = alloc.free
        armed = [True]

        def bad_free(ids, retain=None):
            ids = list(ids)
            if armed[0] and ids:
                armed[0] = False
                ids = ids[1:]
            return orig(ids, retain)

        alloc.free = bad_free

    # ---- the run loop --------------------------------------------------
    def run(self, events: Sequence[FaultEvent], *, seed: Optional[int] = None,
            inj_seed: int = 0, bug: Optional[str] = None) -> DSTResult:
        cfg = self.cfg
        self._reset_pools()
        self._install_bug(bug)
        clock = VirtualClock()
        inj = TimelineFaultInjector(
            [e for e in events if e.kind in FAULT_KINDS], seed=inj_seed)
        work = deque(e for e in events if e.kind in WORKLOAD_KINDS)
        end_t = max((e.t + e.duration for e in events), default=0.0)
        # timeline boundaries (window starts/ends): idle ticks jump to the
        # next one so quiet stretches don't burn thousands of no-op pumps
        bounds = sorted({e.t for e in events}
                        | {e.t + e.duration for e in events})
        sched = TierScheduler(
            self.pools, clock=clock, preempt=True, shed_overdue=True,
            request_timeout_s=cfg.request_timeout_s, requeue_lost=True,
            breaker_threshold=cfg.breaker_threshold,
            breaker_reset_s=cfg.breaker_reset_s,
            hedge_s=cfg.hedge_s, hedge_from="edge", hedge_to="cloud",
            hedge_gate=lambda now: not inj.partitioned(now))
        _, graph = self._knowledge_substrate()
        updater = AdaptiveKnowledgeUpdater(graph, KnowledgeUpdateConfig(
            update_trigger=1, max_chunks_per_update=12,
            top_k_communities=2, recent_window=8))
        stores = self._fresh_stores()
        slack = {"interactive": cfg.interactive_slo_s,
                 "batch": cfg.batch_slo_s}
        ledger: Dict[str, int] = {
            "submitted": 0, "delivered": 0, "dropped": 0, "shed": 0,
            "stale_served": 0, "knowledge_events": 0, "ships": 0,
            "defers": 0, "syncs": 0, "invalidations": 0, "crashes": 0,
            "restarts": 0, "partitions": 0, "heals": 0, "slo_shifts": 0}
        meta: Dict[int, dict] = {}        # id(request) -> spec/outcome
        self._interactive_frac = 0.5
        self._link_down = False
        self._crashed: set = set()
        self._prev_breakers: Dict[tuple, str] = {}
        self._prev_epochs: dict = {"latest": updater.latest_epoch,
                                   "stores": {k: v.epoch
                                              for k, v in stores.items()}}
        if cfg.check_token_identity:
            for ev in work:
                if ev.kind == "arrivals":
                    for spec in ev.params["reqs"]:
                        self._reference_text(spec)

        def apply_transitions(now: float) -> bool:
            moved = False
            for tier, pool in self.pools.items():
                for i, e in enumerate(pool):
                    want = inj.crashed(tier, i, now, len(pool))
                    if want and not e.dead:
                        e.crash()
                        self._crashed.add((tier, i))
                        ledger["crashes"] += 1
                        moved = True
                    elif not want and e.dead and (tier, i) in self._crashed:
                        e.restart()
                        self._crashed.discard((tier, i))
                        ledger["restarts"] += 1
                        moved = True
            part = inj.partitioned(now)
            if part and not self._link_down:
                ledger["partitions"] += 1
                moved = True
            elif self._link_down and not part:
                # heal: anti-entropy replays deferred refreshes; shipped
                # chunks invalidate cached retrieved-context prefixes
                for eid in sorted(stores):
                    if updater.sync(eid, stores[eid], now=now):
                        ledger["syncs"] += 1
                        self._invalidate_edges(ledger)
                ledger["heals"] += 1
                moved = True
            self._link_down = part
            return moved

        def apply_event(ev: FaultEvent, now: float) -> None:
            if ev.kind == "arrivals":
                for spec in ev.params["reqs"]:
                    slo = ("interactive" if spec["u"] < self._interactive_frac
                           else "batch")
                    req = Request(self._prompt(spec),
                                  max_new_tokens=int(spec["new"]), slo=slo)
                    sched.submit(req, spec.get("tier", "edge"),
                                 deadline_s=now + slack[slo], now=now)
                    ledger["submitted"] += 1
                    meta[id(req)] = {"spec": spec, "slo": slo}
            elif ev.kind == "knowledge":
                eid = f"edge{int(ev.params['edge']) % cfg.n_edges}"
                before = stores[eid].epoch
                updater.observe_query(eid, self._kquery(ev.params["qseed"]),
                                      stores[eid], now=now,
                                      link_up=not self._link_down)
                ledger["knowledge_events"] += 1
                if stores[eid].epoch != before:
                    ledger["ships"] += 1
                    self._invalidate_edges(ledger)
                elif self._link_down:
                    ledger["defers"] += 1
                if self._bug_epoch_regress:
                    updater.latest_epoch -= 2
            elif ev.kind == "slo_shift":
                self._interactive_frac = float(ev.magnitude)
                ledger["slo_shifts"] += 1

        snapshots: List[dict] = []
        failure = failure_oracle = None
        mismatches: List[dict] = []
        idle_since: Optional[float] = None
        while True:
            now = clock.now()
            moved = apply_transitions(now)
            while work and work[0].t <= now:
                apply_event(work.popleft(), now)
                moved = True
            if (not work and not sched.pending() and not sched.in_flight()
                    and not self._crashed and now >= end_t):
                break
            flat = [(t, e) for t, pool in self.pools.items() for e in pool]
            pre = [(e.prefill_tokens, e.decode_rounds) for _, e in flat]
            before = (sched.pending(), sched.in_flight(),
                      tuple(sched.counters.values()))

            def stalled(tier, i, _now=now):
                return inj.stalled(tier, i, _now, len(self.pools[tier]))

            comps = sched.pump(now=now, stalled=stalled)
            if self._bug_breaker_jump and snapshots and sched.breakers:
                # teleport a closed breaker straight to half_open (skipping
                # open + the reset timeout) after the first snapshot has
                # pinned its previous state — the legality oracle's target
                b = next(iter(sched.breakers.values()))
                if b.state(now) == CLOSED:
                    b._state = HALF_OPEN
            dt = 0.0
            for (tier, e), (p0, r0) in zip(flat, pre):
                spec = TIER_SPEC[tier]
                dt = max(dt, modeled_prefill_s(spec, e.prefill_tokens - p0)
                         + (e.decode_rounds - r0)
                         * modeled_decode_round_s(spec))
            if dt > 0:
                clock.advance(dt)
            t_done = clock.now()
            comp_records = []
            for c in comps:
                m = meta.pop(id(c.request), None)
                if m is None:
                    continue                 # duplicate (can't happen; guard)
                rec = {"tier": c.tier, "engine": c.engine_index,
                       "slo": c.slo, "hedged": bool(c.hedged),
                       "new_tokens": c.new_tokens,
                       "preemptions": c.preemptions}
                if (cfg.check_token_identity
                        and c.text != self._reference_text(m["spec"])):
                    mismatches.append(
                        {"tier": c.tier, "engine": c.engine_index,
                         "got": c.text,
                         "want": self._reference_text(m["spec"])})
                eid = f"edge{m['spec']['edge']}"
                stale = updater.is_stale(stores[eid])
                rec["stale"] = bool(stale)
                rec["store"] = eid
                if inj.drop_completion(t_done):
                    ledger["dropped"] += 1
                    rec["dropped"] = True
                else:
                    ledger["delivered"] += 1
                    if stale:
                        ledger["stale_served"] += 1
                comp_records.append(rec)
            for s in sched.pop_sheds():
                meta.pop(id(s.request), None)
                ledger["shed"] += 1
            after = (sched.pending(), sched.in_flight(),
                     tuple(sched.counters.values()))
            try:
                snap = self._check_oracles(
                    sched, updater, stores, t_done, len(snapshots),
                    comp_records, mismatches, ledger, meta)
                snapshots.append(snap)
            except DSTViolation as v:
                snapshots.append(v.snapshot)
                failure, failure_oracle = str(v), v.oracle
                break
            if moved or dt > 0 or after != before:
                idle_since = None
                continue
            idle_since = t_done if idle_since is None else idle_since
            if t_done - idle_since > cfg.wedge_idle_s:
                failure = (f"wedge: no progress for {cfg.wedge_idle_s}s "
                           f"virtual at t={t_done:.2f} with "
                           f"{sched.pending()} queued / "
                           f"{sched.in_flight()} resident")
                failure_oracle = "wedge"
                snapshots.append(
                    {"t": t_done, "violations": [failure],
                     "debug": sched.debug_state_dict(t_done)})
                break
            nxt = next((b for b in bounds if b > t_done + 1e-9),
                       t_done + 0.25)
            clock.advance(min(max(nxt - t_done, 0.05), 0.5))

        if failure is None:
            # quiescence: every live engine fully drained, zero page leaks
            try:
                for tier, pool in self.pools.items():
                    for i, e in enumerate(pool):
                        e.assert_quiescent()
                if meta:
                    raise DSTViolation(
                        f"harness ledger: {len(meta)} request(s) neither "
                        "completed, dropped, nor shed at quiescence",
                        "conservation", {})
            except DSTViolation as v:
                failure, failure_oracle = str(v), v.oracle
            except Exception as exc:  # noqa: BLE001 — any audit breach
                failure = f"quiescence audit failed: {exc}"
                failure_oracle = "page-audit"
        return DSTResult(
            seed=seed, inj_seed=inj_seed, bug=bug, events=list(events),
            snapshots=snapshots, failure=failure,
            failure_oracle=failure_oracle, counters=dict(sched.counters),
            ledger=ledger, makespan_s=clock.now(), n_pumps=len(snapshots))

    def _invalidate_edges(self, ledger: Dict[str, int]) -> None:
        for e in self.pools["edge"]:
            if not e.dead:
                e.invalidate_prefix_cache()
                ledger["invalidations"] += 1

    # ---- 3. the oracle layer -------------------------------------------
    def _check_oracles(self, sched: TierScheduler,
                       updater: AdaptiveKnowledgeUpdater,
                       stores: Dict[str, VectorStore], now: float,
                       pump: int, comp_records: List[dict],
                       mismatches: List[dict], ledger: Dict[str, int],
                       meta: Dict[int, dict]) -> dict:
        """Check every invariant; return the JSON snapshot for the trace
        or raise :class:`DSTViolation`. Snapshots hold only run-local,
        deterministic quantities — replaying the same schedule on reused
        pools must reproduce them byte for byte."""
        violations: List[str] = []
        # 1. request conservation, scheduler side and harness side
        if not sched.conservation_ok():
            violations.append(
                f"conservation: scheduler counters do not balance "
                f"({sched.counters})")
        outstanding = len(meta)
        if (ledger["submitted"] != ledger["delivered"] + ledger["dropped"]
                + ledger["shed"] + outstanding):
            violations.append(
                f"conservation: harness ledger does not balance ({ledger}, "
                f"outstanding={outstanding})")
        # 2. generation-fence legality
        fences = []
        for f in sched.resident_fences():
            ok = not f["dead"] and f["admit_gen"] == f["engine_gen"]
            fences.append([f["tier"], f["engine"], ok])
            if not ok:
                violations.append(
                    f"fence: resident on {f['tier']}[{f['engine']}] "
                    f"dead={f['dead']} admit_gen={f['admit_gen']} "
                    f"engine_gen={f['engine_gen']}")
        # 3. breaker state-machine legality
        breakers = {}
        for key, b in sched.breakers.items():
            snap = b.snapshot(now)
            cur, prev = snap["state"], self._prev_breakers.get(key)
            name = f"{key[0]}:{key[1]}"
            breakers[name] = snap
            if prev == CLOSED and cur == HALF_OPEN:
                violations.append(
                    f"breaker: {name} teleported closed -> half_open")
            if (prev == OPEN and cur == HALF_OPEN
                    and now - b.opened_at + 1e-9 < b.reset_timeout_s):
                violations.append(
                    f"breaker: {name} opened at {b.opened_at:.3f} but "
                    f"half_open at {now:.3f} < reset_timeout "
                    f"{b.reset_timeout_s}")
            if snap["failures"] < 0:
                violations.append(f"breaker: {name} negative failure count")
            self._prev_breakers[key] = cur
        # 4. monotone knowledge epochs
        ep = updater.snapshot(stores)
        if ep["latest_epoch"] < self._prev_epochs["latest"]:
            violations.append(
                f"epoch: latest_epoch regressed "
                f"{self._prev_epochs['latest']} -> {ep['latest_epoch']}")
        for eid in sorted(stores):
            cur = stores[eid].epoch
            if cur < self._prev_epochs["stores"].get(eid, 0):
                violations.append(
                    f"epoch: store {eid} regressed "
                    f"{self._prev_epochs['stores'][eid]} -> {cur}")
            if cur > ep["latest_epoch"]:
                violations.append(
                    f"epoch: store {eid} epoch {cur} ahead of latest "
                    f"{ep['latest_epoch']}")
        if not self._link_down and updater.deferred:
            violations.append(
                f"epoch: deferred updates {sorted(updater.deferred)} "
                "while the link is up (anti-entropy missed)")
        self._prev_epochs = {"latest": ep["latest_epoch"],
                             "stores": {k: v.epoch
                                        for k, v in stores.items()}}
        # 5. no unflagged stale-epoch completions (independent recompute)
        for rec in comp_records:
            truth = stores[rec["store"]].epoch < updater.latest_epoch
            if truth and not rec["stale"]:
                violations.append(
                    f"epoch: completion from {rec['store']} served at "
                    f"stale epoch without a stale_epoch flag")
        # 6. page-arena audit on every live engine
        pages = {}
        for tier, pool in self.pools.items():
            reports = []
            for i, e in enumerate(pool):
                try:
                    reports.append(e.audit())
                except PagingError as exc:
                    reports.append({"error": str(exc)})
                    violations.append(f"page-audit: {tier}[{i}]: {exc}")
            pages[tier] = reports
        # 7. greedy token identity (resumed/hedged/restarted re-serves)
        for m in mismatches:
            violations.append(
                f"token-identity: {m['tier']}[{m['engine']}] diverged from "
                f"the uncontended greedy reference "
                f"({m['got']!r} != {m['want']!r})")
        del mismatches[:]
        snap = {"t": now, "pump": pump, "queued": sched.pending(),
                "resident": sched.in_flight(),
                "counters": dict(sched.counters), "fences": fences,
                "breakers": breakers, "epochs": ep, "pages": pages,
                "link_down": self._link_down, "ledger": dict(ledger),
                "completions": comp_records}
        if violations:
            snap["violations"] = violations
            raise DSTViolation("; ".join(violations),
                               violations[0].split(":")[0], snap)
        return snap


# ---------------------------------------------------------------------------
# Convenience entry points
# ---------------------------------------------------------------------------
def run_dst(seed: int, cfg: Optional[DSTConfig] = None,
            harness: Optional[DSTHarness] = None,
            bug: Optional[str] = None) -> DSTResult:
    """Generate the schedule for ``seed`` and run it. Pass a shared
    ``harness`` when sweeping many seeds — engine construction dominates
    otherwise."""
    harness = harness or DSTHarness(cfg)
    events = generate_schedule(seed, harness.cfg)
    return harness.run(events, seed=seed, inj_seed=seed, bug=bug)


def replay_trace(trace: dict, harness: DSTHarness
                 ) -> Tuple[DSTResult, bool]:
    """Re-run a recorded trace's schedule and compare: same oracle, and
    byte-identical snapshot stream (via canonical JSON). Returns
    ``(result, matched)``."""
    events = [FaultEvent.from_dict(d) for d in trace["events"]]
    res = harness.run(events, seed=trace.get("seed"),
                      inj_seed=int(trace.get("inj_seed", 0)),
                      bug=trace.get("bug"))
    matched = (res.failure_oracle == trace.get("failure_oracle")
               and json.dumps(res.snapshots, sort_keys=True)
               == json.dumps(trace["snapshots"], sort_keys=True))
    return res, matched


# ---------------------------------------------------------------------------
# 4. Delta-debugging shrinker
# ---------------------------------------------------------------------------
def make_failure_predicate(harness: DSTHarness, *, inj_seed: int = 0,
                           bug: Optional[str] = None,
                           oracle: Optional[str] = None
                           ) -> Callable[[Sequence[FaultEvent]], bool]:
    """Predicate for :func:`shrink_schedule`: does this schedule still
    fail (optionally: with the SAME oracle — shrinking must preserve the
    bug, not swap it for a different one)?"""
    def failing(events: Sequence[FaultEvent]) -> bool:
        res = harness.run(events, inj_seed=inj_seed, bug=bug)
        if res.failure is None:
            return False
        return oracle is None or res.failure_oracle == oracle
    return failing


def shrink_schedule(events: Sequence[FaultEvent],
                    failing: Callable[[Sequence[FaultEvent]], bool], *,
                    max_runs: int = 200,
                    log: Optional[Callable[[str], None]] = None
                    ) -> List[FaultEvent]:
    """Zeller-style ddmin over the event list, then a 1-minimal polish
    pass and per-burst request shrinking — minimizes a failing schedule
    to a small repro while the predicate keeps failing. The predicate
    must be deterministic (it is: runs are replayable), so the minimized
    schedule is a guaranteed repro artifact."""
    events = list(events)
    if not failing(events):
        raise ValueError("schedule does not fail; nothing to shrink")
    runs = 0

    def say(msg: str) -> None:
        if log:
            log(msg)

    n = 2
    while len(events) >= 2 and runs < max_runs:
        chunk = math.ceil(len(events) / n)
        reduced = False
        for start in range(0, len(events), chunk):
            cand = events[:start] + events[start + chunk:]
            if not cand:
                continue
            runs += 1
            if failing(cand):
                events = cand
                n = max(n - 1, 2)
                reduced = True
                say(f"shrink: {len(events)} events (dropped chunk "
                    f"@{start}, {runs} runs)")
                break
            if runs >= max_runs:
                break
        if not reduced:
            if n >= len(events):
                break
            n = min(n * 2, len(events))
    # 1-minimal polish: no single remaining event can be dropped
    i = 0
    while i < len(events) and len(events) > 1 and runs < max_runs:
        cand = events[:i] + events[i + 1:]
        runs += 1
        if failing(cand):
            events = cand
            say(f"shrink: {len(events)} events (polish)")
        else:
            i += 1
    # payload shrink: drop single requests inside arrival bursts
    changed = True
    while changed and runs < max_runs:
        changed = False
        for idx, ev in enumerate(events):
            if ev.kind != "arrivals":
                continue
            reqs = list(ev.params["reqs"])
            j = 0
            while len(reqs) > 1 and j < len(reqs) and runs < max_runs:
                cand_reqs = reqs[:j] + reqs[j + 1:]
                cand = list(events)
                cand[idx] = FaultEvent(ev.t, "arrivals",
                                       params={"reqs": cand_reqs})
                runs += 1
                if failing(cand):
                    events, reqs = cand, cand_reqs
                    ev = cand[idx]
                    changed = True
                    say(f"shrink: burst @{ev.t} down to {len(reqs)} reqs")
                else:
                    j += 1
    return events


__all__ = [
    "DSTConfig", "DSTHarness", "DSTResult", "DSTViolation", "BUGS",
    "generate_schedule", "run_dst", "replay_trace", "shrink_schedule",
    "make_failure_predicate", "save_trace", "load_trace", "WORKLOAD_KINDS",
]
