from repro.cluster.faults import FaultConfig, FaultInjector
from repro.cluster.network import NetworkConfig, NetworkModel
from repro.cluster.oracle import AccuracyOracle, ArmQuality, DEFAULT_QUALITY
from repro.cluster.simulator import EACOCluster, SimConfig, StepLog
from repro.cluster.workload import QueryEvent, WorkloadConfig, WorkloadGenerator

__all__ = [
    "NetworkModel", "NetworkConfig", "AccuracyOracle", "ArmQuality",
    "DEFAULT_QUALITY", "EACOCluster", "SimConfig", "StepLog",
    "WorkloadGenerator", "WorkloadConfig", "QueryEvent",
    "FaultInjector", "FaultConfig",
]
