from repro.cluster.dst import (
    DSTConfig, DSTHarness, DSTResult, DSTViolation, generate_schedule,
    load_trace, replay_trace, run_dst, save_trace, shrink_schedule,
)
from repro.cluster.faults import (
    FAULT_KINDS, FaultConfig, FaultEvent, FaultInjector,
    TimelineFaultInjector,
)
from repro.cluster.network import NetworkConfig, NetworkModel
from repro.cluster.oracle import AccuracyOracle, ArmQuality, DEFAULT_QUALITY
from repro.cluster.simulator import EACOCluster, SimConfig, StepLog
from repro.cluster.workload import QueryEvent, WorkloadConfig, WorkloadGenerator

__all__ = [
    "NetworkModel", "NetworkConfig", "AccuracyOracle", "ArmQuality",
    "DEFAULT_QUALITY", "EACOCluster", "SimConfig", "StepLog",
    "WorkloadGenerator", "WorkloadConfig", "QueryEvent",
    "FaultInjector", "FaultConfig", "FaultEvent", "TimelineFaultInjector",
    "FAULT_KINDS",
    "DSTConfig", "DSTHarness", "DSTResult", "DSTViolation",
    "generate_schedule", "run_dst", "replay_trace", "shrink_schedule",
    "save_trace", "load_trace",
]
