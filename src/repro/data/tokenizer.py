"""Byte-level tokenizer (vocab = 256 bytes + specials). Used by the real
serving engine and the training example; reduced-arch vocabs (>=1024) always
cover it."""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

PAD, BOS, EOS = 256, 257, 258
N_SPECIAL = 3
VOCAB = 256 + N_SPECIAL


class ByteTokenizer:
    vocab_size = VOCAB
    pad_id, bos_id, eos_id = PAD, BOS, EOS

    def encode(self, text: str, bos: bool = True, eos: bool = False) -> List[int]:
        ids = list(text.encode("utf-8", errors="replace"))
        if bos:
            ids = [BOS] + ids
        if eos:
            ids = ids + [EOS]
        return ids

    def decode(self, ids: Sequence[int]) -> str:
        bs = bytes(i for i in ids if 0 <= i < 256)
        return bs.decode("utf-8", errors="replace")

    def pad_batch(self, seqs: Sequence[Sequence[int]], length: int = 0):
        """Left-align, pad right. Returns (tokens [B,L], lengths [B])."""
        if not length:
            length = max(len(s) for s in seqs)
        B = len(seqs)
        out = np.full((B, length), PAD, np.int32)
        lens = np.zeros(B, np.int32)
        for i, s in enumerate(seqs):
            s = list(s)[:length]
            out[i, : len(s)] = s
            lens[i] = len(s)
        return out, lens


__all__ = ["ByteTokenizer", "VOCAB", "PAD", "BOS", "EOS"]
