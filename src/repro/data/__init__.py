from repro.data.corpus import Corpus, Fact, QAPair, generate_corpus, specialized_like, wiki_like
from repro.data.pipeline import PackedLMDataset
from repro.data.tokenizer import ByteTokenizer, VOCAB

__all__ = ["Corpus", "Fact", "QAPair", "generate_corpus", "wiki_like",
           "specialized_like", "PackedLMDataset", "ByteTokenizer", "VOCAB"]
