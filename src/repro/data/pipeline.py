"""Training data pipeline: deterministic shuffled batches of packed token
sequences from a corpus (used by the end-to-end training example)."""
from __future__ import annotations

from typing import Iterator, Optional, Tuple

import numpy as np

from repro.data.corpus import Corpus
from repro.data.tokenizer import ByteTokenizer


class PackedLMDataset:
    """Concatenate corpus chunk texts into one token stream, serve
    (tokens, targets) windows with epoch shuffling."""

    def __init__(self, corpus: Corpus, seq_len: int, batch: int,
                 seed: int = 0, vocab_cap: Optional[int] = None):
        tok = ByteTokenizer()
        ids = []
        for c in corpus.chunks:
            ids.extend(tok.encode(c.text, bos=True, eos=True))
        stream = np.array(ids, np.int32)
        if vocab_cap:
            stream = stream % vocab_cap
        n_win = (len(stream) - 1) // seq_len
        self.windows = np.stack([
            stream[i * seq_len : i * seq_len + seq_len + 1]
            for i in range(n_win)
        ])
        self.seq_len = seq_len
        self.batch = batch
        self.rng = np.random.default_rng(seed)

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        while True:
            order = self.rng.permutation(len(self.windows))
            for i in range(0, len(order) - self.batch + 1, self.batch):
                w = self.windows[order[i : i + self.batch]]
                yield w[:, :-1], w[:, 1:]

    def n_batches_per_epoch(self) -> int:
        return len(self.windows) // self.batch


__all__ = ["PackedLMDataset"]
