"""Synthetic corpora with controlled entity structure, regional skew and
temporal drift (DESIGN.md §9.4 — reproducible stand-ins for the paper's
Wiki QA and Harry Potter QA datasets).

A corpus is a set of *topics* (one per region-affinity group), each with
entities carrying attribute facts. Articles (chunks) verbalize facts; QA
pairs ask for them (single-hop) or chain through a relation (multi-hop).
Facts can be *versioned over time* — the adaptive-update experiments flip
fact values at given timestamps, so stale edge stores answer incorrectly.
"""
from __future__ import annotations

import itertools
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.retrieval.store import Chunk, make_chunk

_ADJ = ["amber", "crimson", "cobalt", "ivory", "obsidian", "emerald",
        "saffron", "violet", "umber", "teal", "coral", "slate"]
_NOUN = ["falcon", "harbor", "summit", "meadow", "lantern", "orchard",
         "citadel", "glacier", "prairie", "bazaar", "archive", "foundry"]
_ATTRS = ["founder", "capital", "signature dish", "anthem", "festival",
          "guardian", "export", "monument", "motto", "rival"]
_REL = ["ally", "neighbor", "parent guild", "sister city"]


@dataclass
class Fact:
    entity: str
    attr: str
    value: str
    since: float = 0.0            # becomes true at this time (versioning)
    topic: str = ""


@dataclass
class QAPair:
    question: str
    answer: str
    topic: str
    multihop: bool = False
    asks_at: float = 0.0


@dataclass
class Corpus:
    name: str
    topics: List[str]
    facts: List[Fact]
    chunks: List[Chunk]
    qa: List[QAPair]
    relations: Dict[str, str] = field(default_factory=dict)

    def chunks_for_topic(self, topic: str) -> List[Chunk]:
        return [c for c in self.chunks if c.topic == topic]

    def gold_answer(self, q: QAPair, at_time: float = 0.0) -> str:
        return q.answer


def _name(rng: random.Random) -> str:
    return f"{rng.choice(_ADJ)} {rng.choice(_NOUN)}"


def generate_corpus(name: str = "wiki", n_topics: int = 8,
                    entities_per_topic: int = 14, attrs_per_entity: int = 6,
                    multihop_frac: float = 0.3, versioned_frac: float = 0.15,
                    horizon: float = 1000.0, seed: int = 0) -> Corpus:
    rng = random.Random(seed)
    topics = [f"{name}-topic-{i}" for i in range(n_topics)]
    facts: List[Fact] = []
    chunks: List[Chunk] = []
    qa: List[QAPair] = []
    relations: Dict[str, str] = {}
    entities_by_topic: Dict[str, List[str]] = {}

    for ti, topic in enumerate(topics):
        ents = []
        for _ in range(entities_per_topic):
            # entity names carry a topic-specific token so that keyword
            # overlap can actually discriminate edge datasets
            e = f"{_name(rng)} of {name}{ti}x{rng.randint(10, 99)}"
            ents.append(e)
        entities_by_topic[topic] = ents
        for e in ents:
            attrs = rng.sample(_ATTRS, attrs_per_entity)
            rel_target = rng.choice([x for x in ents if x != e])
            relations[e] = rel_target
            rel_name = rng.choice(_REL)
            sentences = [f"{e} is a notable subject of {topic}."]
            sentences.append(f"The {rel_name} of {e} is {rel_target}.")
            for a in attrs:
                v = f"{_name(rng)} {rng.randint(100, 999)}"
                since = 0.0
                if rng.random() < versioned_frac:
                    since = rng.uniform(0.3, 0.8) * horizon
                facts.append(Fact(e, a, v, since, topic))
                when = "" if since == 0 else f" (since update at t={since:.0f})"
                sentences.append(f"The {a} of {e} is {v}{when}.")
            text = " ".join(sentences)
            chunks.append(make_chunk(text, source=topic, topic=topic))

    # single-hop QA
    for f in facts:
        q = f"What is the {f.attr} of {f.entity}?"
        qa.append(QAPair(q, f.value, f.topic, False,
                         asks_at=max(f.since, 0.0)))
    # multi-hop QA: attr of the relation target
    n_multi = int(len(qa) * multihop_frac)
    fact_by_ent: Dict[str, List[Fact]] = {}
    for f in facts:
        fact_by_ent.setdefault(f.entity, []).append(f)
    ents_all = list(relations)
    rng.shuffle(ents_all)
    for e in ents_all[:n_multi]:
        tgt = relations[e]
        tfs = fact_by_ent.get(tgt)
        if not tfs:
            continue
        f = rng.choice(tfs)
        q = (f"What is the {f.attr} of the entity related to {e}, and what "
             f"impact does this connection have?")
        qa.append(QAPair(q, f.value, f.topic, True, asks_at=f.since))

    rng.shuffle(qa)
    return Corpus(name, topics, facts, chunks, qa, relations)


def wiki_like(seed: int = 0) -> Corpus:
    """General-domain stand-in (paper: 139 Wikipedia pages, 571 QA)."""
    return generate_corpus("wiki", n_topics=8, entities_per_topic=14,
                           attrs_per_entity=5, multihop_frac=0.25, seed=seed)


def specialized_like(seed: int = 1) -> Corpus:
    """Specialized-domain stand-in (paper: Harry Potter books, 1180 QA) —
    fewer topics, denser relations, more multi-hop."""
    return generate_corpus("hp", n_topics=4, entities_per_topic=20,
                           attrs_per_entity=7, multihop_frac=0.45,
                           versioned_frac=0.05, seed=seed)


__all__ = ["Corpus", "Fact", "QAPair", "generate_corpus", "wiki_like",
           "specialized_like"]
