"""Pure-jnp oracle for the RBF covariance kernel."""
from __future__ import annotations

import jax.numpy as jnp


def rbf_matrix_ref(x1, x2, lengthscale, signal_var):
    x1 = x1.astype(jnp.float32)
    x2 = x2.astype(jnp.float32)
    n1 = jnp.sum(x1 * x1, axis=1, keepdims=True)
    n2 = jnp.sum(x2 * x2, axis=1, keepdims=True)
    d2 = jnp.maximum(n1 + n2.T - 2.0 * x1 @ x2.T, 0.0)
    return signal_var * jnp.exp(-0.5 * d2 / (lengthscale ** 2))
