"""jit'd public wrapper for the RBF covariance kernel."""
from __future__ import annotations

import jax

from repro.kernels.rbf.kernel import rbf_matrix_pallas
from repro.kernels.rbf.ref import rbf_matrix_ref


def rbf_matrix(x1, x2, lengthscale, signal_var):
    return rbf_matrix_pallas(x1, x2, lengthscale, signal_var,
                             interpret=jax.default_backend() != "tpu")


__all__ = ["rbf_matrix", "rbf_matrix_ref"]
