"""Pallas TPU kernel: RBF covariance matrix for the gate's GPs.

K[i,j] = sv * exp(-0.5 * ||x1_i - x2_j||^2 / l^2), tiled (BM x BN) with the
cross-term on the MXU (||a-b||^2 = |a|^2 + |b|^2 - 2ab). Hyperparameters
arrive as a (1,2) scalar operand [lengthscale, signal_var] so re-tuning does
not retrace.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rbf_kernel(h_ref, x1_ref, x2_ref, o_ref):
    x1 = x1_ref[...].astype(jnp.float32)                 # [BM, D]
    x2 = x2_ref[...].astype(jnp.float32)                 # [BN, D]
    ls = h_ref[0, 0]
    sv = h_ref[0, 1]
    n1 = jnp.sum(x1 * x1, axis=1, keepdims=True)         # [BM,1]
    n2 = jnp.sum(x2 * x2, axis=1, keepdims=True)         # [BN,1]
    cross = jax.lax.dot_general(x1, x2, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    d2 = jnp.maximum(n1 + n2.T - 2.0 * cross, 0.0)
    o_ref[...] = (sv * jnp.exp(-0.5 * d2 / (ls * ls))).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def rbf_matrix_pallas(x1, x2, lengthscale, signal_var, *,
                      block_m: int = 128, block_n: int = 128,
                      interpret: bool = True):
    """x1 [M, D], x2 [N, D] -> K [M, N] (f32)."""
    M, D = x1.shape
    N = x2.shape[0]
    bm = min(block_m, M)
    bn = min(block_n, N)
    pm = (-M) % bm
    pn = (-N) % bn
    if pm:
        x1 = jnp.pad(x1, ((0, pm), (0, 0)))
    if pn:
        x2 = jnp.pad(x2, ((0, pn), (0, 0)))
    h = jnp.stack([jnp.asarray(lengthscale, jnp.float32),
                   jnp.asarray(signal_var, jnp.float32)])[None]

    out = pl.pallas_call(
        _rbf_kernel,
        grid=(x1.shape[0] // bm, x2.shape[0] // bn),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
            pl.BlockSpec((bm, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, D), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x1.shape[0], x2.shape[0]), jnp.float32),
        interpret=interpret,
    )(h, x1, x2)
    return out[:M, :N]
