"""Pallas TPU kernels for the serving hot loops. Each kernel package has
kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd wrapper; interpret
mode off-TPU) and ref.py (pure-jnp oracle used by the allclose sweeps)."""
