"""Pallas TPU kernel: fused retrieval scoring + blockwise top-k merge.

The RAG hot loop: score = E @ q over the chunk-embedding matrix, keeping the
running top-k. On GPU this is typically a shared-memory heap reduction; the
TPU formulation streams [BN, D] embedding tiles through the MXU against the
query vector and merges each tile's scores into a VMEM top-k scratch with k
iterative masked-max passes (k is small; sort-free and VPU-friendly).
Rows beyond ``n_valid`` (capacity padding) are masked to -inf.

Off-TPU note: this kernel is TPU-only in practice. Interpret mode emulates
each grid step in Python, so the blockwise merge that saves HBM traffic on
TPU becomes pure host overhead — measured ~4x slower than the jnp reference
(kernels_bench: 1679us vs 422us, N=4096 D=384). ``ops.retrieval_topk``
therefore falls back to the reference on non-TPU backends; interpret mode
remains available here for correctness tests of the kernel body itself.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _topk_kernel(nvalid_ref, emb_ref, q_ref, vals_ref, idx_ref,
                 cand_v_ref, cand_i_ref, *, block_n: int, k: int):
    """Grid: (N // block_n,). emb_ref [BN, D], q_ref [1, D].
    Outputs vals_ref [1, k], idx_ref [1, k].
    Scratch: cand_v/cand_i [1, BN + k] merge buffers."""
    i = pl.program_id(0)
    n_blocks = pl.num_programs(0)

    emb = emb_ref[...].astype(jnp.float32)               # [BN, D]
    q = q_ref[...].astype(jnp.float32)                   # [1, D]
    scores = jax.lax.dot_general(
        emb, q, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]        # [BN]
    rows = i * block_n + jax.lax.iota(jnp.int32, block_n)
    scores = jnp.where(rows < nvalid_ref[0], scores, NEG_INF)

    @pl.when(i == 0)
    def _init():
        cand_v_ref[...] = jnp.full_like(cand_v_ref, NEG_INF)
        cand_i_ref[...] = jnp.zeros_like(cand_i_ref)

    # merge buffer: [previous top-k | this block's scores]
    cand_v_ref[0, k:] = scores
    cand_i_ref[0, k:] = rows

    # k iterative masked-max passes extract the new top-k in order
    cv = cand_v_ref[0, :]
    ci = cand_i_ref[0, :]
    new_v = jnp.full((k,), NEG_INF, jnp.float32)
    new_i = jnp.zeros((k,), jnp.int32)
    for j in range(k):
        m = jnp.max(cv)
        am = jnp.argmax(cv)
        new_v = new_v.at[j].set(m)
        new_i = new_i.at[j].set(ci[am])
        cv = cv.at[am].set(NEG_INF)
    cand_v_ref[0, :k] = new_v
    cand_i_ref[0, :k] = new_i

    @pl.when(i == n_blocks - 1)
    def _done():
        vals_ref[0, :] = cand_v_ref[0, :k]
        idx_ref[0, :] = cand_i_ref[0, :k]


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def retrieval_topk_pallas(emb, q, k: int = 5, *, block_n: int = 512,
                          n_valid=None, interpret: bool = True):
    """emb [N, D] (rows may be padding), q [D] -> (vals [k], idx [k])."""
    N, D = emb.shape
    if n_valid is None:
        n_valid = N
    n_valid = jnp.asarray([n_valid], jnp.int32)
    # pad N to a block multiple
    block_n = min(block_n, max(N, 8))
    pad = (-N) % block_n
    if pad:
        emb = jnp.pad(emb, ((0, pad), (0, 0)))
    Np = emb.shape[0]

    kernel = functools.partial(_topk_kernel, block_n=block_n, k=k)
    vals, idx = pl.pallas_call(
        kernel,
        grid=(Np // block_n,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, k), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, block_n + k), jnp.float32),
            pltpu.VMEM((1, block_n + k), jnp.int32),
        ],
        interpret=interpret,
    )(n_valid, emb, q[None])
    return vals[0], idx[0]
