"""jit'd public wrapper for fused retrieval top-k."""
from __future__ import annotations

import jax

from repro.kernels.retrieval_topk.kernel import retrieval_topk_pallas
from repro.kernels.retrieval_topk.ref import retrieval_topk_ref


def retrieval_topk(emb, q, k: int = 5, *, n_valid=None, block_n: int = 512):
    return retrieval_topk_pallas(emb, q, k, block_n=block_n, n_valid=n_valid,
                                 interpret=jax.default_backend() != "tpu")


__all__ = ["retrieval_topk", "retrieval_topk_ref"]
