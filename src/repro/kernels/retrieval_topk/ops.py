"""jit'd public wrapper for fused retrieval top-k.

Dispatch policy: the Pallas kernel only runs where it compiles — on TPU.
Off-TPU it previously ran in interpret mode, which benchmarked ~4x SLOWER
than the plain-jnp reference (results/benchmarks/kernels_bench.json:
1679us vs 422us at N=4096, D=384): interpret mode executes the kernel body
block-by-block in Python, so the blockwise top-k merge — whose whole point
is avoiding HBM round-trips on TPU — degenerates into per-block host
dispatch overhead. A real fallback therefore routes to the reference, which
XLA compiles to a single fused matvec + top_k.
"""
from __future__ import annotations

import jax

from repro.kernels.retrieval_topk.kernel import retrieval_topk_pallas
from repro.kernels.retrieval_topk.ref import retrieval_topk_ref


def retrieval_topk(emb, q, k: int = 5, *, n_valid=None, block_n: int = 512):
    if jax.default_backend() == "tpu":
        return retrieval_topk_pallas(emb, q, k, block_n=block_n,
                                     n_valid=n_valid, interpret=False)
    return retrieval_topk_ref(emb, q, k, n_valid=n_valid)


__all__ = ["retrieval_topk", "retrieval_topk_ref"]
