"""Pure-jnp oracle for retrieval top-k."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def retrieval_topk_ref(emb, q, k: int = 5, n_valid=None):
    N = emb.shape[0]
    scores = (emb.astype(jnp.float32) @ q.astype(jnp.float32))
    if n_valid is not None:
        scores = jnp.where(jnp.arange(N) < n_valid, scores, -1e30)
    return jax.lax.top_k(scores, k)
