"""Pure-jnp oracle for the flash-decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q [B,H,hd]; k_cache/v_cache [B,S,KV,hd]; lengths [B] -> [B,H,hd]."""
    B, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    k = k_cache.astype(jnp.float32)
    v = v_cache.astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k) / np.sqrt(hd)
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v)
    return out.reshape(B, H, hd).astype(q.dtype)
