"""Pure-jnp oracles for the flash-decode kernels (contiguous + paged)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q [B,H,hd]; k_cache/v_cache [B,S,KV,hd]; lengths [B] -> [B,H,hd].

    Rows with ``length == 0`` return zeros (no valid keys to attend to) —
    the same contract the kernel implements.
    """
    B, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    k = k_cache.astype(jnp.float32)
    v = v_cache.astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k) / np.sqrt(hd)
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v)
    return out.reshape(B, H, hd).astype(q.dtype)


def paged_decode_attention_ref(q, k_arena, v_arena, page_table, lengths):
    """Gather-based paged oracle.

    q [B,H,hd]; arenas [P, page_size, KV, hd]; page_table [B, n_pages] of
    physical page ids; lengths [B] -> [B,H,hd]. Logical position
    ``t`` of row ``b`` lives at ``arena[page_table[b, t // page_size],
    t % page_size]``; the gather materializes each row's logical
    [n_pages * page_size, KV, hd] view and defers to the contiguous oracle.
    """
    B = q.shape[0]
    _, page_size, KV, hd = k_arena.shape
    n_pages = page_table.shape[1]
    k = k_arena[page_table].reshape(B, n_pages * page_size, KV, hd)
    v = v_arena[page_table].reshape(B, n_pages * page_size, KV, hd)
    return decode_attention_ref(q, k, v, lengths)


def paged_append_attention_ref(q, k_arena, v_arena, page_table, prefix_len,
                               total_len):
    """Gather-based oracle for chunked suffix prefill against paged KV.

    q [S, H, hd] — suffix token i sits at absolute position
    ``prefix_len + i``; arenas [P, page_size, KV, hd]; page_table [n_pages]
    physical page ids for one request; prefix_len/total_len scalars with
    ``total_len = prefix_len + valid_suffix``. The gather materializes the
    request's logical [n_pages * page_size, KV, hd] view (prefix pages
    written by whoever shared them + the suffix this prefill just
    scattered) and runs causal attention: key position <= query position,
    both bounded by ``total_len``. Padded q rows (position >= total_len)
    return zeros.
    """
    S, H, hd = q.shape
    _, page_size, KV, _ = k_arena.shape
    n_pages = page_table.shape[0]
    T = n_pages * page_size
    k = k_arena[page_table].reshape(T, KV, hd).astype(jnp.float32)
    v = v_arena[page_table].reshape(T, KV, hd).astype(jnp.float32)
    G = H // KV
    qg = q.reshape(S, KV, G, hd).astype(jnp.float32)
    qpos = prefix_len + jnp.arange(S)
    kpos = jnp.arange(T)
    valid = (kpos[None, :] <= qpos[:, None]) & (qpos[:, None] < total_len)
    s = jnp.einsum("skgd,tkd->kgst", qg, k) / np.sqrt(hd)
    s = jnp.where(valid[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid[None, None], p, 0.0)
    out = jnp.einsum("kgst,tkd->skgd", p, v)
    return out.reshape(S, H, hd).astype(q.dtype)
