"""Pallas TPU kernel: fused GQA flash-decode attention.

One new query token per sequence attends to a [S, KV, hd] KV cache with an
online-softmax accumulation over sequence blocks — the serving hot loop.

TPU adaptation (vs a CUDA warp-per-row decode kernel): the grid iterates
(batch, kv_head, seq_block); each program instance processes a whole
[BS, hd] cache tile from VMEM against the [G, hd] query group on the MXU,
with running max / sum-exp / weighted-value accumulators in VMEM scratch.
hd is kept at a 128-lane multiple and BS at a multiple of 8 for the VPU/MXU
layout. Masking uses the per-row valid length (ring-buffer caches pass
length=min(len, S) with order-independent softmax).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, block_s: int, scale: float):
    """Grid: (B, KV, S//block_s) — S is the innermost (sequential) axis.

    q_ref:   [G, hd]      (this batch row, this kv head's query group)
    k_ref:   [block_s, hd]
    v_ref:   [block_s, hd]
    len_ref: [1]          (valid cache length for this row)
    o_ref:   [G, hd]
    scratch: m_ref [G, 1], l_ref [G, 1], acc_ref [G, hd]  (f32)
    """
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)                    # [G, hd]
    k = k_ref[...].astype(jnp.float32)                    # [BS, hd]
    v = v_ref[...].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # mask positions beyond the valid length
    pos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = pos < len_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                                   # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                # [G, BS]
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(s_idx == n_s - 1)
    def _done():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention_pallas(q, k_cache, v_cache, lengths, *,
                            block_s: int = 256, interpret: bool = True):
    """q [B,H,hd]; k_cache/v_cache [B,S,KV,hd]; lengths [B] -> [B,H,hd]."""
    B, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    block_s = min(block_s, S)
    while S % block_s:
        block_s -= 1
    scale = 1.0 / (hd ** 0.5)

    qg = q.reshape(B, KV, G, hd)
    lengths = lengths.astype(jnp.int32)

    grid = (B, KV, S // block_s)
    kernel = functools.partial(_decode_attn_kernel, block_s=block_s,
                               scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, s: (b,)),                      # len
            pl.BlockSpec((None, None, G, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((None, block_s, None, hd),
                         lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((None, block_s, None, hd),
                         lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G, hd),
                               lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),       # running max
            pltpu.VMEM((G, 1), jnp.float32),       # running sum-exp
            pltpu.VMEM((G, hd), jnp.float32),      # running weighted values
        ],
        interpret=interpret,
    )(lengths, qg, k_cache, v_cache)
    return out.reshape(B, H, hd)
