"""Pallas TPU kernels: fused GQA flash-decode attention, contiguous + paged.

One new query token per sequence attends to its KV cache with an
online-softmax accumulation over sequence blocks — the serving hot loop.

Two cache layouts share one kernel body:

* contiguous — ``k_cache/v_cache [B, S, KV, hd]``: the grid iterates
  (batch, kv_head, seq_block) and each program consumes one ``[block_s, hd]``
  cache tile.
* paged — ``k_arena/v_arena [num_pages, page_size, KV, hd]`` plus a per-row
  ``page_table [B, n_pages]`` of physical page ids: the grid's seq-block axis
  indexes *through the page table* (one program per logical page) using
  Pallas scalar prefetch, so the same online-softmax accumulators run over a
  scattered arena without ever materializing a contiguous copy.

TPU adaptation (vs a CUDA warp-per-row decode kernel): each program instance
processes a whole ``[BS, hd]`` cache tile from VMEM against the ``[G, hd]``
query group on the MXU, with running max / sum-exp / weighted-value
accumulators in VMEM scratch. hd is kept at a 128-lane multiple and BS at a
multiple of 8 for the VPU/MXU layout. Masking uses the per-row valid length;
probabilities AND values are zeroed outside it, so out-of-bounds tile padding
(NaN in interpret mode, garbage on TPU) and ``length == 0`` rows (defined to
return zeros) never reach the accumulators.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _flash_decode_body(len_ref, q_ref, k_ref, v_ref, o_ref,
                       m_ref, l_ref, acc_ref, *, block_s: int, scale: float):
    """Shared online-softmax block step; grid axis 2 walks sequence tiles.

    q_ref:   [G, hd]      (this batch row, this kv head's query group)
    k_ref:   [block_s, hd]
    v_ref:   [block_s, hd]
    len_ref: [1]          (valid cache length for this row)
    o_ref:   [G, hd]
    scratch: m_ref [G, 1], l_ref [G, 1], acc_ref [G, hd]  (f32)

    Tile rows hold *logical* positions ``s_idx * block_s + i`` regardless of
    layout: contiguous callers map grid index -> cache offset directly,
    paged callers map it through the page table in their BlockSpecs, so the
    masking below is layout-agnostic.
    """
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)                    # [G, hd]
    k = k_ref[...].astype(jnp.float32)                    # [BS, hd]
    v = v_ref[...].astype(jnp.float32)

    tile_start = s_idx * block_s
    length = len_ref[0]
    # zero cache-value rows beyond the valid length BEFORE they can meet the
    # accumulators: tile padding past the array end is undefined (NaN in
    # interpret mode) and 0 * NaN would poison the p @ v product
    pos_col = tile_start + jax.lax.broadcasted_iota(jnp.int32, (block_s, 1), 0)
    v = jnp.where(pos_col < length, v, 0.0)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = tile_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = pos < length
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                                   # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    # masked probabilities are forced to exact 0 — a fully-masked tile would
    # otherwise contribute exp(NEG_INF - NEG_INF) = 1 per position (NEG_INF
    # is a finite sentinel) and a length-0 row would average garbage
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)         # [G, BS]
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(s_idx == n_s - 1)
    def _done():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                      ).astype(o_ref.dtype)


def _paged_decode_attn_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                              m_ref, l_ref, acc_ref, *, page_size: int,
                              scale: float):
    """Paged layout. Grid: (B, KV, n_pages); ``pt_ref`` is the scalar-
    prefetched page table — the k/v BlockSpecs already used it to DMA the
    physical page for this (row, logical page) program, so the body only
    needs the logical position ``page_idx * page_size`` for masking."""
    _flash_decode_body(len_ref, q_ref, k_ref, v_ref, o_ref,
                       m_ref, l_ref, acc_ref, block_s=page_size, scale=scale)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention_pallas(q, k_cache, v_cache, lengths, *,
                            block_s: int = 256, interpret: bool = True):
    """q [B,H,hd]; k_cache/v_cache [B,S,KV,hd]; lengths [B] -> [B,H,hd].

    ``block_s`` is clamped to cover S at the 8-multiple VPU/MXU layout
    constraint; a cache shorter than the block therefore runs a single
    (padded, masked) program instead of a zero-size grid.
    """
    B, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    block_s = max(8, min(_round_up(block_s, 8), _round_up(S, 8)))
    scale = 1.0 / (hd ** 0.5)

    qg = q.reshape(B, KV, G, hd)
    lengths = lengths.astype(jnp.int32)

    grid = (B, KV, -(-S // block_s))     # ceil: ragged tail tile is masked
    kernel = functools.partial(_flash_decode_body, block_s=block_s,
                               scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, s: (b,)),                      # len
            pl.BlockSpec((None, None, G, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((None, block_s, None, hd),
                         lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((None, block_s, None, hd),
                         lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G, hd),
                               lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),       # running max
            pltpu.VMEM((G, 1), jnp.float32),       # running sum-exp
            pltpu.VMEM((G, hd), jnp.float32),      # running weighted values
        ],
        interpret=interpret,
    )(lengths, qg, k_cache, v_cache)
    return out.reshape(B, H, hd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(q, k_arena, v_arena, page_table, lengths, *,
                                  interpret: bool = True):
    """Paged flash-decode: q [B,H,hd]; arenas [P, page_size, KV, hd];
    page_table [B, n_pages] int32 physical page ids; lengths [B] -> [B,H,hd].

    One program per (row, kv_head, logical page). The page table rides in as
    a scalar-prefetch operand so the k/v BlockSpec index maps can chase it:
    program (b, h, i) DMAs physical page ``page_table[b, i]``. Entries past a
    row's valid length may point anywhere (allocators pad with a trash page)
    — they are masked by ``lengths`` exactly like the contiguous tail.
    """
    B, H, hd = q.shape
    P, page_size, KV, _ = k_arena.shape
    n_pages = page_table.shape[1]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)

    qg = q.reshape(B, KV, G, hd)
    lengths = lengths.astype(jnp.int32)
    page_table = page_table.astype(jnp.int32)

    kernel = functools.partial(_paged_decode_attn_kernel,
                               page_size=page_size, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                       # the page table
        grid=(B, KV, n_pages),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, i, pt: (b,)),                  # len
            pl.BlockSpec((None, None, G, hd),
                         lambda b, h, i, pt: (b, h, 0, 0)),
            pl.BlockSpec((None, page_size, None, hd),
                         lambda b, h, i, pt: (pt[b, i], 0, h, 0)),
            pl.BlockSpec((None, page_size, None, hd),
                         lambda b, h, i, pt: (pt[b, i], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G, hd),
                               lambda b, h, i, pt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(page_table, lengths, qg, k_arena, v_arena)
    return out.reshape(B, H, hd)
