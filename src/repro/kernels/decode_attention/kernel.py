"""Pallas TPU kernels: fused GQA flash attention against a KV cache —
contiguous decode, paged decode, and paged *append* (chunked suffix
prefill).

One online-softmax accumulation over sequence blocks serves three callers:

* contiguous decode — ``k_cache/v_cache [B, S, KV, hd]``: the grid iterates
  (batch, kv_head, seq_block) and each program consumes one ``[block_s, hd]``
  cache tile.
* paged decode — ``k_arena/v_arena [num_pages, page_size, KV, hd]`` plus a
  per-row ``page_table [B, n_pages]`` of physical page ids: the grid's
  seq-block axis indexes *through the page table* (one program per logical
  page) using Pallas scalar prefetch, so the same online-softmax
  accumulators run over a scattered arena without materializing a
  contiguous copy.
* paged append — the multi-token sibling of paged decode, used by
  prefix-cached suffix prefill: q is a ``[block_q, H, hd]`` chunk of new
  tokens at absolute positions ``prefix_len + i``, and the grid's seq axis
  chases the (scalar-prefetched) page table over *prefix + suffix* pages.
  The causal mask lives entirely inside the q tile's position arithmetic:
  key position <= query position admits every shared-prefix key and the
  already-written part of the suffix, exactly like a causal prefill over
  the logically reassembled cache.

TPU adaptation (vs a CUDA warp-per-row decode kernel): each program instance
processes a whole ``[BS, hd]`` cache tile from VMEM against the query tile
(``[G, hd]`` for decode, ``[block_q * G, hd]`` for append) on the MXU, with
running max / sum-exp / weighted-value accumulators in VMEM scratch. hd is
kept at a 128-lane multiple and BS at a multiple of 8 for the VPU/MXU
layout. Masking uses per-row valid lengths/positions; probabilities AND
values are zeroed outside them, so out-of-bounds tile padding (NaN in
interpret mode, garbage on TPU) and fully-masked rows (defined to return
zeros) never reach the accumulators.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _softmax_accumulate(q, k, v, valid, m_ref, l_ref, acc_ref, *,
                        scale: float):
    """One online-softmax block step, shared by decode and append.

    q [R, hd], k/v [BS, hd] (f32), valid [R, BS] boolean keep-mask with the
    caller's causal/length semantics baked in; running max / sum-exp /
    weighted-value accumulators in VMEM scratch ([R, 1], [R, 1], [R, hd]).
    The caller must zero v rows that can hold undefined data BEFORE calling
    (0 * NaN would poison the p @ v product)."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_ref[...]                                   # [R, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    # masked probabilities are forced to exact 0 — a fully-masked tile would
    # otherwise contribute exp(NEG_INF - NEG_INF) = 1 per position (NEG_INF
    # is a finite sentinel) and a fully-masked row would average garbage
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)         # [R, BS]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _flash_decode_body(len_ref, q_ref, k_ref, v_ref, o_ref,
                       m_ref, l_ref, acc_ref, *, block_s: int, scale: float):
    """Decode online-softmax block step; grid axis 2 walks sequence tiles.

    q_ref:   [G, hd]      (this batch row, this kv head's query group)
    k_ref:   [block_s, hd]
    v_ref:   [block_s, hd]
    len_ref: [1]          (valid cache length for this row)
    o_ref:   [G, hd]
    scratch: m_ref [G, 1], l_ref [G, 1], acc_ref [G, hd]  (f32)

    Tile rows hold *logical* positions ``s_idx * block_s + i`` regardless of
    layout: contiguous callers map grid index -> cache offset directly,
    paged callers map it through the page table in their BlockSpecs, so the
    masking below is layout-agnostic.
    """
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)                    # [G, hd]
    k = k_ref[...].astype(jnp.float32)                    # [BS, hd]
    v = v_ref[...].astype(jnp.float32)

    tile_start = s_idx * block_s
    length = len_ref[0]
    # zero cache-value rows beyond the valid length BEFORE they can meet the
    # accumulators: tile padding past the array end is undefined (NaN in
    # interpret mode) and 0 * NaN would poison the p @ v product
    pos_col = tile_start + jax.lax.broadcasted_iota(jnp.int32, (block_s, 1), 0)
    v = jnp.where(pos_col < length, v, 0.0)

    pos = tile_start + jax.lax.broadcasted_iota(
        jnp.int32, (q.shape[0], block_s), 1)
    _softmax_accumulate(q, k, v, pos < length, m_ref, l_ref, acc_ref,
                        scale=scale)

    @pl.when(s_idx == n_s - 1)
    def _done():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                      ).astype(o_ref.dtype)


def _paged_decode_attn_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                              m_ref, l_ref, acc_ref, *, page_size: int,
                              scale: float):
    """Paged layout. Grid: (B, KV, n_pages); ``pt_ref`` is the scalar-
    prefetched page table — the k/v BlockSpecs already used it to DMA the
    physical page for this (row, logical page) program, so the body only
    needs the logical position ``page_idx * page_size`` for masking."""
    _flash_decode_body(len_ref, q_ref, k_ref, v_ref, o_ref,
                       m_ref, l_ref, acc_ref, block_s=page_size, scale=scale)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention_pallas(q, k_cache, v_cache, lengths, *,
                            block_s: int = 256, interpret: bool = True):
    """q [B,H,hd]; k_cache/v_cache [B,S,KV,hd]; lengths [B] -> [B,H,hd].

    ``block_s`` is clamped to cover S at the 8-multiple VPU/MXU layout
    constraint; a cache shorter than the block therefore runs a single
    (padded, masked) program instead of a zero-size grid.
    """
    B, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    block_s = max(8, min(_round_up(block_s, 8), _round_up(S, 8)))
    scale = 1.0 / (hd ** 0.5)

    qg = q.reshape(B, KV, G, hd)
    lengths = lengths.astype(jnp.int32)

    grid = (B, KV, -(-S // block_s))     # ceil: ragged tail tile is masked
    kernel = functools.partial(_flash_decode_body, block_s=block_s,
                               scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, s: (b,)),                      # len
            pl.BlockSpec((None, None, G, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((None, block_s, None, hd),
                         lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((None, block_s, None, hd),
                         lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G, hd),
                               lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),       # running max
            pltpu.VMEM((G, 1), jnp.float32),       # running sum-exp
            pltpu.VMEM((G, hd), jnp.float32),      # running weighted values
        ],
        interpret=interpret,
    )(lengths, qg, k_cache, v_cache)
    return out.reshape(B, H, hd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(q, k_arena, v_arena, page_table, lengths, *,
                                  interpret: bool = True):
    """Paged flash-decode: q [B,H,hd]; arenas [P, page_size, KV, hd];
    page_table [B, n_pages] int32 physical page ids; lengths [B] -> [B,H,hd].

    One program per (row, kv_head, logical page). The page table rides in as
    a scalar-prefetch operand so the k/v BlockSpec index maps can chase it:
    program (b, h, i) DMAs physical page ``page_table[b, i]``. Entries past a
    row's valid length may point anywhere (allocators pad with a trash page)
    — they are masked by ``lengths`` exactly like the contiguous tail.
    """
    B, H, hd = q.shape
    P, page_size, KV, _ = k_arena.shape
    n_pages = page_table.shape[1]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)

    qg = q.reshape(B, KV, G, hd)
    lengths = lengths.astype(jnp.int32)
    page_table = page_table.astype(jnp.int32)

    kernel = functools.partial(_paged_decode_attn_kernel,
                               page_size=page_size, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                       # the page table
        grid=(B, KV, n_pages),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, i, pt: (b,)),                  # len
            pl.BlockSpec((None, None, G, hd),
                         lambda b, h, i, pt: (b, h, 0, 0)),
            pl.BlockSpec((None, page_size, None, hd),
                         lambda b, h, i, pt: (pt[b, i], 0, h, 0)),
            pl.BlockSpec((None, page_size, None, hd),
                         lambda b, h, i, pt: (pt[b, i], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G, hd),
                               lambda b, h, i, pt: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(page_table, lengths, qg, k_arena, v_arena)
    return out.reshape(B, H, hd)


def _paged_append_attn_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                              m_ref, l_ref, acc_ref, *, page_size: int,
                              block_q: int, group: int, scale: float):
    """Paged append (chunked suffix prefill). Grid: (n_q_chunks, KV,
    n_pages) with the page axis innermost so the accumulators carry across
    the whole logical sequence; the k/v BlockSpecs already chased the
    scalar-prefetched page table, so the body only needs position
    arithmetic.

    q_ref: [block_q * G, hd] — row r is query token ``r // G`` of this
    chunk, group member ``r % G``; its absolute position is ``prefix_len +
    chunk_start + r // G``. The causal mask admits key positions <= the
    query position (shared prefix + already-written suffix); q rows past the
    valid suffix have position >= total_len and mask out entirely (their
    output is the defined zero and the engine never reads them).
    len_ref: [2] = (prefix_len, total_len = prefix_len + suffix_len).
    """
    i = pl.program_id(2)
    n_i = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)                    # [block_q*G, hd]
    k = k_ref[...].astype(jnp.float32)                    # [page_size, hd]
    v = v_ref[...].astype(jnp.float32)

    prefix = len_ref[0]
    total = len_ref[1]
    page_start = i * page_size
    # zero value rows at positions never written (stale pages / trash /
    # interpret-mode padding) before they can meet the accumulators
    vpos = page_start + jax.lax.broadcasted_iota(
        jnp.int32, (page_size, 1), 0)
    v = jnp.where(vpos < total, v, 0.0)

    rows = q.shape[0]
    qpos = (prefix + pl.program_id(0) * block_q
            + jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0) // group)
    kpos = page_start + jax.lax.broadcasted_iota(
        jnp.int32, (rows, page_size), 1)
    valid = (kpos <= qpos) & (qpos < total)               # causal + q padding
    _softmax_accumulate(q, k, v, valid, m_ref, l_ref, acc_ref, scale=scale)

    @pl.when(i == n_i - 1)
    def _done():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def paged_append_attention_pallas(q, k_arena, v_arena, page_table, lens, *,
                                  block_q: int = 128, interpret: bool = True):
    """Chunked paged append attention (prefix-cached suffix prefill).

    q [S, H, hd] — S suffix tokens (padded; multiple of 8) whose token i
    sits at absolute position ``prefix_len + i``; arenas
    [P, page_size, KV, hd]; page_table [n_pages] int32 physical page ids for
    ONE request (batch-1 admission path); lens [2] int32 =
    (prefix_len, total_len). Returns [S, H, hd].

    The grid is (S / block_q, KV, n_pages): each program attends one
    ``[block_q * G, hd]`` query tile to one physical page, chasing the
    scalar-prefetched page table over prefix AND suffix pages with the
    causal mask applied inside the tile — so a request that shares its first
    ``prefix_len`` tokens reads the prefix KV another request wrote, without
    ever materializing a contiguous copy. ``block_q`` is clamped to divide S
    at a multiple of 8.
    """
    S, H, hd = q.shape
    _, page_size, KV, _ = k_arena.shape
    n_pages = page_table.shape[0]
    G = H // KV
    if S % 8:
        raise ValueError(
            f"suffix length {S} must be padded to a multiple of 8 "
            "(VPU/MXU sublane layout)")
    block_q = min(block_q, S)
    while S % block_q:
        block_q -= 8
    n_qc = S // block_q
    scale = 1.0 / (hd ** 0.5)

    # [S, H, hd] -> [KV, n_qc, block_q * G, hd]: kv-head-major, rows flatten
    # (token-in-chunk, group) so row r of a tile is token r // G
    qg = (q.reshape(S, KV, G, hd).transpose(1, 0, 2, 3)
          .reshape(KV, n_qc, block_q * G, hd))
    lens = lens.astype(jnp.int32)
    page_table = page_table.astype(jnp.int32)

    kernel = functools.partial(_paged_append_attn_kernel,
                               page_size=page_size, block_q=block_q,
                               group=G, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                       # the page table
        grid=(n_qc, KV, n_pages),
        in_specs=[
            pl.BlockSpec((2,), lambda c, h, i, pt: (0,)),                  # lens
            pl.BlockSpec((None, None, block_q * G, hd),
                         lambda c, h, i, pt: (h, c, 0, 0)),
            pl.BlockSpec((None, page_size, None, hd),
                         lambda c, h, i, pt: (pt[i], 0, h, 0)),
            pl.BlockSpec((None, page_size, None, hd),
                         lambda c, h, i, pt: (pt[i], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q * G, hd),
                               lambda c, h, i, pt: (h, c, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q * G, 1), jnp.float32),
            pltpu.VMEM((block_q * G, 1), jnp.float32),
            pltpu.VMEM((block_q * G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((KV, n_qc, block_q * G, hd), q.dtype),
        interpret=interpret,
    )(page_table, lens, qg, k_arena, v_arena)
    return (out.reshape(KV, n_qc, block_q, G, hd)
            .transpose(1, 2, 0, 3, 4).reshape(S, H, hd))
