"""jit'd public wrappers for flash-decode attention (contiguous + paged).

Dispatch policy: the Pallas kernels run compiled on TPU; every other backend
gets the pure-jnp reference, which XLA fuses well — interpret-mode Pallas is
a Python-level emulator meant for kernel correctness work, not serving (see
the retrieval_topk note for measurements of that gap).
"""
from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import (
    decode_attention_pallas, paged_decode_attention_pallas,
)
from repro.kernels.decode_attention.ref import (
    decode_attention_ref, paged_decode_attention_ref,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode_attention(q, k_cache, v_cache, lengths, *, block_s: int = 256):
    """Fused GQA flash-decode. q [B,H,hd]; caches [B,S,KV,hd]; lengths [B].

    ``block_s`` is a tiling hint; the kernel clamps it to cover S at the
    8-multiple layout constraint, so S < block_s no longer collapses to a
    zero-size sequence grid.
    """
    if _on_tpu():
        return decode_attention_pallas(q, k_cache, v_cache, lengths,
                                       block_s=block_s, interpret=False)
    return decode_attention_ref(q, k_cache, v_cache, lengths)


def paged_decode_attention(q, k_arena, v_arena, page_table, lengths):
    """Paged GQA flash-decode. q [B,H,hd]; arenas [P, page_size, KV, hd];
    page_table [B, n_pages] physical page ids; lengths [B]."""
    if _on_tpu():
        return paged_decode_attention_pallas(q, k_arena, v_arena, page_table,
                                             lengths, interpret=False)
    return paged_decode_attention_ref(q, k_arena, v_arena, page_table,
                                      lengths)


__all__ = ["decode_attention", "decode_attention_ref",
           "paged_decode_attention", "paged_decode_attention_ref"]
