"""jit'd public wrapper: Pallas on TPU, interpret-mode elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode_attention(q, k_cache, v_cache, lengths, *, block_s: int = 256):
    """Fused GQA flash-decode. q [B,H,hd]; caches [B,S,KV,hd]; lengths [B]."""
    return decode_attention_pallas(q, k_cache, v_cache, lengths,
                                   block_s=block_s,
                                   interpret=not _on_tpu())


__all__ = ["decode_attention", "decode_attention_ref"]
