"""jit'd public wrappers for flash-decode attention (contiguous + paged).

Dispatch policy: the Pallas kernels run compiled on TPU; every other backend
gets the pure-jnp reference, which XLA fuses well — interpret-mode Pallas is
a Python-level emulator meant for kernel correctness work, not serving (see
the retrieval_topk note for measurements of that gap).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import (
    decode_attention_pallas, paged_append_attention_pallas,
    paged_decode_attention_pallas,
)
from repro.kernels.decode_attention.ref import (
    decode_attention_ref, paged_append_attention_ref,
    paged_decode_attention_ref,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode_attention(q, k_cache, v_cache, lengths, *, block_s: int = 256):
    """Fused GQA flash-decode. q [B,H,hd]; caches [B,S,KV,hd]; lengths [B].

    ``block_s`` is a tiling hint; the kernel clamps it to cover S at the
    8-multiple layout constraint, so S < block_s no longer collapses to a
    zero-size sequence grid.
    """
    if _on_tpu():
        return decode_attention_pallas(q, k_cache, v_cache, lengths,
                                       block_s=block_s, interpret=False)
    return decode_attention_ref(q, k_cache, v_cache, lengths)


def paged_decode_attention(q, k_arena, v_arena, page_table, lengths):
    """Paged GQA flash-decode. q [B,H,hd]; arenas [P, page_size, KV, hd];
    page_table [B, n_pages] physical page ids; lengths [B]."""
    if _on_tpu():
        return paged_decode_attention_pallas(q, k_arena, v_arena, page_table,
                                             lengths, interpret=False)
    return paged_decode_attention_ref(q, k_arena, v_arena, page_table,
                                      lengths)


def paged_append_attention(q, k_arena, v_arena, page_table, prefix_len,
                           total_len, *, block_q: int = 128):
    """Chunked paged append attention — the multi-token sibling of
    :func:`paged_decode_attention`, used by prefix-cached suffix prefill.

    q [S, H, hd] (suffix token i at absolute position ``prefix_len + i``);
    arenas [P, page_size, KV, hd]; page_table [n_pages] for ONE request;
    prefix_len / total_len int32 scalars (``total_len`` = prefix + valid
    suffix; padded q rows beyond it return zeros).
    """
    if _on_tpu():
        lens = jnp.stack([jnp.asarray(prefix_len, jnp.int32),
                          jnp.asarray(total_len, jnp.int32)])
        return paged_append_attention_pallas(q, k_arena, v_arena, page_table,
                                             lens, block_q=block_q,
                                             interpret=False)
    return paged_append_attention_ref(q, k_arena, v_arena, page_table,
                                      prefix_len, total_len)


__all__ = ["decode_attention", "decode_attention_ref",
           "paged_decode_attention", "paged_decode_attention_ref",
           "paged_append_attention", "paged_append_attention_ref"]
