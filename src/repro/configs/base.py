"""Config system: model/architecture configs and input-shape specs.

Every assigned architecture gets one module in this package defining a
``ModelConfig`` with the exact published hyperparameters (source cited in the
module docstring) plus a ``reduced()`` smoke variant (<=2 layers,
d_model<=512, <=4 experts) used by CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Input shapes (assigned; see the task brief)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    top_k: int = 0
    n_shared_experts: int = 0
    expert_ff: int = 0            # per-expert hidden size
    first_k_dense: int = 0        # leading layers that use a dense FFN
    dense_ff: int = 0             # hidden size of those dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # "auto": XLA SPMD propagation; "ep": explicit expert-parallel shard_map
    # schedule (local dispatch -> local expert FFN -> psum combine) — the
    # beyond-paper §Perf optimization.
    shard_mode: str = "auto"


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 0         # compressed kv dim (cached at decode)
    q_lora_rank: int = 0          # 0 = direct q projection
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 0
    d_head: int = 64              # per-head channel dim for mamba2 / rwkv
    expand: int = 2               # mamba2 inner expansion
    conv_width: int = 4           # mamba2 depthwise conv width
    chunk: int = 256              # chunked-scan block length


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                  # 0 for attention-free (rwkv)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False

    moe: MoEConfig = field(default_factory=MoEConfig)
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # sliding-window attention: window size and pattern (local:global).
    sliding_window: int = 0       # 0 = full attention everywhere
    swa_pattern: Tuple[int, int] = (0, 0)   # (n_local, n_global) per repeat unit

    # hybrid (zamba2): one shared attention block applied every k SSM blocks
    shared_attn_every: int = 0

    # enc-dec (whisper): encoder depth; n_layers is the decoder depth
    n_enc_layers: int = 0
    n_frames: int = 1500          # stubbed audio-frame embeddings fed to encoder

    # vlm (llama-3.2-vision): cross-attn layer interval; stubbed patch embeds
    cross_attn_every: int = 0
    n_image_tokens: int = 1601
    vision_dim: int = 0           # dim of stubbed vision embeddings (0 = d_model)

    # numerics / training
    dtype: str = "bfloat16"
    remat: bool = True
    loss_chunk: int = 512         # sequence-chunked cross-entropy block
    scan_layers: bool = True
    q_chunk: int = 1024           # blockwise-attention query chunk
    embed_scale: bool = False     # multiply embeddings by sqrt(d) (gemma)
    rwkv_chunk: int = 1           # 1 = exact sequential wkv; >1 = chunked
    kv_cache_dtype: str = "bf16"  # "bf16" | "int8" (per-token-head absmax)

    source: str = ""              # citation for the config

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def is_subquadratic(self) -> bool:
        """True if the arch can serve a 500k-token context without a full
        quadratic-attention KV cache (SSM/hybrid, or SWA-dominant dense)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    @property
    def activation_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        hd = self.resolved_head_dim
        for i in range(self.n_layers):
            total += 2 * d  # norms
            if self._layer_is_ssm(i):
                if self.family == "ssm":  # rwkv6
                    total += rwkv6_block_params(d, self.d_ff)
                else:
                    total += mamba2_block_params(d, self.ssm)
            else:
                total += self._attn_params(d, hd)
                total += self._ffn_params(i, d)
        if self.shared_attn_every:
            # one shared (weight-tied) attention + MLP block
            total += self._attn_params(d, hd) + 3 * d * self.d_ff + 2 * d
        if self.n_enc_layers:
            for _ in range(self.n_enc_layers):
                total += self._attn_params(d, hd) + d * self.d_ff * 3 + 2 * d
            total += self.n_layers * (self._attn_params(d, hd) + d)  # cross-attn
        if self.cross_attn_every:
            n_x = self.n_layers // self.cross_attn_every
            total += n_x * (self._attn_params(d, hd) + 2 * d)
        return total

    def n_active_params(self) -> int:
        """Per-token active parameters (MoE: only top-k + shared experts)."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        m = self.moe
        total = self.n_params()
        # subtract inactive routed experts
        per_expert = 3 * d * m.expert_ff
        n_moe_layers = self.n_layers - m.first_k_dense
        total -= n_moe_layers * (m.n_experts - m.top_k) * per_expert
        return total

    def _attn_params(self, d: int, hd: int) -> int:
        if self.mla is not None:
            m = self.mla
            qd = m.qk_nope_dim + m.qk_rope_dim
            p = d * (m.kv_lora_rank + m.qk_rope_dim)                 # down kv + rope k
            p += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
            if m.q_lora_rank:
                p += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qd
            else:
                p += d * self.n_heads * qd
            p += self.n_heads * m.v_head_dim * d                     # o proj
            return p
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        b = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
        return q + kv + o + b

    def _ffn_params(self, layer: int, d: int) -> int:
        if self.family == "moe" and layer >= self.moe.first_k_dense:
            m = self.moe
            per = 3 * d * m.expert_ff
            return (m.n_experts + m.n_shared_experts) * per + d * m.n_experts
        if self.family == "moe":
            return 3 * d * self.moe.dense_ff
        return 3 * d * self.d_ff

    def _layer_is_ssm(self, i: int) -> bool:
        return self.family in ("ssm", "hybrid")

    # ---- reduced smoke variant ----------------------------------------------
    def reduced(self) -> "ModelConfig":
        """<=2 layers, d_model<=512, <=4 experts — same family/mechanisms."""
        d = min(self.d_model, 256)
        n_heads = max(1, min(self.n_heads, 4)) if self.n_heads else 0
        ratio = max(1, self.n_heads // max(self.n_kv_heads, 1)) if self.n_heads else 1
        n_kv = max(1, n_heads // min(ratio, n_heads)) if n_heads else 0
        hd = 64 if (n_heads and d // n_heads < 32) else (d // n_heads if n_heads else 0)
        kw = dict(
            n_layers=2, d_model=d, n_heads=n_heads, n_kv_heads=n_kv,
            d_ff=min(self.d_ff, 512), vocab=min(self.vocab, 1024),
            head_dim=hd, loss_chunk=64, remat=False, q_chunk=64,
        )
        if self.moe.n_experts:
            kw["moe"] = replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2),
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                expert_ff=128, first_k_dense=min(self.moe.first_k_dense, 1),
                dense_ff=256,
            )
        if self.mla is not None:
            kw["mla"] = replace(self.mla, kv_lora_rank=64, q_lora_rank=0,
                                qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32)
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, d_state=16, d_head=32, chunk=32)
        if self.sliding_window:
            kw["sliding_window"] = 64
            kw["swa_pattern"] = self.swa_pattern
        if self.shared_attn_every:
            kw["shared_attn_every"] = 2
        if self.n_enc_layers:
            kw["n_enc_layers"] = 2
            kw["n_frames"] = 64
        if self.cross_attn_every:
            kw["cross_attn_every"] = 2
            kw["n_image_tokens"] = 16
        return replace(self, **kw)


def mamba2_block_params(d: int, ssm: SSMConfig) -> int:
    d_in = ssm.expand * d
    n_heads = d_in // ssm.d_head
    p = d * (2 * d_in + 2 * ssm.d_state + n_heads)   # in_proj(zx) + B,C proj + dt
    p += ssm.conv_width * (d_in + 2 * ssm.d_state)   # depthwise conv
    p += n_heads * 2                                  # A_log, D
    p += d_in * d                                     # out proj
    return p


def rwkv6_block_params(d: int, d_ff: int) -> int:
    # time-mix: r,k,v,g,o projections + data-dependent decay lora + token-shift mus
    p = 5 * d * d
    p += 2 * (d * 32 + 32 * d)     # decay + bonus low-rank adapters
    p += 6 * d                      # token-shift interpolation params
    p += d * d_ff + d_ff * d + d   # channel-mix (r gate shares d*d above approx)
    return p


__all__ = [
    "InputShape", "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K", "INPUT_SHAPES",
]
