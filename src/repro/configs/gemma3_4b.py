"""gemma3-4b — dense, GQA kv=4, 5:1 local:global sliding-window, 128k ctx.
[hf:google/gemma-3-1b-pt family card, 4B variant]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_ff=10_240,
    vocab=262_144,
    head_dim=256,
    rope_theta=1_000_000.0,
    sliding_window=1024,
    swa_pattern=(5, 1),          # 5 local layers : 1 global layer
    tie_embeddings=True,
    embed_scale=True,
    source="hf:google/gemma-3-1b-pt (family model card, 4B variant)",
)
