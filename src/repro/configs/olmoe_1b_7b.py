"""olmoe-1b-7b — MoE, 64 experts top-8, GQA kv=16. [arXiv:2409.02060]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,                    # per-expert hidden (mirrors expert_ff)
    vocab=50_304,
    head_dim=128,
    rope_theta=10_000.0,
    moe=MoEConfig(
        n_experts=64,
        top_k=8,
        n_shared_experts=0,
        expert_ff=1024,
        first_k_dense=0,
        capacity_factor=1.25,
    ),
    source="arXiv:2409.02060 (OLMoE)",
)
