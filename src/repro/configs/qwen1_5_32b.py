"""qwen1.5-32b — dense, GQA kv=40 (MHA-like), QKV bias. [hf:Qwen/Qwen1.5-0.5B family card]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27_392,
    vocab=152_064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-0.5B (family model card, 32B variant)",
)
