"""llama-3.2-vision-11b — VLM: language decoder with cross-attn image layers.
[hf:meta-llama/Llama-3.2-11B-Vision]

The ViT vision encoder + projector are stubbed: ``input_specs()`` supplies
projected patch embeddings (batch, n_image_tokens, d_model). The language
stack is 40 layers with a gated cross-attention layer every 5 layers
(8 cross-attn layers total), GQA kv=8.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab=128_256,
    head_dim=128,
    rope_theta=500_000.0,
    cross_attn_every=5,
    n_image_tokens=1601,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
