"""rwkv6-3b (Finch) — attention-free, data-dependent decay. [arXiv:2404.05892]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=0,                   # attention-free
    n_kv_heads=0,
    d_ff=8960,
    vocab=65_536,
    ssm=SSMConfig(
        d_state=64,              # per-head wkv state is d_head x d_head
        d_head=64,               # 2560/64 = 40 wkv heads
        chunk=256,
    ),
    source="arXiv:2404.05892 (RWKV-6 Finch)",
)
