"""Architecture registry: 10 assigned architectures + reduced smoke variants."""
from __future__ import annotations

from repro.configs.base import (
    DECODE_32K, INPUT_SHAPES, LONG_500K, PREFILL_32K, TRAIN_4K,
    InputShape, MLAConfig, ModelConfig, MoEConfig, SSMConfig,
)

from repro.configs.llama3_2_vision_11b import CONFIG as LLAMA32_VISION_11B
from repro.configs.deepseek_v2_lite_16b import CONFIG as DEEPSEEK_V2_LITE_16B
from repro.configs.whisper_base import CONFIG as WHISPER_BASE
from repro.configs.qwen1_5_32b import CONFIG as QWEN1_5_32B
from repro.configs.qwen2_0_5b import CONFIG as QWEN2_0_5B
from repro.configs.zamba2_2_7b import CONFIG as ZAMBA2_2_7B
from repro.configs.rwkv6_3b import CONFIG as RWKV6_3B
from repro.configs.gemma3_4b import CONFIG as GEMMA3_4B
from repro.configs.olmoe_1b_7b import CONFIG as OLMOE_1B_7B
from repro.configs.qwen2_72b import CONFIG as QWEN2_72B

ARCHS: dict[str, ModelConfig] = {
    c.arch_id: c
    for c in (
        LLAMA32_VISION_11B, DEEPSEEK_V2_LITE_16B, WHISPER_BASE, QWEN1_5_32B,
        QWEN2_0_5B, ZAMBA2_2_7B, RWKV6_3B, GEMMA3_4B, OLMOE_1B_7B, QWEN2_72B,
    )
}


def get_config(arch_id: str, reduced: bool = False) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    cfg = ARCHS[arch_id]
    return cfg.reduced() if reduced else cfg


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Whether (arch, shape) is runnable; reason when skipped (DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "full-attention arch: 500k decode requires sub-quadratic variant"
    return True, ""


__all__ = [
    "ARCHS", "get_config", "shape_applicable",
    "InputShape", "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig",
    "INPUT_SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
]
