"""qwen2-72b — dense, GQA kv=8, QKV bias. [arXiv:2407.10671]

Also plays the *cloud LLM* role in EACO-RAG examples (the paper's 72B tier).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29_568,
    vocab=152_064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="arXiv:2407.10671 (Qwen2 technical report)",
)
