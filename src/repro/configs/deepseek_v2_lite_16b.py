"""deepseek-v2-lite-16b — MoE + MLA. [arXiv:2405.04434]

MLA: kv_lora_rank=512, decoupled rope head 64, nope 128, v_head 128.
MoE: 64 routed experts top-6 + 2 shared, expert_ff=1408, first layer dense
(d_ff=10944 per the V2-Lite card). The assignment line mentions "160 routed"
(full V2); we follow the Lite spec cited: 64 routed, top-6.
"""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,               # MLA: kv heads == heads after up-projection
    d_ff=1408,
    vocab=102_400,
    head_dim=128,
    rope_theta=10_000.0,
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        n_shared_experts=2,
        expert_ff=1408,
        first_k_dense=1,
        dense_ff=10_944,
        capacity_factor=1.25,
    ),
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=0,           # V2-Lite projects q directly
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
    ),
    source="arXiv:2405.04434 (DeepSeek-V2; Lite variant)",
)
