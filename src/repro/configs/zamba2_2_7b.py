"""zamba2-2.7b — hybrid Mamba2 backbone + shared attention block. [arXiv:2411.15242]

54 Mamba2 blocks; one *shared* (weight-tied) attention+MLP block is applied
every 6 Mamba2 blocks (9 applications).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10_240,
    vocab=32_000,
    head_dim=80,
    ssm=SSMConfig(
        d_state=64,
        d_head=64,
        expand=2,
        conv_width=4,
        chunk=256,
    ),
    shared_attn_every=6,
    source="arXiv:2411.15242 (Zamba2)",
)
