"""whisper-base — encoder-decoder, conv/mel frontend stubbed. [arXiv:2212.04356]

n_layers is the decoder depth; the encoder has n_enc_layers. The audio
frontend (mel spectrogram + 2x conv) is a stub: ``input_specs()`` supplies
precomputed frame embeddings of shape (batch, n_frames, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-base",
    family="encdec",
    n_layers=6,                  # decoder layers
    n_enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51_865,
    head_dim=64,
    rope_theta=0.0,              # whisper uses learned/sinusoidal positions
    n_frames=1500,
    tie_embeddings=True,
    source="arXiv:2212.04356 (Whisper)",
)
