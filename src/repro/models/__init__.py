from repro.models.api import Model, build_model, chunked_cross_entropy
from repro.models.pdefs import (
    ParamDef, abstract_from_defs, count_params, init_from_defs,
    pspecs_from_defs, shardings_from_defs,
)
from repro.models.shardctx import activation_sharding, constrain

__all__ = [
    "Model", "build_model", "chunked_cross_entropy", "ParamDef",
    "abstract_from_defs", "count_params", "init_from_defs",
    "pspecs_from_defs", "shardings_from_defs", "activation_sharding",
    "constrain",
]
