"""Unified model API.

``build_model(cfg, max_seq)`` returns a :class:`Model` exposing:
  param_defs / cache_defs / extra_input_defs   (declarative; dry-run friendly)
  init(key) -> params
  train_loss(params, batch) -> (loss, metrics)
  prefill(params, tokens, extras) -> (last_logits, cache)
  decode_step(params, cache, tokens1, positions) -> (logits, cache)

max_seq parameterizes cache sizes and long-context adaptations (e.g. the
zamba2 shared-attention block switches to a sliding window beyond 64k; see
DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import families as F
from repro.models.pdefs import (
    ParamDef, abstract_from_defs, count_params, init_from_defs, stack,
)
from repro.models.shardctx import constrain
from repro.models.stacks import (
    Segment, run_segments_append, run_segments_decode, run_segments_full,
    run_segments_fused, segments_cache_defs, segments_paged_cache_defs,
    segments_param_defs,
)


# ---------------------------------------------------------------------------
# Family -> segments
# ---------------------------------------------------------------------------

def _segments(cfg: ModelConfig, max_seq: int):
    """Returns (encoder_segments, decoder_segments, extra_top_defs)."""
    fam = cfg.family
    extra: Dict[str, Any] = {}

    if fam == "dense" and not cfg.sliding_window:
        mk = F.make_attn_layer(cfg)
        return [], [Segment("blocks", cfg.n_layers, *mk)], extra

    if fam == "dense" and cfg.sliding_window:
        # gemma3: repeat (n_local local + n_global global), remainder local
        n_local, n_global = cfg.swa_pattern
        unit_len = n_local + n_global
        n_units = cfg.n_layers // unit_len
        rem = cfg.n_layers - n_units * unit_len
        local = F.make_attn_layer(cfg, window=cfg.sliding_window)
        glob = F.make_attn_layer(cfg)
        unit = F.make_unit([
            ("local", F.make_stacked_sublayer(local, n_local)),
            ("global", glob),
        ])
        segs = [Segment("units", n_units, *unit)]
        if rem:
            segs.append(Segment("tail", rem, *local))
        return [], segs, extra

    if fam == "moe":
        segs = []
        m = cfg.moe
        attn_mk = (lambda **kw: F.make_mla_layer(cfg, **kw)) if cfg.mla else \
                  (lambda **kw: F.make_attn_layer(cfg, **kw))
        if m.first_k_dense:
            segs.append(Segment("dense0", m.first_k_dense,
                                *attn_mk(ffn="dense", dense_ff=m.dense_ff)))
        segs.append(Segment("blocks", cfg.n_layers - m.first_k_dense,
                            *attn_mk(ffn="moe")))
        return [], segs, extra

    if fam == "ssm":
        mk = F.make_rwkv_layer(cfg)
        return [], [Segment("blocks", cfg.n_layers, *mk)], extra

    if fam == "hybrid":
        k = cfg.shared_attn_every
        n_units = cfg.n_layers // k
        rem = cfg.n_layers - n_units * k
        mamba = F.make_mamba_layer(cfg)
        shared_window = 4096 if max_seq > 65536 else 0
        shared_base = F.make_attn_layer(cfg, ffn="dense",
                                        window=shared_window)
        extra["shared_attn"] = shared_base[0]()       # weight-tied block
        shared = _make_shared_from(shared_base)
        unit = F.make_unit([
            ("mamba", F.make_stacked_sublayer(mamba, k)),
            ("shared", shared),
        ])
        segs = [Segment("units", n_units, *unit)]
        if rem:
            segs.append(Segment("tail", rem, *mamba))
        return [], segs, extra

    if fam == "encdec":
        enc = F.make_bidir_layer(cfg)
        enc_segs = [Segment("enc", cfg.n_enc_layers, *enc)]
        self_l = F.make_attn_layer(cfg, rope=False)
        cross_l = F.make_cross_layer(cfg, gated=False, n_mem=cfg.n_frames,
                                     with_ffn=False)
        unit = F.make_unit([("self", self_l), ("cross", cross_l)])
        dec_segs = [Segment("blocks", cfg.n_layers, *unit)]
        extra["enc_pos"] = ParamDef((cfg.n_frames, cfg.d_model),
                                    ("frames", "embed"), cfg.activation_dtype)
        extra["dec_pos"] = ParamDef((max(max_seq, 1), cfg.d_model),
                                    (None, "embed"), cfg.activation_dtype)
        return enc_segs, dec_segs, extra

    if fam == "vlm":
        k = cfg.cross_attn_every
        n_units = cfg.n_layers // k
        self_l = F.make_attn_layer(cfg)
        cross_l = F.make_cross_layer(cfg, gated=True, n_mem=cfg.n_image_tokens)
        unit = F.make_unit([
            ("self", F.make_stacked_sublayer(self_l, k - 1)),
            ("cross", cross_l),
        ])
        return [], [Segment("units", n_units, *unit)], extra

    raise ValueError(f"unknown family {fam}")


# shared-attn wrapper bound to an existing base (weights in ctx["shared"])
def _make_shared_from(base):
    def defs():
        return {}

    def fwd_full(p, x, ctx):
        return base[1](ctx["shared"], x, ctx)

    def fwd_decode(p, x1, ctx, ce):
        return base[2](ctx["shared"], x1, ctx, ce)

    def cache_defs(B, S):
        return base[3](B, S)

    return defs, fwd_full, fwd_decode, cache_defs


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclass
class Model:
    cfg: ModelConfig
    max_seq: int
    enc_segments: List[Segment]
    dec_segments: List[Segment]
    _defs: Dict[str, Any]

    # ---- declarative -------------------------------------------------------
    def param_defs(self):
        return self._defs

    def cache_defs(self, batch: int):
        cd = segments_cache_defs(self.dec_segments, batch, self.max_seq)
        return cd

    def paged_cache_defs(self, num_pages: int, page_size: int):
        """Page-arena defs ([num_pages, page_size, ...] per layer, no batch
        axis) for block-granular KV paging; None when any decoder segment
        only supports contiguous lanes (windows, quantized caches, SSM/RWKV
        state, cross-attention memories)."""
        return segments_paged_cache_defs(self.dec_segments, num_pages,
                                         page_size)

    @property
    def supports_paged_cache(self) -> bool:
        return self.paged_cache_defs(1, 8) is not None

    def extra_input_defs(self, batch: int):
        """Stubbed modality inputs (DESIGN.md: the one allowed stub)."""
        cfg = self.cfg
        dt = cfg.activation_dtype
        if cfg.family == "vlm":
            return {"memory": ParamDef((batch, cfg.n_image_tokens, cfg.d_model),
                                       ("batch", "frames", "embed"), dt)}
        if cfg.family == "encdec":
            return {"memory": ParamDef((batch, cfg.n_frames, cfg.d_model),
                                       ("batch", "frames", "embed"), dt)}
        return {}

    def init(self, key):
        return init_from_defs(self._defs, key)

    def abstract_params(self):
        return abstract_from_defs(self._defs)

    def n_params(self) -> int:
        return count_params(self._defs)

    # ---- embedding / head --------------------------------------------------
    def _embed(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0)
        if self.cfg.embed_scale:
            x = x * np.sqrt(self.cfg.d_model).astype(np.float32)
        return x.astype(self.cfg.activation_dtype)

    def _head_weight(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    def _logits(self, params, x):
        w = self._head_weight(params)
        return jnp.einsum("...d,dv->...v", x, w).astype(jnp.float32)

    # ---- context -----------------------------------------------------------
    def _ctx(self, mode, positions, lengths=None, memory=None, params=None,
             cache_len=None):
        ctx = {
            "mode": mode,
            "positions": positions,
            "lengths": lengths,
            "memory": memory,
            "cfg": self.cfg,
            "cache_len": cache_len if cache_len is not None else self.max_seq,
        }
        if params is not None and "shared_attn" in params:
            ctx["shared"] = params["shared_attn"]
        return ctx

    def _run_encoder(self, params, memory):
        """encdec: run encoder over stubbed frame embeddings -> enc memory."""
        cfg = self.cfg
        x = memory + params["enc_pos"][None, : memory.shape[1]]
        ctx = self._ctx("train", jnp.arange(memory.shape[1]), params=params)
        x, _, _ = run_segments_full(params, x, self.enc_segments, ctx,
                                    want_cache=False, remat=cfg.remat)
        return x

    def _body_full(self, params, tokens, mode, memory):
        cfg = self.cfg
        S = tokens.shape[1]
        x = self._embed(params, tokens)
        x = constrain(x, ("batch", None, "embed"))
        if cfg.family == "encdec":
            memory = self._run_encoder(params, memory)
            x = x + params["dec_pos"][None, :S]
        positions = jnp.arange(S)
        ctx = self._ctx(mode, positions, memory=memory, params=params)
        x, cache, aux = run_segments_full(
            params, x, self.dec_segments, ctx,
            want_cache=(mode == "prefill"), remat=cfg.remat)
        x = F.rms_norm(x, params["final_norm"], cfg.rms_eps)
        return x, cache, aux

    # ---- public entry points -----------------------------------------------
    def train_loss(self, params, batch):
        """batch: {tokens [B,S], targets [B,S], (memory)} -> (loss, metrics)."""
        cfg = self.cfg
        x, _, aux = self._body_full(params, batch["tokens"], "train",
                                    batch.get("memory"))
        loss, acc = chunked_cross_entropy(
            x, self._head_weight(params), batch["targets"], cfg.loss_chunk)
        return loss + aux, {"ce": loss, "aux": aux, "acc": acc}

    def prefill(self, params, tokens, memory=None, lengths=None):
        """lengths [B]: per-row prompt lengths (right-padded batches) — the
        returned logits are taken at each row's last real token."""
        x, cache, _ = self._body_full(params, tokens, "prefill", memory)
        if lengths is None:
            last = x[:, -1]
        else:
            last = x[jnp.arange(x.shape[0]), lengths - 1]
        logits = self._logits(params, last)
        return logits, cache

    def forward_logits(self, params, tokens, memory=None):
        x, _, _ = self._body_full(params, tokens, "train", memory)
        return self._logits(params, x)

    def decode_step(self, params, cache, tokens1, positions):
        """tokens1 [B,1]; positions [B] (position of this token)."""
        return self._decode_step(params, cache, tokens1, positions, None, 0)

    def prefill_paged(self, params, cache, tokens, suffix_len, prefix_len,
                      page_table, *, page_size: int):
        """Suffix prefill straight into the page arena (``decode_step_paged``'s
        multi-token sibling, used by the prefix-cached admission path).

        ``cache`` leaves are page arenas; ``tokens [1, S]`` is the (padded)
        unique suffix of one request whose first ``prefix_len`` positions are
        already resident in the pages of ``page_table [n_pages]``;
        ``suffix_len`` is the number of valid suffix tokens. Each layer
        scatters the suffix KV at its (physical page, offset) and attends
        over prefix + suffix, so no intermediate contiguous lane is ever
        materialized. Returns (last-valid-token logits [1, V], new cache).
        """
        assert self.supports_paged_cache, \
            f"{self.cfg.arch_id}: decoder has non-pageable cache segments"
        cfg = self.cfg
        S = tokens.shape[1]
        x = self._embed(params, tokens)
        positions = jnp.asarray(prefix_len, jnp.int32) + jnp.arange(S)
        ctx = self._ctx("append", positions, params=params)
        ctx["page_table"] = page_table
        ctx["page_size"] = page_size
        ctx["prefix_len"] = jnp.asarray(prefix_len, jnp.int32)
        ctx["suffix_len"] = jnp.asarray(suffix_len, jnp.int32)
        x, new_cache, _ = run_segments_append(params, x, self.dec_segments,
                                              ctx, cache)
        x = F.rms_norm(x, params["final_norm"], cfg.rms_eps)
        last = jax.lax.dynamic_index_in_dim(
            x[0], jnp.asarray(suffix_len, jnp.int32) - 1, 0, keepdims=False)
        logits = self._logits(params, last[None])
        return logits, new_cache

    def fused_step(self, params, cache, tokens1, positions, page_tables,
                   chunk_tokens, chunk_suffix_len, chunk_prefix_len,
                   chunk_page_row, *, page_size: int):
        """One fused chunked-prefill + decode step against the page arena
        (Sarathi-style). Runs the ``[B, 1]`` decode for every resident row
        AND one request's bounded prefill chunk ``chunk_tokens [1, C]``
        (valid length ``chunk_suffix_len``, appended after
        ``chunk_prefix_len`` already-resident positions of
        ``chunk_page_row [n_pages]``) in a single call, sharing one layer
        scan — see :func:`~repro.models.stacks.run_segments_fused` for the
        page-disjointness argument that makes the fusion order-invariant.

        Rows of ``page_tables [B, n_pages]`` belonging to mid-prefill or
        empty slots must be all-trash (their scatters land in page 0, never
        read); the chunk's own decode row is one of those. Returns
        ``(decode_logits [B, V], chunk_logits [1, V], new_cache)`` where
        the chunk logits are taken at the chunk's last valid token — only
        the FINAL chunk's logits are first-token logits; earlier chunks'
        are computed and discarded (fixed shape beats a second trace)."""
        assert self.supports_paged_cache, \
            f"{self.cfg.arch_id}: decoder has non-pageable cache segments"
        cfg = self.cfg
        # decode side (identical to _decode_step's setup)
        x1 = self._embed(params, tokens1)
        lengths = positions + 1
        ctx_d = self._ctx("decode", positions, lengths=lengths, params=params)
        ctx_d["page_table"] = page_tables
        ctx_d["page_size"] = page_size
        # append side (identical to prefill_paged's setup)
        C = chunk_tokens.shape[1]
        xc = self._embed(params, chunk_tokens)
        cpos = jnp.asarray(chunk_prefix_len, jnp.int32) + jnp.arange(C)
        ctx_a = self._ctx("append", cpos, params=params)
        ctx_a["page_table"] = chunk_page_row
        ctx_a["page_size"] = page_size
        ctx_a["prefix_len"] = jnp.asarray(chunk_prefix_len, jnp.int32)
        ctx_a["suffix_len"] = jnp.asarray(chunk_suffix_len, jnp.int32)
        x1, xc, new_cache, _ = run_segments_fused(
            params, x1, xc, self.dec_segments, ctx_d, ctx_a, cache)
        x1 = F.rms_norm(x1, params["final_norm"], cfg.rms_eps)
        dec_logits = self._logits(params, x1[:, 0])
        xc = F.rms_norm(xc, params["final_norm"], cfg.rms_eps)
        last = jax.lax.dynamic_index_in_dim(
            xc[0], jnp.asarray(chunk_suffix_len, jnp.int32) - 1, 0,
            keepdims=False)
        chunk_logits = self._logits(params, last[None])
        return dec_logits, chunk_logits, new_cache

    def decode_step_paged(self, params, cache, tokens1, positions,
                          page_table, *, page_size: int):
        """Paged-cache decode step: ``cache`` leaves are page arenas and
        ``page_table [B, n_pages]`` maps each row's logical pages to physical
        page ids (trash-page 0 past its allocation)."""
        assert self.supports_paged_cache, \
            f"{self.cfg.arch_id}: decoder has non-pageable cache segments"
        return self._decode_step(params, cache, tokens1, positions,
                                 page_table, page_size)

    def _decode_step(self, params, cache, tokens1, positions, page_table,
                     page_size):
        cfg = self.cfg
        x1 = self._embed(params, tokens1)
        if cfg.family == "encdec":
            x1 = x1 + jnp.take(params["dec_pos"], positions, axis=0)[:, None]
        lengths = positions + 1
        ctx = self._ctx("decode", positions, lengths=lengths, params=params)
        if page_table is not None:
            ctx["page_table"] = page_table
            ctx["page_size"] = page_size
        x1, new_cache, _ = run_segments_decode(params, x1, self.dec_segments,
                                               ctx, cache)
        x1 = F.rms_norm(x1, params["final_norm"], cfg.rms_eps)
        logits = self._logits(params, x1[:, 0])
        return logits, new_cache


def chunked_cross_entropy(x, head_w, targets, chunk: int):
    """CE over [B,S,D] hidden states without materializing [B,S,V] logits.

    Scans over sequence chunks with remat — with qwen2-72b at 1M tokens the
    full logit tensor would be ~300 TB; chunked, the live slice is
    B x chunk x V (sharded over vocab/model).
    """
    B, S, D = x.shape
    c = min(chunk, S)
    while S % c:
        c -= 1
    n = S // c
    xc = x.reshape(B, n, c, D).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, n, c).transpose(1, 0, 2)

    def body(carry, args):
        xi, ti = args
        logits = jnp.einsum("bsd,dv->bsv", xi, head_w).astype(jnp.float32)
        logits = constrain(logits, ("batch", None, "vocab"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, ti[..., None], axis=-1)[..., 0]
        nll = (lse - tgt).sum()
        correct = (jnp.argmax(logits, -1) == ti).sum()
        return (carry[0] + nll, carry[1] + correct), None

    body = jax.checkpoint(body, prevent_cse=False)
    (nll, correct), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), (xc, tc))
    n_tok = B * S
    return nll / n_tok, correct.astype(jnp.float32) / n_tok


def build_model(cfg: ModelConfig, max_seq: int = 4096) -> Model:
    enc_segs, dec_segs, extra = _segments(cfg, max_seq)
    defs: Dict[str, Any] = {}
    defs["embed"] = ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                             cfg.activation_dtype, init="embed")
    defs.update(segments_param_defs(enc_segs))
    defs.update(segments_param_defs(dec_segs))
    defs["final_norm"] = F.rms_norm_def(cfg.d_model)
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((cfg.d_model, cfg.vocab),
                                   ("embed", "vocab"), cfg.activation_dtype)
    defs.update(extra)
    return Model(cfg, max_seq, enc_segs, dec_segs, defs)


__all__ = ["Model", "build_model", "chunked_cross_entropy"]
