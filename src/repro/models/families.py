"""Per-family segment constructors for all 10 assigned architectures.

Each family builds a list of :class:`Segment` (plus optional encoder
segments and extra top-level params). See stacks.py for the contract.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.decode_attention.ops import (
    paged_append_attention, paged_decode_attention,
)
from repro.models import mla as mla_mod
from repro.models import mamba2 as m2
from repro.models import rwkv6 as rw
from repro.models.layers import (
    apply_rope, causal_attention, cross_attention, decode_attention,
    gqa_proj_defs, out_proj, qkv, rms_norm, rms_norm_def, swiglu, swiglu_defs,
)
from repro.models.moe import moe_defs, moe_ffn
from repro.models.pdefs import ParamDef, stack
from repro.models.shardctx import constrain
from repro.models.stacks import Segment

ZERO = lambda: jnp.zeros((), jnp.float32)


def _kv_cache_defs(B: int, S: int, n_kv: int, hd: int, dtype=jnp.bfloat16,
                   quant: bool = False):
    ax = ("batch", "cache_seq", "kv_heads", None)
    if quant:
        # int8 per-(token, head) absmax quantization: ~2x cache memory +
        # HBM-read reduction (the decode read is the serving bottleneck)
        sax = ("batch", "cache_seq", "kv_heads")
        return {
            "k": ParamDef((B, S, n_kv, hd), ax, jnp.int8, init="zeros"),
            "ks": ParamDef((B, S, n_kv), sax, jnp.float32, init="zeros"),
            "v": ParamDef((B, S, n_kv, hd), ax, jnp.int8, init="zeros"),
            "vs": ParamDef((B, S, n_kv), sax, jnp.float32, init="zeros"),
        }
    return {
        "k": ParamDef((B, S, n_kv, hd), ax, dtype, init="zeros"),
        "v": ParamDef((B, S, n_kv, hd), ax, dtype, init="zeros"),
    }


def _kv_arena_defs(P: int, ps: int, n_kv: int, hd: int, dtype=jnp.bfloat16):
    """Paged layout: one global page arena per layer instead of per-slot
    lanes. Logical position t of a request lives at
    ``arena[page_table[slot, t // ps], t % ps]``."""
    ax = ("pages", "page_seq", "kv_heads", None)
    return {
        "k": ParamDef((P, ps, n_kv, hd), ax, dtype, init="zeros"),
        "v": ParamDef((P, ps, n_kv, hd), ax, dtype, init="zeros"),
    }


def _quantize_kv(kv):
    """[..., hd] -> (int8 [..., hd], scale [...])."""
    amax = jnp.max(jnp.abs(kv.astype(jnp.float32)), axis=-1)
    scale = amax / 127.0 + 1e-8
    q = jnp.clip(jnp.round(kv.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def _write_ring(cache, kv_new, S: int, W: int):
    """Write the last min(S, W) tokens of kv_new [B,S,...] into ring slots."""
    take = min(S, W)
    idx = (jnp.arange(S - take, S) % W)
    return cache.at[:, idx].set(kv_new[:, -take:].astype(cache.dtype))


def _write_decode(cache, kv1, pos, ring_w: int = 0):
    """Write one token kv1 [B,1,...] at per-row position pos [B]."""
    slot = pos % ring_w if ring_w else pos
    return cache.at[jnp.arange(kv1.shape[0]), slot].set(
        kv1[:, 0].astype(cache.dtype))


# ---------------------------------------------------------------------------
# GQA attention layer (dense / moe / local-global window / qkv-bias)
# ---------------------------------------------------------------------------

def make_attn_layer(cfg: ModelConfig, *, window: int = 0, ffn: str = "dense",
                    dense_ff: int = 0, causal: bool = True, rope: bool = True):
    d, H, KV = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    dt = cfg.activation_dtype
    theta = cfg.rope_theta if rope else 0.0
    quant = cfg.kv_cache_dtype == "int8"

    def _pack(k, v):
        """kv [B,S,KV,hd] -> cache entry dict (quantized or plain)."""
        if quant:
            qk, sk = _quantize_kv(k)
            qv, sv = _quantize_kv(v)
            return {"k": qk, "ks": sk, "v": qv, "vs": sv}
        return {"k": k.astype(dt), "v": v.astype(dt)}

    def _unpack(ce):
        if quant:
            return (_dequantize_kv(ce["k"], ce["ks"], dt),
                    _dequantize_kv(ce["v"], ce["vs"], dt))
        return ce["k"], ce["v"]

    def defs():
        dd = {
            "ln1": rms_norm_def(d),
            "attn": gqa_proj_defs(d, H, KV, hd, cfg.qkv_bias, dt),
            "ln2": rms_norm_def(d),
        }
        if ffn == "moe":
            dd["ffn"] = moe_defs(d, cfg.moe, dt)
        else:
            dd["ffn"] = swiglu_defs(d, dense_ff or cfg.d_ff, dt)
        return dd

    def _ffn_apply(p, x):
        h = rms_norm(x, p["ln2"], cfg.rms_eps)
        if ffn == "moe":
            y, aux = moe_ffn(p["ffn"], h, cfg.moe, dtype=dt)
            return x + y, aux
        return x + swiglu(p["ffn"], h), ZERO()

    def fwd_full(p, x, ctx):
        pos = ctx["positions"]
        h = rms_norm(x, p["ln1"], cfg.rms_eps)
        q, k, v = qkv(p["attn"], h)
        q = apply_rope(q, pos, theta)
        k = apply_rope(k, pos, theta)
        a = causal_attention(q, k, v, n_kv=KV, window=window,
                             q_chunk=cfg.q_chunk)
        x = x + out_proj(p["attn"], a)
        x, aux = _ffn_apply(p, x)
        ce = {}
        if ctx["mode"] == "prefill":
            S_cache = ctx["cache_len"]
            B, S = k.shape[0], k.shape[1]
            packed = _pack(k, v)
            cd = _kv_cache_defs(B, min(window, S_cache) if window else S_cache,
                                KV, hd, dt, quant)
            if window and window < S_cache:
                ce = {name: _write_ring(jnp.zeros(cd[name].shape,
                                                  cd[name].dtype),
                                        packed[name], S, window)
                      for name in packed}
            else:
                ce = {name: jnp.zeros(cd[name].shape, cd[name].dtype)
                      .at[:, :S].set(packed[name]) for name in packed}
        return x, ce, aux

    def fwd_decode(p, x1, ctx, ce):
        pos = ctx["positions"]                       # [B]
        h = rms_norm(x1, p["ln1"], cfg.rms_eps)
        q, k, v = qkv(p["attn"], h)                  # [B,1,H,hd]
        q = apply_rope(q, pos[:, None], theta)
        k = apply_rope(k, pos[:, None], theta)
        packed = _pack(k, v)
        if ctx.get("page_table") is not None:
            # paged layout: ce leaves are [P, page_size, KV, hd] arenas;
            # scatter this token at its slot's physical (page, offset) and
            # attend through the page table. The allocator guarantees every
            # active slot owns distinct pages, so the scatter never races;
            # inactive slots park on the trash page (id 0, never read).
            ps_sz = ctx["page_size"]
            pt = ctx["page_table"]                   # [B, n_pages] int32
            phys = jnp.take_along_axis(
                pt, (pos // ps_sz)[:, None], axis=1)[:, 0]
            new_ce = {name: ce[name].at[phys, pos % ps_sz].set(
                          packed[name][:, 0].astype(ce[name].dtype))
                      for name in packed}
            a = paged_decode_attention(q[:, 0], new_ce["k"], new_ce["v"],
                                       pt, ctx["lengths"])
        else:
            ring_w = window if (window and ce["k"].shape[1] == window) else 0
            new_ce = {name: _write_decode(ce[name], packed[name], pos, ring_w)
                      for name in packed}
            kc, vc = _unpack(new_ce)
            a = decode_attention(q[:, 0], kc, vc, ctx["lengths"],
                                 n_kv=KV, window=window, ring=bool(ring_w))
        x1 = x1 + out_proj(p["attn"], a[:, None])
        x1, aux = _ffn_apply(p, x1)
        return x1, new_ce, aux

    def cache_defs(B, S):
        S_eff = min(window, S) if window else S
        return _kv_cache_defs(B, S_eff, KV, hd, dt, quant)

    # block-granular paged cache: full-context bf16 GQA only — a ring-buffer
    # window already bounds memory, and int8 paging would need scale arenas
    paged_cache_defs = None
    fwd_append = None
    if not window and not quant:
        def paged_cache_defs(num_pages, page_size):
            return _kv_arena_defs(num_pages, page_size, KV, hd, dt)

        def fwd_append(p, x, ctx, ce):
            """Batch-1 suffix prefill against the page arena: token i of x
            sits at absolute position ``prefix_len + i``. The suffix KV is
            scattered token-granularly at its (physical page, offset) —
            pages the slot owns privately, so writes never race a shared
            prefix page — and attention runs over prefix + suffix through
            the page table. Rows past ``suffix_len`` scatter to the trash
            page and mask out of the attention."""
            ps_sz = ctx["page_size"]
            pt = ctx["page_table"]                   # [n_pages] (one slot)
            prefix_len = ctx["prefix_len"]
            suffix_len = ctx["suffix_len"]
            pos = ctx["positions"]                   # [S] = prefix + arange
            S = x.shape[1]
            h = rms_norm(x, p["ln1"], cfg.rms_eps)
            q, k, v = qkv(p["attn"], h)              # [1,S,H,hd]
            q = apply_rope(q, pos[None], theta)
            k = apply_rope(k, pos[None], theta)
            packed = _pack(k, v)
            phys = jnp.where(jnp.arange(S) < suffix_len,
                             pt[pos // ps_sz], 0)    # padding -> trash page
            off = pos % ps_sz
            new_ce = {name: ce[name].at[phys, off].set(
                          packed[name][0].astype(ce[name].dtype))
                      for name in packed}
            a = paged_append_attention(q[0], new_ce["k"], new_ce["v"], pt,
                                       prefix_len, prefix_len + suffix_len)
            x = x + out_proj(p["attn"], a[None])
            x, aux = _ffn_apply(p, x)
            return x, new_ce, aux

    return defs, fwd_full, fwd_decode, cache_defs, paged_cache_defs, \
        fwd_append


# ---------------------------------------------------------------------------
# MLA attention layer (deepseek) — compressed-latent cache
# ---------------------------------------------------------------------------

def make_mla_layer(cfg: ModelConfig, *, ffn: str = "moe", dense_ff: int = 0):
    d, H = cfg.d_model, cfg.n_heads
    m = cfg.mla
    dt = cfg.activation_dtype

    def defs():
        dd = {
            "ln1": rms_norm_def(d),
            "attn": mla_mod.mla_defs(d, H, m, dt),
            "ln2": rms_norm_def(d),
        }
        if ffn == "moe":
            dd["ffn"] = moe_defs(d, cfg.moe, dt)
        else:
            dd["ffn"] = swiglu_defs(d, dense_ff or cfg.d_ff, dt)
        return dd

    def _ffn_apply(p, x):
        h = rms_norm(x, p["ln2"], cfg.rms_eps)
        if ffn == "moe":
            y, aux = moe_ffn(p["ffn"], h, cfg.moe, dtype=dt)
            return x + y, aux
        return x + swiglu(p["ffn"], h), ZERO()

    def fwd_full(p, x, ctx):
        pos = ctx["positions"]
        h = rms_norm(x, p["ln1"], cfg.rms_eps)
        out, (c_kv, k_rope) = mla_mod.mla_attention_prefill(
            p["attn"], h, m, positions=pos, theta=cfg.rope_theta,
            eps=cfg.rms_eps, q_chunk=cfg.q_chunk)
        x = x + out
        x, aux = _ffn_apply(p, x)
        ce = {}
        if ctx["mode"] == "prefill":
            B, S = c_kv.shape[0], c_kv.shape[1]
            Sc = ctx["cache_len"]
            ck = jnp.zeros((B, Sc, m.kv_lora_rank), dt)
            kr = jnp.zeros((B, Sc, m.qk_rope_dim), dt)
            ce = {"ckv": ck.at[:, :S].set(c_kv.astype(dt)),
                  "kr": kr.at[:, :S].set(k_rope.astype(dt))}
        return x, ce, aux

    def fwd_decode(p, x1, ctx, ce):
        pos = ctx["positions"]
        h = rms_norm(x1, p["ln1"], cfg.rms_eps)
        c_kv, k_rope = mla_mod.mla_latents(p["attn"], h, m, pos[:, None],
                                           cfg.rope_theta, cfg.rms_eps)
        new_ckv = _write_decode(ce["ckv"], c_kv, pos)
        new_kr = _write_decode(ce["kr"], k_rope, pos)
        out = mla_mod.mla_attention_decode(
            p["attn"], h, m, new_ckv, new_kr, ctx["lengths"],
            positions=pos, theta=cfg.rope_theta, eps=cfg.rms_eps)
        x1 = x1 + out
        x1, aux = _ffn_apply(p, x1)
        return x1, {"ckv": new_ckv, "kr": new_kr}, aux

    def cache_defs(B, S):
        ax = ("batch", "cache_seq", None)
        return {"ckv": ParamDef((B, S, m.kv_lora_rank), ax, dt, init="zeros"),
                "kr": ParamDef((B, S, m.qk_rope_dim), ax, dt, init="zeros")}

    return defs, fwd_full, fwd_decode, cache_defs


# ---------------------------------------------------------------------------
# Cross-attention layer (VLM: gated; whisper decoder: ungated)
# ---------------------------------------------------------------------------

def make_cross_layer(cfg: ModelConfig, *, gated: bool, n_mem: int,
                     with_ffn: bool = True):
    d, H, KV = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    dt = cfg.activation_dtype

    def defs():
        dd = {
            "ln1": rms_norm_def(d),
            "attn": gqa_proj_defs(d, H, KV, hd, cfg.qkv_bias, dt),
        }
        if gated:
            dd["gate_attn"] = ParamDef((1,), (None,), jnp.float32, init="zeros")
            dd["gate_ffn"] = ParamDef((1,), (None,), jnp.float32, init="zeros")
        if with_ffn:
            dd["ln2"] = rms_norm_def(d)
            dd["ffn"] = swiglu_defs(d, cfg.d_ff, dt)
        return dd

    def _mem_kv(p, mem):
        k = jnp.einsum("btd,dhe->bthe", mem, p["attn"]["wk"])
        v = jnp.einsum("btd,dhe->bthe", mem, p["attn"]["wv"])
        if "bk" in p["attn"]:
            k = k + p["attn"]["bk"]
            v = v + p["attn"]["bv"]
        return k, v

    def _apply(p, x, k, v):
        h = rms_norm(x, p["ln1"], cfg.rms_eps)
        q = jnp.einsum("bsd,dhe->bshe", h, p["attn"]["wq"])
        if "bq" in p["attn"]:
            q = q + p["attn"]["bq"]
        a = cross_attention(q, k, v, n_kv=KV)
        y = out_proj(p["attn"], a)
        if gated:
            y = jnp.tanh(p["gate_attn"]).astype(y.dtype) * y
        x = x + y
        if with_ffn:
            f = swiglu(p["ffn"], rms_norm(x, p["ln2"], cfg.rms_eps))
            if gated:
                f = jnp.tanh(p["gate_ffn"]).astype(f.dtype) * f
            x = x + f
        return x

    def fwd_full(p, x, ctx):
        k, v = _mem_kv(p, ctx["memory"])
        x = _apply(p, x, k, v)
        ce = {"k": k.astype(dt), "v": v.astype(dt)} if ctx["mode"] == "prefill" else {}
        return x, ce, ZERO()

    def fwd_decode(p, x1, ctx, ce):
        x1 = _apply(p, x1, ce["k"], ce["v"])
        return x1, {"k": ce["k"], "v": ce["v"]}, ZERO()

    def cache_defs(B, S):
        ax = ("batch", "frames", "kv_heads", None)
        return {"k": ParamDef((B, n_mem, KV, hd), ax, dt, init="zeros"),
                "v": ParamDef((B, n_mem, KV, hd), ax, dt, init="zeros")}

    return defs, fwd_full, fwd_decode, cache_defs


def make_bidir_layer(cfg: ModelConfig):
    """Bidirectional self-attention encoder layer (whisper encoder)."""
    d, H, KV = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    dt = cfg.activation_dtype

    def defs():
        return {
            "ln1": rms_norm_def(d),
            "attn": gqa_proj_defs(d, H, KV, hd, cfg.qkv_bias, dt),
            "ln2": rms_norm_def(d),
            "ffn": swiglu_defs(d, cfg.d_ff, dt),
        }

    def fwd_full(p, x, ctx):
        h = rms_norm(x, p["ln1"], cfg.rms_eps)
        q, k, v = qkv(p["attn"], h)
        a = cross_attention(q, k, v, n_kv=KV)
        x = x + out_proj(p["attn"], a)
        x = x + swiglu(p["ffn"], rms_norm(x, p["ln2"], cfg.rms_eps))
        return x, {}, ZERO()

    def fwd_decode(p, x1, ctx, ce):
        raise NotImplementedError("encoder layers never run at decode")

    def cache_defs(B, S):
        return {}

    return defs, fwd_full, fwd_decode, cache_defs


# ---------------------------------------------------------------------------
# Mamba2 layer / RWKV6 layer
# ---------------------------------------------------------------------------

def make_mamba_layer(cfg: ModelConfig):
    d, s = cfg.d_model, cfg.ssm
    d_in, H = m2.mamba2_dims(d, s)
    dt = cfg.activation_dtype
    conv_ch = d_in + 2 * s.d_state

    def defs():
        return {"ln": rms_norm_def(d), "mamba": m2.mamba2_defs(d, s, dt)}

    def fwd_full(p, x, ctx):
        h = rms_norm(x, p["ln"], cfg.rms_eps)
        y, final = m2.mamba2_scan(p["mamba"], h, s)
        ce = {}
        if ctx["mode"] == "prefill":
            # conv state: last (W-1) pre-activation conv inputs
            u = _mamba_conv_inputs(p["mamba"], h, s)
            ce = {"state": final,
                  "conv": u[:, -(s.conv_width - 1):].astype(jnp.float32)}
        return x + y, ce, ZERO()

    def fwd_decode(p, x1, ctx, ce):
        h = rms_norm(x1, p["ln"], cfg.rms_eps)
        y, new_state, new_conv = m2.mamba2_step(
            p["mamba"], h, s, ce["state"], ce["conv"].astype(h.dtype))
        return x1 + y, {"state": new_state, "conv": new_conv.astype(jnp.float32)}, ZERO()

    def cache_defs(B, S):
        return {
            "state": ParamDef((B, H, s.d_head, s.d_state),
                              ("batch", "heads", None, None), jnp.float32,
                              init="zeros"),
            "conv": ParamDef((B, s.conv_width - 1, conv_ch),
                             ("batch", None, "ff"), jnp.float32, init="zeros"),
        }

    return defs, fwd_full, fwd_decode, cache_defs


def _mamba_conv_inputs(params, x, s):
    xs = jnp.einsum("bsd,de->bse", x, params["w_x"])
    bc = jnp.einsum("bsd,de->bse", x, params["w_bc"])
    return jnp.concatenate([xs, bc], axis=-1)


def make_rwkv_layer(cfg: ModelConfig):
    d, s = cfg.d_model, cfg.ssm
    H = rw.rwkv6_dims(d, s.d_head)
    dt = cfg.activation_dtype

    def defs():
        dd = rw.rwkv6_defs(d, cfg.d_ff, s.d_head, dt)
        dd["ln1"] = rms_norm_def(d)
        dd["ln2"] = rms_norm_def(d)
        return dd

    def fwd_full(p, x, ctx):
        h = rms_norm(x, p["ln1"], cfg.rms_eps)
        y, S_f, x_tm = rw.time_mix(p["tm"], h, s.d_head, chunk=cfg.rwkv_chunk)
        x = x + y
        h2 = rms_norm(x, p["ln2"], cfg.rms_eps)
        y2, x_cm = rw.channel_mix(p["cm"], h2)
        x = x + y2
        ce = {}
        if ctx["mode"] == "prefill":
            ce = {"S": S_f, "x_tm": x_tm, "x_cm": x_cm}
        return x, ce, ZERO()

    def fwd_decode(p, x1, ctx, ce):
        h = rms_norm(x1, p["ln1"], cfg.rms_eps)
        y, S_new, x_tm = rw.time_mix_step(p["tm"], h, s.d_head, ce["S"], ce["x_tm"])
        x1 = x1 + y
        h2 = rms_norm(x1, p["ln2"], cfg.rms_eps)
        y2, x_cm = rw.channel_mix(p["cm"], h2, ce["x_cm"])
        x1 = x1 + y2
        return x1, {"S": S_new, "x_tm": x_tm, "x_cm": x_cm}, ZERO()

    def cache_defs(B, S):
        return {
            "S": ParamDef((B, H, s.d_head, s.d_head),
                          ("batch", "heads", None, None), jnp.float32, init="zeros"),
            "x_tm": ParamDef((B, 1, d), ("batch", None, "embed"), dt, init="zeros"),
            "x_cm": ParamDef((B, 1, d), ("batch", None, "embed"), dt, init="zeros"),
        }

    return defs, fwd_full, fwd_decode, cache_defs


# ---------------------------------------------------------------------------
# Composite units (gemma local/global, vlm self+cross, zamba mamba+shared-attn)
# ---------------------------------------------------------------------------

def make_unit(layer_makers):
    """Compose sub-layers (name, maker_tuple) into one scanned 'unit' layer."""
    def defs():
        return {name: mk[0]() for name, mk in layer_makers}

    def fwd_full(p, x, ctx):
        ces, aux = {}, ZERO()
        for name, mk in layer_makers:
            x, ce, a = mk[1](p[name], x, ctx)
            if ce:
                ces[name] = ce
            aux += a
        return x, ces, aux

    def fwd_decode(p, x1, ctx, ce):
        new, aux = {}, ZERO()
        for name, mk in layer_makers:
            x1, ce2, a = mk[2](p[name], x1, ctx, ce[name])
            if ce2:
                new[name] = ce2
            aux += a
        return x1, new, aux

    def cache_defs(B, S):
        out = {}
        for name, mk in layer_makers:
            cd = mk[3](B, S)
            if cd:
                out[name] = cd
        return out

    return defs, fwd_full, fwd_decode, cache_defs


def make_stacked_sublayer(maker, n: int):
    """A sub-layer that is itself an inner scanned stack of n layers."""
    dfs, f_full, f_dec, cdefs = maker[:4]

    def defs():
        return stack(dfs(), n)

    def fwd_full(p, x, ctx):
        def body(h, pl):
            h2, ce, aux = f_full(pl, h, ctx)
            return h2, (ce, aux)
        x, (ces, auxs) = jax.lax.scan(body, x, p)
        return x, ces, jnp.sum(auxs)

    def fwd_decode(p, x1, ctx, ce):
        def body(h, args):
            pl, cl = args
            h2, c2, aux = f_dec(pl, h, ctx, cl)
            return h2, (c2, aux)
        x1, (ces, auxs) = jax.lax.scan(body, x1, (p, ce))
        return x1, ces, jnp.sum(auxs)

    def cache_defs(B, S):
        cd = cdefs(B, S)
        return stack(cd, n) if cd else {}

    return defs, fwd_full, fwd_decode, cache_defs


__all__ = [
    "make_attn_layer", "make_mla_layer", "make_cross_layer",
    "make_mamba_layer", "make_rwkv_layer", "make_bidir_layer", "make_unit",
    "make_stacked_sublayer", "_kv_cache_defs", "_kv_arena_defs",
]
