"""Mamba2 (SSD) block — chunked-scan TPU formulation.

Instead of a per-timestep recurrence (GPU-style selective scan), we use the
SSD block decomposition: quadratic *within* a chunk (MXU matmuls) and a
single inter-chunk state recurrence (lax.scan over S/chunk steps). All decay
exponentials are of non-positive arguments, so the chunked form is
numerically safe without rescaling.

State layout: S [B, H, P, N] with H = expand*d/d_head heads, P = d_head,
N = d_state. B/C projections are shared across heads (multi-value SSD).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SSMConfig
from repro.models.pdefs import ParamDef


def mamba2_dims(d: int, s: SSMConfig):
    d_in = s.expand * d
    n_heads = d_in // s.d_head
    return d_in, n_heads


def mamba2_defs(d: int, s: SSMConfig, dtype=jnp.bfloat16):
    d_in, H = mamba2_dims(d, s)
    N, W = s.d_state, s.conv_width
    conv_ch = d_in + 2 * N
    return {
        "w_z": ParamDef((d, d_in), ("embed", "ff"), dtype),
        "w_x": ParamDef((d, d_in), ("embed", "ff"), dtype),
        "w_bc": ParamDef((d, 2 * N), ("embed", None), dtype),
        "w_dt": ParamDef((d, H), ("embed", "heads"), dtype),
        "dt_bias": ParamDef((H,), ("heads",), jnp.float32, init="zeros"),
        "conv_w": ParamDef((W, conv_ch), (None, "ff"), jnp.float32, init="normal",
                           fan_in_dims=(0,)),
        "A_log": ParamDef((H,), ("heads",), jnp.float32, init="zeros"),
        "D_skip": ParamDef((H,), ("heads",), jnp.float32, init="ones"),
        "out_norm": ParamDef((d_in,), ("ff",), init="zeros"),
        "w_out": ParamDef((d_in, d), ("ff", "embed"), dtype),
    }


def _causal_conv(u, w, init_state=None):
    """Depthwise causal conv. u [B,S,C], w [W,C]. init_state [B,W-1,C]."""
    W = w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((u.shape[0], W - 1, u.shape[2]), u.dtype)
    up = jnp.concatenate([init_state.astype(u.dtype), u], axis=1)
    out = sum(up[:, i : i + u.shape[1]] * w[i].astype(u.dtype) for i in range(W))
    new_state = up[:, -(W - 1):] if W > 1 else init_state
    return out, new_state


def _project(params, x, s: SSMConfig, conv_state=None):
    """Shared front half: projections + causal conv + activations."""
    d_in, H = mamba2_dims(x.shape[-1], s)
    N = s.d_state
    z = jnp.einsum("bsd,de->bse", x, params["w_z"])
    xs = jnp.einsum("bsd,de->bse", x, params["w_x"])
    bc = jnp.einsum("bsd,de->bse", x, params["w_bc"])
    dt = jnp.einsum("bsd,dh->bsh", x, params["w_dt"]).astype(jnp.float32)
    u = jnp.concatenate([xs, bc], axis=-1)
    u, new_conv = _causal_conv(u, params["conv_w"], conv_state)
    u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)
    xs, B_, C_ = u[..., :d_in], u[..., d_in : d_in + N], u[..., d_in + N :]
    dt = jax.nn.softplus(dt + params["dt_bias"])                  # [B,S,H]
    A = -jnp.exp(params["A_log"])                                 # [H] (<0)
    la = dt * A                                                   # log-decay <= 0
    xh = xs.reshape(*xs.shape[:-1], H, s.d_head)                  # [B,S,H,P]
    return z, xh, B_, C_, dt, la, new_conv


def mamba2_scan(params, x, s: SSMConfig, init_state=None) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence chunked SSD. x [B,S,D] -> (y [B,S,D], final_state)."""
    Bsz, S, D = x.shape
    d_in, H = mamba2_dims(D, s)
    P, N = s.d_head, s.d_state
    L = min(s.chunk, S)
    while S % L:
        L -= 1
    nC = S // L

    z, xh, B_, C_, dt, la, _ = _project(params, x, s)
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    # reshape into chunks
    def ch(a):
        return a.reshape(Bsz, nC, L, *a.shape[2:])
    xh_c, B_c, C_c, dt_c, la_c = map(ch, (xh, B_, C_, dt, la))
    cum = jnp.cumsum(la_c, axis=2)                                # [B,nC,L,H]

    xdt = xh_c * dt_c[..., None]                                  # [B,nC,L,H,P]
    # intra-chunk: M[b,c,h,t,s] = (C_t . B_s) * exp(cum_t - cum_s) * causal
    G = jnp.einsum("bctn,bcsn->bcts", C_c.astype(jnp.float32),
                   B_c.astype(jnp.float32))                       # [B,nC,L,L]
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # [B,nC,t,s,H]
    tri = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    M = G[..., None] * decay                                      # [B,nC,t,s,H]
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", M, xdt.astype(jnp.float32))

    # chunk-final states: S_end = sum_s exp(cum_L - cum_s) * xdt_s (x) B_s
    w_end = jnp.exp(cum[:, :, -1:, :] - cum)                      # [B,nC,L,H]
    S_end = jnp.einsum("bcsh,bcshp,bcsn->bchpn",
                       w_end, xdt.astype(jnp.float32),
                       B_c.astype(jnp.float32))                   # per-chunk

    # inter-chunk recurrence over nC chunks
    chunk_decay = jnp.exp(cum[:, :, -1, :])                       # [B,nC,H]

    def body(S_prev, args):
        S_end_c, cd_c = args                                      # [B,H,P,N],[B,H]
        S_new = cd_c[:, :, None, None] * S_prev + S_end_c
        return S_new, S_prev

    S_ends = jnp.moveaxis(S_end, 1, 0)                            # [nC,B,H,P,N]
    cds = jnp.moveaxis(chunk_decay, 1, 0)                         # [nC,B,H]
    final_state, S_prevs = jax.lax.scan(body, init_state, (S_ends, cds))
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)                         # [B,nC,H,P,N]

    y_inter = jnp.einsum("bcth,bctn,bchpn->bcthp",
                         jnp.exp(cum), C_c.astype(jnp.float32), S_prevs)

    y = y_intra + y_inter + params["D_skip"][None, None, None, :, None] * xh_c.astype(jnp.float32)
    y = y.reshape(Bsz, S, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    # group norm (rms over channels)
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-5) * (1.0 + params["out_norm"])
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), params["w_out"])
    return out, final_state


def mamba2_step(params, x1, s: SSMConfig, state, conv_state):
    """Single decode step. x1 [B,1,D]; state [B,H,P,N]; conv_state [B,W-1,C]."""
    Bsz, _, D = x1.shape
    d_in, H = mamba2_dims(D, s)
    z, xh, B_, C_, dt, la, new_conv = _project(params, x1, s, conv_state)
    xdt = (xh * dt[..., None])[:, 0].astype(jnp.float32)          # [B,H,P]
    a = jnp.exp(la[:, 0])                                         # [B,H]
    new_state = (a[:, :, None, None] * state
                 + jnp.einsum("bhp,bn->bhpn", xdt, B_[:, 0].astype(jnp.float32)))
    y = jnp.einsum("bn,bhpn->bhp", C_[:, 0].astype(jnp.float32), new_state)
    y = y + params["D_skip"][None, :, None] * xh[:, 0].astype(jnp.float32)
    y = y.reshape(Bsz, 1, d_in) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-5) * (1.0 + params["out_norm"])
    out = jnp.einsum("bse,ed->bsd", y.astype(x1.dtype), params["w_out"])
    return out, new_state, new_conv


__all__ = ["mamba2_defs", "mamba2_scan", "mamba2_step", "mamba2_dims"]
