"""RWKV-6 (Finch) block: data-dependent decay linear attention + channel mix.

The wkv recurrence keeps a per-head matrix state S [B,H,K,V]:
    y_t = r_t @ (S_{t-1} + (u * k_t)^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
with per-channel decay w_t = exp(-exp(w0 + lora_w(x))) (data-dependent — the
Finch contribution) and token-shift "ddlerp" interpolation with a low-rank
adapter.

Baseline implementation is a sequential lax.scan over time (exact). A
chunked MXU-friendly variant (`rwkv6_scan(..., chunk=L)`) processes L steps
per matmul block and is the §Perf optimization target; chunk=1 falls back to
the sequential path.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.pdefs import ParamDef

DDLERP_RANK = 32
DECAY_RANK = 64
N_MIX = 5  # w,k,v,r,g


def rwkv6_dims(d: int, d_head: int):
    return d // d_head  # n_heads


def rwkv6_defs(d: int, d_ff: int, d_head: int, dtype=jnp.bfloat16):
    H = rwkv6_dims(d, d_head)
    return {
        "tm": {  # time mix
            "mu_x": ParamDef((N_MIX, d), (None, "embed"), jnp.float32, init="zeros"),
            "ddlerp_a": ParamDef((d, N_MIX * DDLERP_RANK), ("embed", "lora"), dtype),
            "ddlerp_b": ParamDef((N_MIX, DDLERP_RANK, d), (None, "lora", "embed"), dtype),
            "w_r": ParamDef((d, d), ("embed", "heads"), dtype),
            "w_k": ParamDef((d, d), ("embed", "heads"), dtype),
            "w_v": ParamDef((d, d), ("embed", "heads"), dtype),
            "w_g": ParamDef((d, d), ("embed", "heads"), dtype),
            "w_o": ParamDef((d, d), ("heads", "embed"), dtype),
            "decay_w0": ParamDef((d,), ("embed",), jnp.float32, init="zeros"),
            "decay_a": ParamDef((d, DECAY_RANK), ("embed", "lora"), dtype),
            "decay_b": ParamDef((DECAY_RANK, d), ("lora", "embed"), dtype),
            "bonus_u": ParamDef((H, d_head), ("heads", None), jnp.float32, init="zeros"),
            "ln_x": ParamDef((d,), ("embed",), init="zeros"),
        },
        "cm": {  # channel mix
            "mu_k": ParamDef((d,), ("embed",), jnp.float32, init="zeros"),
            "mu_r": ParamDef((d,), ("embed",), jnp.float32, init="zeros"),
            "w_k": ParamDef((d, d_ff), ("embed", "ff"), dtype),
            "w_v": ParamDef((d_ff, d), ("ff", "embed"), dtype),
            "w_r": ParamDef((d, d), ("embed", "heads"), dtype),
        },
    }


def _shift(x, last):
    """x [B,S,D]; last [B,1,D] (previous token, zeros at start)."""
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _ddlerp(p, x, x_prev):
    """Data-dependent 5-way token-shift interpolation -> [5][B,S,D]."""
    xx = x_prev - x
    base = x + xx * p["mu_x"][0]  # use first mu as the adapter input mix
    low = jnp.tanh(jnp.einsum("bsd,dr->bsr", base, p["ddlerp_a"])
                   .reshape(*x.shape[:2], N_MIX, DDLERP_RANK))
    delta = jnp.einsum("bsmr,mrd->bsmd", low, p["ddlerp_b"])     # [B,S,5,D]
    outs = []
    for i in range(N_MIX):
        mi = p["mu_x"][i] + delta[:, :, i].astype(jnp.float32)
        outs.append(x + xx * mi.astype(x.dtype))
    return outs


def _tm_project(p, x, x_prev, d_head: int):
    """Projections + decay for the time-mix. Returns r,k,v,g,w(decay),H-shaped."""
    B, S, D = x.shape
    H = D // d_head
    xw, xk, xv, xr, xg = _ddlerp(p, x, x_prev)
    r = jnp.einsum("bsd,de->bse", xr, p["w_r"]).reshape(B, S, H, d_head)
    k = jnp.einsum("bsd,de->bse", xk, p["w_k"]).reshape(B, S, H, d_head)
    v = jnp.einsum("bsd,de->bse", xv, p["w_v"]).reshape(B, S, H, d_head)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["w_g"]).astype(jnp.float32))
    dd = jnp.einsum("bsd,dr->bsr", xw, p["decay_a"])
    dd = jnp.einsum("bsr,rd->bsd", jnp.tanh(dd), p["decay_b"])
    logw = -jnp.exp(p["decay_w0"] + dd.astype(jnp.float32))      # <= 0
    w = jnp.exp(logw).reshape(B, S, H, d_head)                   # decay in (0,1)
    return r, k, v, g, w, logw.reshape(B, S, H, d_head)


def _tm_finish(p, wkv_out, g, x_dtype):
    """Per-head groupnorm + gate + output projection."""
    B, S, H, dv = wkv_out.shape
    y = wkv_out
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-5)
    y = y.reshape(B, S, H * dv) * (1.0 + p["ln_x"])
    y = y * g
    return jnp.einsum("bse,ed->bsd", y.astype(x_dtype), p["w_o"])


def time_mix(p, x, d_head: int, state=None, x_last=None,
             chunk: int = 1) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence time-mix. Returns (out, final_state, last_x).

    state: [B,H,K,V] f32; x_last: [B,1,D] previous token for the shift.
    """
    B, S, D = x.shape
    H = D // d_head
    if x_last is None:
        x_last = jnp.zeros((B, 1, D), x.dtype)
    if state is None:
        state = jnp.zeros((B, H, d_head, d_head), jnp.float32)
    x_prev = _shift(x, x_last)
    r, k, v, g, w, logw = _tm_project(p, x, x_prev, d_head)
    u = p["bonus_u"]

    if chunk > 1 and S % chunk == 0 and S > chunk:
        out, final = _wkv_chunked(r, k, v, w, logw, u, state, chunk)
    else:
        out, final = _wkv_sequential(r, k, v, w, u, state)
    y = _tm_finish(p, out, g, x.dtype)
    return y, final, x[:, -1:]


def _wkv_sequential(r, k, v, w, u, state):
    """Exact per-step recurrence (oracle / baseline)."""
    B, S, H, dk = r.shape
    rs, ks, vs, ws = (jnp.moveaxis(a, 1, 0).astype(jnp.float32)
                      for a in (r, k, v, w))

    def body(S_prev, args):
        rt, kt, vt, wt = args                                    # [B,H,dk]
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        yt = jnp.einsum("bhk,bhkv->bhv", rt, S_prev + u[None] [..., None] * kv)
        S_new = wt[..., None] * S_prev + kv
        return S_new, yt

    final, ys = jax.lax.scan(body, state, (rs, ks, vs, ws))
    return jnp.moveaxis(ys, 0, 1), final                         # [B,S,H,dv]


def _wkv_chunked(r, k, v, w, logw, u, state, L: int):
    """Chunked linear-attention form (MXU-friendly §Perf variant).

    All exponentials have non-positive arguments (cum log-decays are
    monotonically decreasing), so the chunked form is numerically safe:
      intra-chunk decay(t,s) = exp(cum_{t-1} - cum_s)  for s < t   (<= 1)
      inter-chunk factor     = exp(cum_{t-1})                       (<= 1)
      state carry factor     = exp(cum_L - cum_s)                   (<= 1)
    The intra-chunk pairwise diff tensor is [B,L,L,H,K]; L is capped at 64
    to bound its footprint (secondary chunking would lift this — §Perf).
    """
    B, S, H, dk = r.shape
    assert L <= 64, "chunked wkv uses a direct pairwise-diff; keep chunk <= 64"
    nC = S // L

    def ch(a):
        return jnp.moveaxis(a.reshape(B, nC, L, H, dk), 1, 0)    # [nC,B,L,H,dk]
    rc, kc, vc, lc = map(ch, (r, k, v, logw))
    rc, kc, vc = (a.astype(jnp.float32) for a in (rc, kc, vc))
    lc = lc.astype(jnp.float32)
    tri = jnp.tril(jnp.ones((L, L), bool), k=-1)

    def body(S_prev, args):
        rt, kt, vt, lt = args                                    # [B,L,H,dk]
        cum = jnp.cumsum(lt, axis=1)                             # inclusive
        cum_prev = cum - lt                                      # cum_{t-1}
        # inter-chunk: y_inter[t] = (r_t * exp(cum_{t-1})) @ S_prev
        y_inter = jnp.einsum("blhk,bhkv->blhv", rt * jnp.exp(cum_prev), S_prev)
        # intra-chunk, direct log-space pairwise differences (all <= 0)
        diff = cum_prev[:, :, None] - cum[:, None, :, :]         # [B,t,s,H,K]
        dec = jnp.where(tri[None, :, :, None, None], jnp.exp(diff), 0.0)
        A = jnp.einsum("bthk,bshk,btshk->bhts", rt, kt, dec)
        diag = jnp.einsum("blhk,blhk->blh", rt, u[None, None] * kt)
        y_intra = jnp.einsum("bhts,bshv->bthv", A, vt) + diag[..., None] * vt
        # state update
        wL = jnp.exp(cum[:, -1])                                 # [B,H,dk]
        kw = kt * jnp.exp(cum[:, -1:, :, :] - cum)
        S_new = wL[..., None] * S_prev + jnp.einsum("bshk,bshv->bhkv", kw, vt)
        return S_new, y_inter + y_intra

    final, ys = jax.lax.scan(body, state, (rc, kc, vc, lc))
    ys = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, dk)
    return ys, final


def time_mix_step(p, x1, d_head: int, state, x_last):
    """Single decode step. x1 [B,1,D]."""
    r, k, v, g, w, _ = _tm_project(p, x1, x_last, d_head)
    rt, kt, vt, wt = (a[:, 0].astype(jnp.float32) for a in (r, k, v, w))
    kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
    u = p["bonus_u"]
    yt = jnp.einsum("bhk,bhkv->bhv", rt, state + u[None][..., None] * kv)
    S_new = wt[..., None] * state + kv
    y = _tm_finish(p, yt[:, None], g, x1.dtype)
    return y, S_new, x1


def channel_mix(p, x, x_last=None):
    from repro.models.shardctx import constrain
    B, S, D = x.shape
    if x_last is None:
        x_last = jnp.zeros((B, 1, D), x.dtype)
    x_prev = _shift(x, x_last)
    xx = x_prev - x
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xr = x + xx * p["mu_r"].astype(x.dtype)
    k = jnp.einsum("bsd,df->bsf", xk, p["w_k"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["w_r"]).astype(jnp.float32))
    # keep the w_v output sharded like the gate (reduce-scatter instead of
    # all-reduce; the gating product stays local) — §Perf pair 3
    wv = jnp.einsum("bsf,fd->bsd", k, p["w_v"]).astype(jnp.float32)
    wv = constrain(wv, ("batch", None, "heads"))
    out = constrain(r * wv, ("batch", None, "heads"))
    return out.astype(x.dtype), x[:, -1:]


__all__ = [
    "rwkv6_defs", "rwkv6_dims", "time_mix", "time_mix_step", "channel_mix",
]
