"""Declarative parameter definitions.

A model's parameters are described once as a pytree of :class:`ParamDef`
(shape + logical axes + init rule). From that single source of truth we derive:

* real initialized params        (``init_from_defs``)
* abstract ShapeDtypeStructs     (``abstract_from_defs``) — used by the dry-run
* PartitionSpecs for a mesh      (``pspecs_from_defs``) — divisibility-aware

Logical axis names used across the codebase:
  "embed"     d_model dim               -> sharded over "data" (FSDP)
  "vocab"     vocabulary dim            -> "model"
  "ff"        mlp hidden dim            -> "model"
  "heads"     q heads (or fused h*hd)   -> "model"
  "kv_heads"  kv heads                  -> "model"
  "experts"   MoE expert dim            -> "model"
  "layers"    scanned layer stack       -> replicated
  "lora"      low-rank adapters, states -> replicated
  None        replicated
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"          # normal | zeros | ones | scaled | embed
    fan_in_dims: Tuple[int, ...] = ()   # dims contributing to fan-in (default: all but last)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_pdef(x) -> bool:
    return isinstance(x, ParamDef)


def _tmap(f, tree):
    return jax.tree_util.tree_map(f, tree, is_leaf=is_pdef)


def _tmap_with_path(f, tree):
    return jax.tree_util.tree_map_with_path(f, tree, is_leaf=is_pdef)


def stack(defs, n: int):
    """Add a leading scanned-layers axis of size n to every ParamDef."""
    return _tmap(
        lambda d: dataclasses.replace(d, shape=(n,) + d.shape, axes=("layers",) + d.axes),
        defs,
    )


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def _init_leaf(key, d: ParamDef):
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    fan_dims = d.fan_in_dims or tuple(range(max(len(d.shape) - 1, 1)))
    # scanned stacks: the leading "layers" axis never counts toward fan-in
    fan = 1
    for i in fan_dims:
        if i < len(d.shape) and d.axes[i] != "layers":
            fan *= d.shape[i]
    if d.init == "embed":
        scale = 1.0
    else:
        scale = 1.0 / np.sqrt(max(fan, 1))
    return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(d.dtype)


def init_from_defs(defs, key):
    """Initialize real parameters. Keys are derived per-path (stable)."""
    def f(path, d):
        pstr = jax.tree_util.keystr(path)
        sub = jax.random.fold_in(key, hash(pstr) % (2**31))
        return _init_leaf(sub, d)
    return _tmap_with_path(f, defs)


def abstract_from_defs(defs):
    return _tmap(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs)


# ---------------------------------------------------------------------------
# Sharding
# ---------------------------------------------------------------------------

# logical axis -> preferred mesh axis (in priority order); divisibility-checked
DEFAULT_RULES = {
    "vocab": ("model",),
    "ff": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "experts": ("model",),
    "embed": ("data",),          # FSDP weight sharding
    "layers": (),
    "lora": (),
    "batch": ("pod", "data"),
    "cache_seq": (),
    "frames": (),
    # paged KV arenas: replicated today; a multi-host sharded arena would
    # shard "pages" over ("pod", "data") once page ids are mesh-local
    "pages": (),
    "page_seq": (),
}


def resolve_axes(axes, shape, mesh: Mesh, rules=None) -> PartitionSpec:
    """Map logical axes to a PartitionSpec, dropping non-dividing or duplicate
    mesh axes (a mesh axis may appear at most once in a spec)."""
    rules = rules if rules is not None else DEFAULT_RULES
    used: set = set()
    out = []
    for size, ax in zip(shape, axes):
        picked = None
        if ax is not None:
            candidates = rules.get(ax, ())
            if isinstance(candidates, str):
                candidates = (candidates,)
            # multi-axis sharding for one dim (e.g. batch over (pod, data))
            multi = []
            prod = 1
            for cand in candidates:
                if cand in used or cand not in mesh.shape:
                    continue
                if size % (prod * mesh.shape[cand]) == 0:
                    multi.append(cand)
                    prod *= mesh.shape[cand]
            if multi:
                for m in multi:
                    used.add(m)
                picked = tuple(multi) if len(multi) > 1 else multi[0]
        out.append(picked)
    # trim trailing Nones for readability
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def pspecs_from_defs(defs, mesh: Mesh, rules=None):
    return _tmap(lambda d: resolve_axes(d.axes, d.shape, mesh, rules), defs)


def shardings_from_defs(defs, mesh: Mesh, rules=None):
    return _tmap(lambda d: NamedSharding(mesh, resolve_axes(d.axes, d.shape, mesh, rules)), defs)


def count_params(defs) -> int:
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=is_pdef)
    return int(sum(int(np.prod(d.shape)) for d in leaves))


__all__ = [
    "ParamDef", "stack", "init_from_defs", "abstract_from_defs",
    "pspecs_from_defs", "shardings_from_defs", "resolve_axes",
    "count_params", "DEFAULT_RULES", "is_pdef",
]
