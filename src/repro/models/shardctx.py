"""Activation-sharding context.

Model code annotates intermediate activations with *logical* axes; the
launcher installs a mesh + rules so those become
``with_sharding_constraint`` calls. On a single device (tests) this is a
no-op.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.models.pdefs import DEFAULT_RULES, resolve_axes

_MESH: Optional[Mesh] = None
_RULES = None


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules=None):
    global _MESH, _RULES
    prev = (_MESH, _RULES)
    _MESH, _RULES = mesh, (rules if rules is not None else DEFAULT_RULES)
    try:
        yield
    finally:
        _MESH, _RULES = prev


def constrain(x, logical_axes):
    """Annotate activation x with logical axes (no-op without a mesh)."""
    if _MESH is None:
        return x
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    spec = resolve_axes(logical_axes, x.shape, _MESH, _RULES)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))


def current_mesh() -> Optional[Mesh]:
    return _MESH


__all__ = ["activation_sharding", "constrain", "current_mesh"]
