"""Mixture-of-Experts FFN with capacity-bounded scatter dispatch.

GShard-style expert parallelism adapted for TPU: tokens are grouped (one
group per sequence by default), routed top-k, and scatter-added into a
[groups, experts, capacity, d] dispatch buffer. With experts sharded over the
"model" mesh axis and groups over "data", XLA SPMD inserts the all-to-all on
the group<->expert exchange — the paper-agnostic substrate for the two MoE
architectures assigned to this reproduction (olmoe-1b-7b, deepseek-v2-lite).

We deliberately avoid the classic [tokens, experts, capacity] one-hot einsum
dispatch: at 1M tokens it would materialize petabyte-scale tensors. The
scatter/gather formulation keeps the footprint at O(G*E*C*D).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import MoEConfig
from repro.models.pdefs import ParamDef
from repro.models.shardctx import constrain, current_mesh


def moe_defs(d: int, m: MoEConfig, dtype=jnp.bfloat16):
    E, F = m.n_experts, m.expert_ff
    defs = {
        "router": ParamDef((d, E), ("embed", "experts"), jnp.float32),
        "wi_gate": ParamDef((E, d, F), ("experts", "embed", "ff"), dtype,
                            fan_in_dims=(1,)),
        "wi_up": ParamDef((E, d, F), ("experts", "embed", "ff"), dtype,
                          fan_in_dims=(1,)),
        "wo": ParamDef((E, F, d), ("experts", "ff", "embed"), dtype,
                       fan_in_dims=(1,)),
    }
    if m.n_shared_experts:
        SF = m.n_shared_experts * F
        defs["shared"] = {
            "wi_gate": ParamDef((d, SF), ("embed", "ff"), dtype),
            "wi_up": ParamDef((d, SF), ("embed", "ff"), dtype),
            "wo": ParamDef((SF, d), ("ff", "embed"), dtype),
        }
    return defs


def _group_tokens(x, group_size: int):
    """[B,S,D] -> [G, g, D] preserving batch-major order."""
    B, S, D = x.shape
    T = B * S
    g = min(group_size, T)
    while T % g:
        g -= 1
    return x.reshape(T // g, g, D), g


def moe_ffn(params, x, m: MoEConfig, *, group_size: int = 4096,
            dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
    """Returns (out [B,S,D], aux_loss scalar). Dispatches to the explicit
    expert-parallel schedule when configured and a mesh is installed."""
    mesh = current_mesh()
    if (m.shard_mode == "ep" and mesh is not None
            and "model" in mesh.shape
            and m.n_experts % mesh.shape["model"] == 0):
        return _moe_ffn_ep(params, x, m, mesh, group_size=group_size,
                           dtype=dtype)
    return _moe_ffn_auto(params, x, m, group_size=group_size, dtype=dtype)


def _moe_ffn_auto(params, x, m: MoEConfig, *, group_size: int = 4096,
                  dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
    """Baseline: rely on XLA SPMD propagation (paper-faithful substrate)."""
    B, S, D = x.shape
    E, K = m.n_experts, m.top_k
    xg, g = _group_tokens(x, group_size)
    G = xg.shape[0]
    C = max(int(np.ceil(g * K / E * m.capacity_factor)), 1)

    # --- routing (f32) ------------------------------------------------------
    logits = jnp.einsum("Gtd,de->Gte", xg.astype(jnp.float32),
                        params["router"])                       # [G,g,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                      # [G,g,K]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))                                # [E]
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0)
    ce = ce / jnp.maximum(ce.sum(), 1.0)
    aux = E * jnp.sum(me * ce) * m.router_aux_weight

    # --- capacity assignment -------------------------------------------------
    # flatten (token, k) assignments in priority order within each group
    e_flat = top_e.reshape(G, g * K)                            # [G,gK]
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)         # [G,gK,E]
    slot = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1    # [G,gK]
    keep = slot < C
    slot_c = jnp.clip(slot, 0, C - 1)

    # --- dispatch: scatter tokens into [G,E,C,D] -----------------------------
    xr = jnp.repeat(xg, K, axis=1)                              # [G,gK,D]
    w_flat = (top_w.reshape(G, g * K) * keep).astype(jnp.float32)
    disp = jnp.zeros((G, E, C, D), dtype)
    gi = jnp.arange(G)[:, None]
    disp = disp.at[gi, e_flat, slot_c].add(
        jnp.where(keep[..., None], xr, 0).astype(dtype))
    disp = constrain(disp, ("batch", "experts", None, None))

    # --- expert computation (all-to-all boundary under SPMD) -----------------
    h_g = jnp.einsum("GEcd,Edf->GEcf", disp, params["wi_gate"])
    h_u = jnp.einsum("GEcd,Edf->GEcf", disp, params["wi_up"])
    h = jax.nn.silu(h_g.astype(jnp.float32)).astype(dtype) * h_u
    y = jnp.einsum("GEcf,Efd->GEcd", h, params["wo"])           # [G,E,C,D]
    y = constrain(y, ("batch", "experts", None, None))

    # --- combine: gather expert outputs back to tokens -----------------------
    y_tok = y[gi, e_flat, slot_c]                               # [G,gK,D]
    y_tok = y_tok * w_flat[..., None].astype(y_tok.dtype)
    out = y_tok.reshape(G, g, K, D).sum(axis=2)                 # [G,g,D]
    out = out.reshape(B, S, D)

    if m.n_shared_experts:
        from repro.models.layers import swiglu
        out = out + swiglu(params["shared"], x)
    return out.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Explicit expert-parallel schedule (§Perf beyond-paper optimization)
# ---------------------------------------------------------------------------

def _moe_ffn_ep(params, x, m: MoEConfig, mesh, *, group_size: int = 4096,
                dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
    """shard_map expert parallelism over the "model" axis.

    Tokens are replicated across "model" (batch is data-sharded), so no
    dispatch exchange is needed at all: every model shard routes all tokens,
    keeps only the assignments owned by its local expert slice, runs the
    expert FFN locally, and the combined token outputs are psum'd over
    "model". Collective cost per layer = one all-reduce of [tokens, D] —
    vs the auto schedule's all-reduce of the full [G,E,C,D] dispatch
    buffers.
    """
    B, S, D = x.shape
    E, K = m.n_experts, m.top_k
    n_model = mesh.shape["model"]
    E_loc = E // n_model
    xg, g = _group_tokens(x, group_size)
    G = xg.shape[0]
    C = max(int(np.ceil(g * K / E * m.capacity_factor)), 1)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape
                       and G % mesh.shape[a] == 0)
    gspec = batch_axes if batch_axes else None

    def local(xg_l, router, wi_g, wi_u, wo):
        midx = jax.lax.axis_index("model")
        lo = midx * E_loc
        Gl = xg_l.shape[0]
        logits = jnp.einsum("Gtd,de->Gte", xg_l.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, K)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

        me = probs.mean(axis=(0, 1))
        ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0)
        ce = ce / jnp.maximum(ce.sum(), 1.0)
        aux = E * jnp.sum(me * ce) * m.router_aux_weight
        # aux identical on every model shard; average keeps it replicated
        aux = jax.lax.pmean(aux, "model")

        e_flat = top_e.reshape(Gl, g * K)
        onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
        slot = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1
        e_local = e_flat - lo
        keep = (slot < C) & (e_local >= 0) & (e_local < E_loc)
        slot_c = jnp.clip(slot, 0, C - 1)
        e_loc_c = jnp.clip(e_local, 0, E_loc - 1)

        xr = jnp.repeat(xg_l, K, axis=1)
        w_flat = (top_w.reshape(Gl, g * K) * keep).astype(jnp.float32)
        disp = jnp.zeros((Gl, E_loc, C, D), dtype)
        gi = jnp.arange(Gl)[:, None]
        disp = disp.at[gi, e_loc_c, slot_c].add(
            jnp.where(keep[..., None], xr, 0).astype(dtype))

        h_g = jnp.einsum("GEcd,Edf->GEcf", disp, wi_g)
        h_u = jnp.einsum("GEcd,Edf->GEcf", disp, wi_u)
        h = jax.nn.silu(h_g.astype(jnp.float32)).astype(dtype) * h_u
        y = jnp.einsum("GEcf,Efd->GEcd", h, wo)

        y_tok = y[gi, e_loc_c, slot_c] * w_flat[..., None].astype(y.dtype)
        out = y_tok.reshape(Gl, g, K, D).sum(axis=2)
        # combine across expert owners — in the compute dtype: each token's
        # contribution comes from <= top_k shards, so bf16 psum loses at
        # most one rounding step vs f32 (measured §Perf pair 1 iter 2)
        out = jax.lax.psum(out.astype(dtype), "model")
        return out, aux

    other = tuple(a for a in mesh.axis_names if a not in (batch_axes or ()))
    out, aux = shard_map(
        local, mesh=mesh,
        in_specs=(P(gspec, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(gspec, None, None), P()),
        check_rep=False,
    )(xg, params["router"], params["wi_gate"], params["wi_up"], params["wo"])
    out = out.reshape(B, S, D)
    if m.n_shared_experts:
        from repro.models.layers import swiglu
        out = out + swiglu(params["shared"], x)
    return out.astype(x.dtype), aux


__all__ = ["moe_defs", "moe_ffn"]
