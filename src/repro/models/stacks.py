"""Segment machinery: a model body is an ordered list of Segments, each a
homogeneous stack of layers run with lax.scan (scanned-layer params carry a
leading [n] axis). Caches mirror the segment structure.

Segment contract (all functions are pure):
  defs()                      -> pytree of ParamDef for ONE layer
  cache_defs(B, S)            -> pytree of ParamDef for ONE layer's cache (or {})
  fwd_full(p, x, ctx)         -> (x, cache_entry, aux)   # train/prefill over S
  fwd_decode(p, x1, ctx, ce)  -> (x1, new_cache_entry, aux)

ctx is a dict with: positions, lengths, memory (image/audio embeddings),
enc_out, cfg, mode.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models.pdefs import ParamDef, stack, abstract_from_defs


@dataclass
class Segment:
    """Field order matches the family maker tuples:
    (defs, fwd_full, fwd_decode, cache_defs[, paged_cache_defs[, fwd_append]]).

    ``paged_cache_defs(num_pages, page_size)`` describes the layer's slice
    of a global page arena (no batch axis — slots map into it through a page
    table); None means the layer only supports contiguous per-slot lanes.

    ``fwd_append(p, x, ctx, ce)`` is the multi-token sibling of
    ``fwd_decode`` for paged caches: x is a batch-1 suffix tile whose token
    ``i`` sits at absolute position ``ctx["prefix_len"] + i``, ``ce`` is the
    layer's page arena, and the layer scatters the suffix KV straight into
    its pages (through ``ctx["page_table"]``) before attending over prefix +
    suffix. Only paged-capable layers provide it."""
    name: str
    n: int
    defs: Callable[[], Any]
    fwd_full: Callable
    fwd_decode: Callable
    cache_defs: Callable[[int, int], Any]
    paged_cache_defs: Optional[Callable[[int, int], Any]] = None
    fwd_append: Optional[Callable] = None
    scan: bool = True


def segments_param_defs(segments: List[Segment]) -> Dict[str, Any]:
    out = {}
    for s in segments:
        d = s.defs()
        out[s.name] = stack(d, s.n) if (s.scan and s.n > 1) else d
    return out


def segments_cache_defs(segments: List[Segment], batch: int, seq: int):
    out = {}
    for s in segments:
        cd = s.cache_defs(batch, seq)
        if not cd:
            continue
        out[s.name] = stack(cd, s.n) if (s.scan and s.n > 1) else cd
    return out


def segments_paged_cache_defs(segments: List[Segment], num_pages: int,
                              page_size: int):
    """Paged-arena defs mirroring :func:`segments_cache_defs`'s structure,
    or None when any caching segment lacks paged support."""
    out = {}
    for s in segments:
        if not s.cache_defs(1, page_size):
            continue                      # stateless segment (e.g. encoder)
        if s.paged_cache_defs is None:
            return None
        cd = s.paged_cache_defs(num_pages, page_size)
        if cd is None:
            return None
        out[s.name] = stack(cd, s.n) if (s.scan and s.n > 1) else cd
    return out


def _maybe_remat(fn, do_remat: bool):
    return jax.checkpoint(fn, prevent_cse=False) if do_remat else fn


def run_segments_full(params, x, segments: List[Segment], ctx,
                      *, want_cache: bool, remat: bool):
    """Run all segments over a full sequence. Returns (x, cache, aux_sum)."""
    cache_out = {}
    aux_total = jnp.zeros((), jnp.float32)
    for s in segments:
        p = params[s.name]
        if s.scan and s.n > 1:
            def body(h, pl, _s=s):
                h2, ce, aux = _s.fwd_full(pl, h, ctx)
                ys = (ce, aux) if want_cache else aux
                return h2, ys
            body = _maybe_remat(body, remat)
            x, ys = jax.lax.scan(body, x, p)
            if want_cache:
                ces, auxs = ys
                if ces:
                    cache_out[s.name] = ces
                aux_total += jnp.sum(auxs)
            else:
                aux_total += jnp.sum(ys)
        else:
            fn = _maybe_remat(lambda pl, h, _s=s: _s.fwd_full(pl, h, ctx), remat)
            x, ce, aux = fn(p, x)
            if want_cache and ce:
                cache_out[s.name] = ce
            aux_total += aux
    return x, cache_out, aux_total


def run_segments_append(params, x, segments: List[Segment], ctx, cache):
    """Multi-token suffix step against an existing paged cache: like
    :func:`run_segments_decode` but x is a [1, S] suffix tile and each layer
    writes S new cache positions (prefix-cached partial prefill)."""
    new_cache = {}
    aux_total = jnp.zeros((), jnp.float32)
    for s in segments:
        if s.fwd_append is None:
            raise NotImplementedError(
                f"segment {s.name!r} has no paged append path")
        p = params[s.name]
        ce = cache.get(s.name)
        if s.scan and s.n > 1:
            def body(h, args, _s=s):
                pl, ce_l = args
                h2, ce2, aux = _s.fwd_append(pl, h, ctx, ce_l)
                return h2, (ce2, aux)
            x, (ces, auxs) = jax.lax.scan(body, x, (p, ce))
            if ces:
                new_cache[s.name] = ces
            aux_total += jnp.sum(auxs)
        else:
            x, ce2, aux = s.fwd_append(p, x, ctx, ce)
            if ce2:
                new_cache[s.name] = ce2
            aux_total += aux
    return x, new_cache, aux_total


def run_segments_fused(params, x1, xc, segments: List[Segment], ctx_d,
                       ctx_a, cache):
    """One fused chunked-prefill + decode pass: each layer first appends one
    request's prefill chunk (``xc [1, C]`` under ``ctx_a`` — page table row,
    prefix/suffix lengths) into the shared page arena, then runs the
    single-token decode for every resident row (``x1 [B, 1]`` under
    ``ctx_d``), chaining the layer's cache entry through both. ONE
    ``lax.scan`` per segment covers both roles, so layer params are read
    once per step no matter how the token budget splits between prefill
    and decode.

    Correctness does not depend on the append/decode order inside a layer:
    the chunk scatters only into its own slot's private suffix pages, the
    decode rows scatter only into *their* slots' private pages (mid-prefill
    and empty rows are masked to the trash page by the caller), and the
    only physically shared pages — prefix-cache blocks — are read-only on
    both sides. The chained cache entry therefore equals the two passes run
    back-to-back, which is what the greedy token-identity gates check."""
    new_cache = {}
    aux_total = jnp.zeros((), jnp.float32)
    for s in segments:
        if s.fwd_append is None:
            raise NotImplementedError(
                f"segment {s.name!r} has no paged append path")
        p = params[s.name]
        ce = cache.get(s.name)
        if s.scan and s.n > 1:
            def body(carry, args, _s=s):
                h1, hc = carry
                pl, ce_l = args
                hc2, ce_mid, aux_a = _s.fwd_append(pl, hc, ctx_a, ce_l)
                h2, ce2, aux_d = _s.fwd_decode(pl, h1, ctx_d, ce_mid)
                return (h2, hc2), (ce2, aux_a + aux_d)
            (x1, xc), (ces, auxs) = jax.lax.scan(body, (x1, xc), (p, ce))
            if ces:
                new_cache[s.name] = ces
            aux_total += jnp.sum(auxs)
        else:
            xc, ce_mid, aux_a = s.fwd_append(p, xc, ctx_a, ce)
            x1, ce2, aux_d = s.fwd_decode(p, x1, ctx_d, ce_mid)
            if ce2:
                new_cache[s.name] = ce2
            aux_total += aux_a + aux_d
    return x1, xc, new_cache, aux_total


def run_segments_decode(params, x1, segments: List[Segment], ctx, cache):
    """Single-token step through all segments, updating the cache."""
    new_cache = {}
    aux_total = jnp.zeros((), jnp.float32)
    for s in segments:
        p = params[s.name]
        ce = cache.get(s.name)
        if s.scan and s.n > 1:
            def body(h, args, _s=s):
                pl, ce_l = args
                h2, ce2, aux = _s.fwd_decode(pl, h, ctx, ce_l)
                return h2, (ce2, aux)
            x1, (ces, auxs) = jax.lax.scan(body, x1, (p, ce))
            if ces:
                new_cache[s.name] = ces
            aux_total += jnp.sum(auxs)
        else:
            x1, ce2, aux = s.fwd_decode(p, x1, ctx, ce)
            if ce2:
                new_cache[s.name] = ce2
            aux_total += aux
    return x1, new_cache, aux_total


__all__ = [
    "Segment", "segments_param_defs", "segments_cache_defs",
    "segments_paged_cache_defs", "run_segments_full", "run_segments_decode",
    "run_segments_append", "run_segments_fused",
]
