"""Shared model layers: RMSNorm, RoPE, SwiGLU, GQA attention (blockwise
causal, sliding-window, cross, decode).

Attention is implemented *blockwise* (lax.scan over query chunks) so that the
S x S score matrix is never materialized — required for the 32k-prefill shapes
where a full score tensor would be petabytes. GQA is computed with grouped
einsums (no KV head repetition in HBM).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.pdefs import ParamDef

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms / FFN
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(dtype)


def rms_norm_def(d: int) -> ParamDef:
    # stored as a delta around 1.0 (zeros init) — gemma-style
    return ParamDef((d,), ("embed",), init="zeros")


def swiglu_defs(d: int, ff: int, dtype=jnp.bfloat16):
    return {
        "wi_gate": ParamDef((d, ff), ("embed", "ff"), dtype),
        "wi_up": ParamDef((d, ff), ("embed", "ff"), dtype),
        "wo": ParamDef((ff, d), ("ff", "embed"), dtype),
    }


def swiglu(params, x):
    g = jnp.einsum("...d,df->...f", x, params["wi_gate"])
    u = jnp.einsum("...d,df->...f", x, params["wi_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["wo"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float32) / dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D] (D even), positions: broadcastable to [..., S]."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))          # [D/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]                   # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA projections
# ---------------------------------------------------------------------------

def gqa_proj_defs(d: int, n_heads: int, n_kv: int, hd: int, bias: bool,
                  dtype=jnp.bfloat16):
    defs = {
        "wq": ParamDef((d, n_heads, hd), ("embed", "heads", None), dtype),
        "wk": ParamDef((d, n_kv, hd), ("embed", "kv_heads", None), dtype),
        "wv": ParamDef((d, n_kv, hd), ("embed", "kv_heads", None), dtype),
        "wo": ParamDef((n_heads, hd, d), ("heads", None, "embed"), dtype),
    }
    if bias:
        defs["bq"] = ParamDef((n_heads, hd), ("heads", None), dtype, init="zeros")
        defs["bk"] = ParamDef((n_kv, hd), ("kv_heads", None), dtype, init="zeros")
        defs["bv"] = ParamDef((n_kv, hd), ("kv_heads", None), dtype, init="zeros")
    return defs


def qkv(params, x):
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, params["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return q, k, v


def out_proj(params, attn):  # attn [B,S,H,hd]
    return jnp.einsum("bshe,hed->bsd", attn, params["wo"])


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------

def _gqa_scores(q, k, scale):
    """q [B,Sq,KV,G,hd], k [B,Sk,KV,hd] -> [B,KV,G,Sq,Sk] (f32)."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                      preferred_element_type=jnp.float32) * scale


def _gqa_out(p, v):
    """p [B,KV,G,Sq,Sk] (f32), v [B,Sk,KV,hd] -> [B,Sq,KV,G,hd]."""
    return jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)


def _attend(q, k, v, mask, scale):
    """One attention block. mask broadcastable to [B,1,1,Sq,Sk] (True=keep)."""
    s = _gqa_scores(q, k, scale)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p, v)


def causal_attention(q, k, v, *, n_kv: int, window: int = 0,
                     q_chunk: int = 1024, q_offset=0):
    """Blockwise causal (optionally sliding-window) self-attention.

    q: [B,S,H,hd]; k,v: [B,Sk,KV,hd]. q_offset: absolute position of q[0]
    (static int or traced scalar). Returns [B,S,H,hd].
    """
    B, S, H, hd = q.shape
    Sk = k.shape[1]
    hv = v.shape[-1]
    G = H // n_kv
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(B, S, n_kv, G, hd)

    if S <= q_chunk:
        qpos = q_offset + jnp.arange(S)
        kpos = jnp.arange(Sk)
        mask = kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > (qpos[:, None] - window)
        out = _attend(qg, k, v, mask[None, None, None], scale)
        return out.reshape(B, S, H, hv)

    assert S % q_chunk == 0, (S, q_chunk)
    n = S // q_chunk
    qc = qg.reshape(B, n, q_chunk, n_kv, G, hd).transpose(1, 0, 2, 3, 4, 5)

    kv_span = 0
    if window:
        # each q-chunk only needs the last (window + q_chunk) keys
        kv_span = min(Sk, window + q_chunk)

    def body(_, args):
        i, qi = args
        cs = q_offset + i * q_chunk               # abs position of chunk start
        qpos = cs + jnp.arange(q_chunk)
        if window and kv_span < Sk:
            start = jnp.clip(cs - window, 0, Sk - kv_span)
            ki = jax.lax.dynamic_slice_in_dim(k, start, kv_span, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, start, kv_span, axis=1)
            kpos = start + jnp.arange(kv_span)
        else:
            ki, vi = k, v
            kpos = jnp.arange(Sk)
        mask = kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > (qpos[:, None] - window)
        out = _attend(qi, ki, vi, mask[None, None, None], scale)
        return None, out

    body = jax.checkpoint(body, prevent_cse=False)
    _, outs = jax.lax.scan(body, None, (jnp.arange(n), qc))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, hv)
    return out


def cross_attention(q, k, v, *, n_kv: int):
    """Full (non-causal) attention to a fixed memory. q [B,S,H,hd]."""
    B, S, H, hd = q.shape
    G = H // n_kv
    qg = q.reshape(B, S, n_kv, G, hd)
    out = _attend(qg, k, v, jnp.bool_(True), 1.0 / np.sqrt(hd))
    return out.reshape(B, S, H, v.shape[-1])


def decode_attention(q, k_cache, v_cache, lengths, *, n_kv: int,
                     window: int = 0, ring: bool = False):
    """Single-token decode attention against a KV cache.

    q: [B,H,hd] (the one new token, rope already applied)
    k_cache/v_cache: [B,S,KV,hd]; lengths: [B] number of valid tokens
    (including the one just written). ring=True means the cache is a
    ring-buffer of size `window` (slot = pos % window) — any slot < min(len,
    S) is valid and order is irrelevant to softmax.
    """
    B, H, hd = q.shape
    S = k_cache.shape[1]
    G = H // n_kv
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(B, 1, n_kv, G, hd)
    slots = jnp.arange(S)
    if ring:
        valid = slots[None, :] < jnp.minimum(lengths, S)[:, None]
    else:
        valid = slots[None, :] < lengths[:, None]
        if window:
            valid &= slots[None, :] >= (lengths[:, None] - window)
    mask = valid[:, None, None, None, :]              # [B,1,1,1,S]
    out = _attend(qg, k_cache, v_cache, mask, scale)
    return out.reshape(B, H, v_cache.shape[-1])


__all__ = [
    "rms_norm", "rms_norm_def", "swiglu", "swiglu_defs", "apply_rope",
    "rope_freqs", "gqa_proj_defs", "qkv", "out_proj", "causal_attention",
    "cross_attention", "decode_attention", "NEG_INF",
]
