"""Multi-head Latent Attention (DeepSeek-V2).

Prefill/train use the *expanded* form (per-head K/V materialized, blockwise
causal attention). Decode uses the *absorbed* form: the cache stores only the
compressed latent c_kv [B,S,lora] + shared rope key [B,S,rope], and the
W_uk / W_uv up-projections are folded into the query/output sides — the
memory win that makes MLA attractive for 32k-decode serving.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MLAConfig
from repro.models.layers import apply_rope, causal_attention, rms_norm_def, rms_norm
from repro.models.pdefs import ParamDef


def mla_defs(d: int, n_heads: int, m: MLAConfig, dtype=jnp.bfloat16):
    qd = m.qk_nope_dim + m.qk_rope_dim
    defs = {
        "wq": ParamDef((d, n_heads, qd), ("embed", "heads", None), dtype),
        "w_dkv": ParamDef((d, m.kv_lora_rank), ("embed", "lora"), dtype),
        "kv_norm": rms_norm_def(m.kv_lora_rank),
        "w_kr": ParamDef((d, m.qk_rope_dim), ("embed", None), dtype),
        "w_uk": ParamDef((m.kv_lora_rank, n_heads, m.qk_nope_dim),
                         ("lora", "heads", None), dtype),
        "w_uv": ParamDef((m.kv_lora_rank, n_heads, m.v_head_dim),
                         ("lora", "heads", None), dtype),
        "wo": ParamDef((n_heads, m.v_head_dim, d), ("heads", None, "embed"), dtype),
    }
    return defs


def _project_q(params, x, m: MLAConfig, positions, theta):
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    q_nope = q[..., : m.qk_nope_dim]
    q_rope = apply_rope(q[..., m.qk_nope_dim:], positions, theta)
    return q_nope, q_rope


def mla_latents(params, x, m: MLAConfig, positions, theta, eps):
    """Compressed latents (what the decode cache stores)."""
    c_kv = jnp.einsum("bsd,dl->bsl", x, params["w_dkv"])
    c_kv = rms_norm(c_kv, params["kv_norm"], eps)
    k_rope = jnp.einsum("bsd,de->bse", x, params["w_kr"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions, theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_attention_prefill(params, x, m: MLAConfig, *, positions, theta, eps,
                          q_chunk=1024):
    """Expanded-form causal MLA. x [B,S,D] -> [B,S,D]."""
    B, S, D = x.shape
    H = params["wq"].shape[1]
    q_nope, q_rope = _project_q(params, x, m, positions, theta)
    c_kv, k_rope = mla_latents(params, x, m, positions, theta, eps)
    k_nope = jnp.einsum("bsl,lhe->bshe", c_kv, params["w_uk"])
    v = jnp.einsum("bsl,lhe->bshe", c_kv, params["w_uv"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)              # [B,S,H,qd]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, m.qk_rope_dim))],
        axis=-1)
    out = causal_attention(q, k, v, n_kv=H, q_chunk=q_chunk)
    out = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    return out, (c_kv, k_rope)


def mla_attention_decode(params, x1, m: MLAConfig, cache_ckv, cache_kr,
                         lengths, *, positions, theta, eps):
    """Absorbed-form decode. x1 [B,1,D]; caches already contain this token.

    cache_ckv [B,S,lora], cache_kr [B,S,rope]; lengths [B].
    """
    B = x1.shape[0]
    q_nope, q_rope = _project_q(params, x1, m, positions[:, None], theta)
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]                 # [B,H,*]
    # absorb W_uk into q: q_lat [B,H,lora]
    q_lat = jnp.einsum("bhe,lhe->bhl", q_nope, params["w_uk"])
    scale = 1.0 / np.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    s = (jnp.einsum("bhl,bsl->bhs", q_lat.astype(jnp.float32),
                    cache_ckv.astype(jnp.float32))
         + jnp.einsum("bhe,bse->bhs", q_rope.astype(jnp.float32),
                      cache_kr.astype(jnp.float32))) * scale
    valid = jnp.arange(cache_ckv.shape[1])[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhs,bsl->bhl", p.astype(cache_ckv.dtype), cache_ckv)
    ctx = jnp.einsum("bhl,lhe->bhe", ctx_lat, params["w_uv"])   # [B,H,v]
    out = jnp.einsum("bhe,hed->bd", ctx, params["wo"])
    return out[:, None, :]                                      # [B,1,D]


__all__ = ["mla_defs", "mla_latents", "mla_attention_prefill", "mla_attention_decode"]
