"""Cloud-side GraphRAG: entity graph + communities over the full corpus.

Nodes are entities (content words scored by tf-idf-like salience), edges are
chunk co-occurrences, communities come from synchronous label propagation.
Retrieval is community-anchored: query keywords are matched to entities
(embedding cosine > 0.5, as in the paper), communities are ranked by matched
entities, and the top communities contribute their most relevant chunks —
the "strong intra-community alignment" that EACO-RAG exploits when it ships
community chunk subsets to edge nodes.
"""
from __future__ import annotations

import math
from collections import Counter, defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.retrieval.embedder import content_words, embed, embed_batch
from repro.retrieval.store import Chunk


@dataclass
class Community:
    cid: int
    entities: List[str]
    chunk_ids: List[int]
    summary_keywords: List[str] = field(default_factory=list)


class KnowledgeGraph:
    def __init__(self, min_entity_count: int = 2, max_entities: int = 4000,
                 max_df: float = 0.2, seed: int = 0):
        self.min_entity_count = min_entity_count
        self.max_entities = max_entities
        self.max_df = max_df          # drop corpus-gluing ubiquitous terms
        self.seed = seed
        self.chunks: List[Chunk] = []
        self.entities: List[str] = []
        self.entity_idx: Dict[str, int] = {}
        self.entity_emb = np.zeros((0, 384), np.float32)
        self.chunk_entities: List[Set[int]] = []
        self.adj: Dict[int, Counter] = defaultdict(Counter)
        self.labels: np.ndarray = np.zeros(0, np.int64)
        self.communities: Dict[int, Community] = {}

    # ---- construction --------------------------------------------------------
    def build(self, chunks: Sequence[Chunk]) -> "KnowledgeGraph":
        self.chunks = list(chunks)
        counts: Counter = Counter()
        per_chunk_words: List[List[str]] = []
        for c in self.chunks:
            ws = content_words(c.text)
            per_chunk_words.append(ws)
            counts.update(set(ws))
        df_cap = max(int(self.max_df * len(self.chunks)),
                     self.min_entity_count + 1)
        # rank by (count desc, word) — most_common breaks count ties by
        # Counter insertion order, i.e. string-hash order, which made the
        # graph (and community coverage) vary with PYTHONHASHSEED
        ranked = sorted(counts.items(),
                        key=lambda kv: (-kv[1], kv[0]))[:self.max_entities]
        vocab = [w for w, n in ranked
                 if self.min_entity_count <= n <= df_cap]
        self.entities = vocab
        self.entity_idx = {w: i for i, w in enumerate(vocab)}
        self.entity_emb = embed_batch(vocab)

        self.chunk_entities = []
        for ws in per_chunk_words:
            es = {self.entity_idx[w] for w in ws if w in self.entity_idx}
            self.chunk_entities.append(es)
            es_l = sorted(es)
            for i, a in enumerate(es_l):
                for b in es_l[i + 1:]:
                    self.adj[a][b] += 1
                    self.adj[b][a] += 1
        self._label_propagation()
        self._build_communities()
        return self

    def _label_propagation(self, iters: int = 12):
        n = len(self.entities)
        labels = np.arange(n, dtype=np.int64)
        rng = np.random.default_rng(self.seed)
        order = np.arange(n)
        for _ in range(iters):
            rng.shuffle(order)
            changed = 0
            for i in order:
                if not self.adj[i]:
                    continue
                tally: Counter = Counter()
                for j, w in self.adj[i].items():
                    tally[labels[j]] += w
                best = max(tally.items(), key=lambda kv: (kv[1], -kv[0]))[0]
                if best != labels[i]:
                    labels[i] = best
                    changed += 1
            if changed == 0:
                break
        self.labels = labels

    def _build_communities(self):
        groups: Dict[int, List[int]] = defaultdict(list)
        for i, l in enumerate(self.labels):
            groups[int(l)].append(i)
        self.communities = {}
        for cid, (_, ents) in enumerate(sorted(groups.items())):
            ent_set = set(ents)
            chunk_ids = [ci for ci, es in enumerate(self.chunk_entities)
                         if es & ent_set]
            kw = [self.entities[e] for e in ents[:16]]
            self.communities[cid] = Community(cid, [self.entities[e] for e in ents],
                                              chunk_ids, kw)
        self._entity_to_comm = {}
        cid_of = {}
        for cid, com in self.communities.items():
            for e in com.entities:
                cid_of[e] = cid
        self._entity_to_comm = cid_of

    # ---- query-side ------------------------------------------------------------
    def match_entities(self, query: str, sim_threshold: float = 0.5,
                       max_matches: int = 16) -> List[str]:
        """Query keywords -> graph entities with cosine > threshold."""
        if not self.entities:
            return []
        qws = content_words(query)
        out: List[str] = []
        seen = set()
        for w in qws:
            if w in self.entity_idx and w not in seen:
                out.append(w)           # exact match
                seen.add(w)
        if len(out) < max_matches and qws:
            qe = embed_batch(qws)       # [Q,384]
            sims = qe @ self.entity_emb.T
            for qi in range(sims.shape[0]):
                j = int(np.argmax(sims[qi]))
                if sims[qi, j] > sim_threshold:
                    e = self.entities[j]
                    if e not in seen:
                        out.append(e)
                        seen.add(e)
        return out[:max_matches]

    def rank_communities(self, query: str, top_k: int = 3) -> List[Community]:
        matched = self.match_entities(query)
        tally: Counter = Counter()
        for e in matched:
            cid = self._entity_to_comm.get(e)
            if cid is not None:
                tally[cid] += 1
        return [self.communities[cid] for cid, _ in tally.most_common(top_k)]

    def retrieve(self, query: str, k: int = 5,
                 top_communities: int = 3) -> List[Tuple[Chunk, float]]:
        """Community-anchored retrieval (cloud GraphRAG path)."""
        comms = self.rank_communities(query, top_communities)
        cand_ids: List[int] = []
        seen = set()
        for com in comms:
            for ci in com.chunk_ids:
                if ci not in seen:
                    cand_ids.append(ci)
                    seen.add(ci)
        if not cand_ids:
            cand_ids = list(range(len(self.chunks)))
        q = embed(query)
        cand_emb = embed_batch([self.chunks[i].text for i in cand_ids])
        sims = cand_emb @ q
        order = np.argsort(-sims)[:k]
        return [(self.chunks[cand_ids[int(i)]], float(sims[int(i)]))
                for i in order]

    def community_chunks_for_queries(self, queries: Sequence[str],
                                     top_k_communities: int = 3,
                                     max_chunks: int = 500) -> List[Chunk]:
        """Adaptive-update extraction: chunks from the communities that best
        match recent queries (paper §5: up to 500 chunks per update)."""
        tally: Counter = Counter()
        for q in queries:
            for com in self.rank_communities(q, top_k_communities):
                tally[com.cid] += 1
        out: List[Chunk] = []
        seen = set()
        for cid, _ in tally.most_common():
            for ci in self.communities[cid].chunk_ids:
                if ci not in seen:
                    out.append(self.chunks[ci])
                    seen.add(ci)
                if len(out) >= max_chunks:
                    return out
        return out


__all__ = ["KnowledgeGraph", "Community"]
