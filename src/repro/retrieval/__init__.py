from repro.retrieval.embedder import (
    DIM, content_words, cosine, embed, embed_batch, tokenize,
)
from repro.retrieval.graph_rag import Community, KnowledgeGraph
from repro.retrieval.store import Chunk, VectorStore, make_chunk

__all__ = [
    "DIM", "embed", "embed_batch", "cosine", "tokenize", "content_words",
    "Chunk", "VectorStore", "make_chunk", "KnowledgeGraph", "Community",
]
