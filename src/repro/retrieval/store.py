"""Edge vector store: fixed-capacity FIFO chunk store with JAX cosine top-k.

The retrieval scoring (embedding matrix x query) is the RAG hot loop;
``repro.kernels.retrieval_topk`` provides the fused Pallas kernel, used when
``use_pallas=True`` (validated in interpret mode on CPU).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.retrieval.embedder import DIM, content_words, embed, embed_batch


@dataclass
class Chunk:
    text: str
    keywords: Tuple[str, ...]
    source: str = ""
    topic: str = ""
    ts: float = 0.0               # ingestion timestamp (for FIFO/audit)


from functools import partial


@partial(jax.jit, static_argnames=("k",))
def _topk_scores(emb: jax.Array, q: jax.Array, k: int = 5):
    scores = emb @ q
    return jax.lax.top_k(scores, k)


class VectorStore:
    """FIFO chunk store. Capacity mirrors the paper's 1000-chunk edge repo."""

    def __init__(self, capacity: int = 1000, use_pallas: bool = False):
        self.capacity = capacity
        self.use_pallas = use_pallas
        self.chunks: List[Chunk] = []
        # knowledge epoch this store was last synced to (stamped by the
        # cloud updater on every successful push; monotone). A store whose
        # epoch trails the updater's latest is serving STALE knowledge —
        # answers from it carry a stale_epoch flag until anti-entropy
        # reconciliation catches it up.
        self.epoch = 0
        self._emb = np.zeros((0, DIM), np.float32)
        self._kw_set: set = set()
        self._kw_dirty = True

    # ---- ingestion (FIFO) ----------------------------------------------------
    def add(self, chunks: Sequence[Chunk]) -> int:
        """Append chunks; evict oldest beyond capacity. Returns #evicted."""
        if not chunks:
            return 0
        new_emb = embed_batch([c.text for c in chunks])
        self.chunks.extend(chunks)
        self._emb = np.concatenate([self._emb, new_emb]) if len(self._emb) else new_emb
        evicted = 0
        if len(self.chunks) > self.capacity:
            evicted = len(self.chunks) - self.capacity
            self.chunks = self.chunks[evicted:]
            self._emb = self._emb[evicted:]
        self._kw_dirty = True
        return evicted

    def __len__(self) -> int:
        return len(self.chunks)

    # ---- keyword index ---------------------------------------------------------
    @property
    def keyword_set(self) -> set:
        if self._kw_dirty:
            self._kw_set = set()
            for c in self.chunks:
                self._kw_set.update(c.keywords)
            self._kw_dirty = False
        return self._kw_set

    def overlap_ratio(self, query_keywords: Sequence[str]) -> float:
        """Fraction of query keywords present in this store (paper §5)."""
        if not query_keywords:
            return 0.0
        ks = self.keyword_set
        return sum(1 for k in query_keywords if k in ks) / len(query_keywords)

    # ---- retrieval -------------------------------------------------------------
    def search(self, query: str, k: int = 5) -> List[Tuple[Chunk, float]]:
        if not self.chunks:
            return []
        k = min(k, len(self.chunks))
        q = jnp.asarray(embed(query))
        emb = jnp.asarray(self._emb)
        if self.use_pallas:
            from repro.kernels.retrieval_topk import ops as rt_ops
            vals, idx = rt_ops.retrieval_topk(emb, q, k)
        else:
            vals, idx = _topk_scores(emb, q, k)
        vals = np.asarray(vals)
        idx = np.asarray(idx)
        return [(self.chunks[int(i)], float(v)) for v, i in zip(vals, idx)]


def make_chunk(text: str, source: str = "", topic: str = "",
               ts: float = 0.0, max_keywords: int = 64) -> Chunk:
    kws = tuple(sorted(set(content_words(text)))[:max_keywords])
    return Chunk(text=text, keywords=kws, source=source, topic=topic, ts=ts)


__all__ = ["Chunk", "VectorStore", "make_chunk"]
