"""Deterministic feature-hashing text embedder (offline stand-in for
'all-MiniLM-L6-v2' — DESIGN.md §9.2).

Word unigrams + bigrams + character trigrams are hashed into a 384-d space
with signed buckets, then L2-normalized, so cosine similarity behaves like a
(bag-of-features) semantic similarity. Deterministic across runs/processes.
"""
from __future__ import annotations

import hashlib
import re
from typing import Iterable, List, Sequence

import numpy as np

DIM = 384
_TOKEN_RE = re.compile(r"[a-z0-9']+")

STOPWORDS = frozenset("""
a an and are as at be by for from has have he her his i if in into is it its
me my of on or our she so that the their them they this to was we were what
when where which who will with you your how why does did do done
""".split())


def tokenize(text: str) -> List[str]:
    return _TOKEN_RE.findall(text.lower())


def content_words(text: str) -> List[str]:
    return [t for t in tokenize(text) if t not in STOPWORDS and len(t) > 2]


def _hash(feature: str) -> int:
    return int.from_bytes(hashlib.md5(feature.encode()).digest()[:8], "little")


def _features(text: str) -> Iterable[str]:
    toks = tokenize(text)
    for t in toks:
        if t in STOPWORDS:
            continue
        yield "u:" + t
        for i in range(len(t) - 2):
            yield "c:" + t[i : i + 3]
    for a, b in zip(toks, toks[1:]):
        yield "b:" + a + "_" + b


def embed(text: str) -> np.ndarray:
    v = np.zeros(DIM, np.float32)
    for f in _features(text):
        h = _hash(f)
        v[h % DIM] += 1.0 if (h >> 16) & 1 else -1.0
    n = np.linalg.norm(v)
    return v / n if n > 0 else v


def embed_batch(texts: Sequence[str]) -> np.ndarray:
    if not texts:
        return np.zeros((0, DIM), np.float32)
    return np.stack([embed(t) for t in texts])


def cosine(a: np.ndarray, b: np.ndarray) -> float:
    return float(np.dot(a, b))


__all__ = ["DIM", "embed", "embed_batch", "cosine", "tokenize",
           "content_words", "STOPWORDS"]
