"""Virtual clock shared by the workload generator, the tier scheduler and
the cluster simulator.

The serving stack times everything through an injectable ``clock`` — any
zero-argument callable returning seconds as a float. Live deployments pass
``time.perf_counter`` (the default everywhere); simulations pass a
:class:`VirtualClock` so arrivals, queue waits, engine service time and
network transit compose on ONE logical timeline instead of mixing event
time with wall time (the bug this class exists to fix: a scheduler fed
logical ``now=`` values must never subtract them from ``perf_counter``).

A :class:`VirtualClock` only moves when someone calls :meth:`advance` —
the simulator is the sole driver, advancing by arrival gaps and by the
(modeled or measured) engine service time per scheduling round.
"""
from __future__ import annotations

import time


class VirtualClock:
    """Monotonic logical clock. Callable, so it drops in anywhere a
    ``time.perf_counter``-style clock is expected."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"clock cannot run backwards (dt={dt})")
        self._t += float(dt)
        return self._t

    def __call__(self) -> float:
        return self._t

    def __repr__(self) -> str:
        return f"VirtualClock(t={self._t:.6f})"


#: The wall clock every component defaults to outside simulations.
WALL_CLOCK = time.perf_counter

__all__ = ["VirtualClock", "WALL_CLOCK"]
