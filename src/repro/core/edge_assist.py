"""Edge-assisted collaborative retrieval (paper §3.3 / §5, contribution C1).

When the local store's coverage is insufficient, retrieval extends to *other*
edge nodes: the query's keywords are compared against each edge's keyword
index and the edge with the highest overlap ratio serves the retrieval.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.retrieval.embedder import content_words, embed, embed_batch
from repro.retrieval.store import Chunk, VectorStore


def query_keywords(query: str, vocab: Optional[Sequence[str]] = None,
                   sim_threshold: float = 0.5) -> List[str]:
    """Valid query keywords: content words, plus embedding-matched vocabulary
    terms above the 50% similarity threshold (paper §5)."""
    kws = content_words(query)
    if vocab:
        import numpy as np
        missing = [w for w in vocab if w not in kws]
        if missing and kws:
            qe = embed(query)
            ve = embed_batch(missing)
            sims = ve @ qe
            for i, s in enumerate(sims):
                if s > sim_threshold:
                    kws.append(missing[i])
    return kws


@dataclass
class EdgeSelection:
    edge_id: str
    overlap: float
    ranking: List[Tuple[str, float]]


def select_edge(stores: Dict[str, VectorStore], query: str,
                local_edge: Optional[str] = None) -> EdgeSelection:
    """Pick the edge whose keyword index best covers the query (ties favor
    the local edge to avoid inter-edge hops)."""
    kws = query_keywords(query)
    ranking = sorted(
        ((eid, s.overlap_ratio(kws)) for eid, s in stores.items()),
        key=lambda kv: (-kv[1], kv[0] != local_edge),
    )
    best_id, best_ov = ranking[0] if ranking else ("", 0.0)
    return EdgeSelection(best_id, best_ov, ranking)


def edge_assisted_search(stores: Dict[str, VectorStore], query: str,
                         k: int = 5, local_edge: Optional[str] = None
                         ) -> Tuple[List[Tuple[Chunk, float]], EdgeSelection]:
    sel = select_edge(stores, query, local_edge)
    if not sel.edge_id:
        return [], sel
    return stores[sel.edge_id].search(query, k), sel


__all__ = ["query_keywords", "select_edge", "edge_assisted_search",
           "EdgeSelection"]
