"""Gaussian-process regression in pure JAX for the collaborative gate.

Fixed-size ring buffers keep everything jit-able: unused slots are masked out
of the kernel matrix (masked rows reduce to identity rows, so their alpha
contribution is exactly zero). Posterior via Cholesky with jitter.

The covariance matrix K(X,X) is the compute hot-spot of the gate at scale;
``repro.kernels.rbf`` provides the Pallas TPU kernel for it (ops.rbf_matrix),
used when ``use_pallas=True``.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class GPHypers:
    lengthscale: float = 1.0
    signal_var: float = 1.0
    noise_var: float = 0.05


class GPState(NamedTuple):
    X: jax.Array          # [N, D] observation inputs (ring buffer)
    y: jax.Array          # [N]
    count: jax.Array      # scalar int32: total observations ever added


def gp_init(capacity: int, dim: int) -> GPState:
    return GPState(
        X=jnp.zeros((capacity, dim), jnp.float32),
        y=jnp.zeros((capacity,), jnp.float32),
        count=jnp.zeros((), jnp.int32),
    )


@jax.jit
def gp_add(state: GPState, x: jax.Array, y: jax.Array) -> GPState:
    """FIFO-append one observation (ring overwrite when full)."""
    idx = state.count % state.X.shape[0]
    return GPState(
        X=state.X.at[idx].set(x.astype(jnp.float32)),
        y=state.y.at[idx].set(jnp.asarray(y, jnp.float32)),
        count=state.count + 1,
    )


def sq_dists(X1: jax.Array, X2: jax.Array) -> jax.Array:
    n1 = jnp.sum(X1 * X1, axis=-1, keepdims=True)
    n2 = jnp.sum(X2 * X2, axis=-1, keepdims=True)
    d = n1 + n2.T - 2.0 * X1 @ X2.T
    return jnp.maximum(d, 0.0)


def rbf(X1: jax.Array, X2: jax.Array, h: GPHypers) -> jax.Array:
    return h.signal_var * jnp.exp(-0.5 * sq_dists(X1, X2) / (h.lengthscale ** 2))


def _mask(state: GPState) -> jax.Array:
    n = state.X.shape[0]
    return (jnp.arange(n) < state.count).astype(jnp.float32)


@partial(jax.jit, static_argnames=("use_pallas",))
def gp_posterior(state: GPState, Xq: jax.Array,
                 lengthscale: jax.Array, signal_var: jax.Array,
                 noise_var: jax.Array, use_pallas: bool = False
                 ) -> Tuple[jax.Array, jax.Array]:
    """Posterior mean/std at query points Xq [Q, D] -> ([Q], [Q])."""
    h = GPHypers(lengthscale, signal_var, noise_var)
    m = _mask(state)
    if use_pallas:
        from repro.kernels.rbf import ops as rbf_ops
        K = rbf_ops.rbf_matrix(state.X, state.X, lengthscale, signal_var)
        Ks = rbf_ops.rbf_matrix(state.X, Xq, lengthscale, signal_var)
    else:
        K = rbf(state.X, state.X, h)
        Ks = rbf(state.X, Xq, h)
    K = K * m[:, None] * m[None, :]
    K = K + jnp.diag(noise_var * m + (1.0 - m) * 1.0 + 1e-6)
    Ks = Ks * m[:, None]                          # [N, Q]
    L = jnp.linalg.cholesky(K)
    alpha = jax.scipy.linalg.cho_solve((L, True), state.y * m)
    mean = Ks.T @ alpha
    v = jax.scipy.linalg.solve_triangular(L, Ks, lower=True)
    var = signal_var - jnp.sum(v * v, axis=0)
    std = jnp.sqrt(jnp.maximum(var, 1e-9))
    # prior fallback before any data
    no_data = state.count == 0
    mean = jnp.where(no_data, jnp.zeros_like(mean), mean)
    std = jnp.where(no_data, jnp.full_like(std, jnp.sqrt(signal_var)), std)
    return mean, std


def gp_log_marginal(state: GPState, h: GPHypers) -> jax.Array:
    """Masked log marginal likelihood (for hyperparameter grid refresh)."""
    m = _mask(state)
    K = rbf(state.X, state.X, h) * m[:, None] * m[None, :]
    K = K + jnp.diag(h.noise_var * m + (1.0 - m) * 1.0 + 1e-6)
    L = jnp.linalg.cholesky(K)
    ym = state.y * m
    alpha = jax.scipy.linalg.cho_solve((L, True), ym)
    ll = -0.5 * ym @ alpha
    ll -= jnp.sum(jnp.log(jnp.diagonal(L)) * m)   # masked slots: log(1)=0
    ll -= 0.5 * jnp.sum(m) * jnp.log(2 * jnp.pi)
    return ll


def refresh_lengthscale(state: GPState, h: GPHypers,
                        grid=(0.25, 0.5, 1.0, 2.0, 4.0)) -> GPHypers:
    """Pick the grid lengthscale maximizing marginal likelihood."""
    lls = jnp.stack([gp_log_marginal(state, replace(h, lengthscale=float(g)))
                     for g in grid])
    best = int(jnp.argmax(lls))
    return replace(h, lengthscale=float(grid[best]))


__all__ = ["GPHypers", "GPState", "gp_init", "gp_add", "gp_posterior",
           "rbf", "sq_dists", "gp_log_marginal", "refresh_lengthscale"]
