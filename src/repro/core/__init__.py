"""The paper's primary contribution: EACO-RAG core (gating, SafeOBO, GPs,
adaptive knowledge update, edge-assisted retrieval, cost model)."""
from repro.core.clock import WALL_CLOCK, VirtualClock
from repro.core.cost_model import (
    PAPER_CLOUD, PAPER_EDGE, TPU_CLOUD, TPU_EDGE, CostWeights, TierSpec,
    generation_delay, inference_tflops, modeled_decode_round_s,
    modeled_prefill_s, time_cost_tflops, total_cost,
)
from repro.core.edge_assist import (
    EdgeSelection, edge_assisted_search, query_keywords, select_edge,
)
from repro.core.gating import (
    CONTEXT_DIM, PAPER_ARMS, Arm, CollaborativeGate, Decision, QueryContext,
    context_features,
)
from repro.core.gp import GPHypers, GPState, gp_add, gp_init, gp_posterior
from repro.core.knowledge import (
    AdaptiveKnowledgeUpdater, KnowledgeUpdateConfig, UpdateStats,
)
from repro.core.safeobo import SafeOBO, SafeOBOConfig

__all__ = [
    "VirtualClock", "WALL_CLOCK",
    "TierSpec", "CostWeights", "PAPER_EDGE", "PAPER_CLOUD", "TPU_EDGE",
    "TPU_CLOUD", "inference_tflops", "generation_delay", "time_cost_tflops",
    "total_cost", "modeled_prefill_s", "modeled_decode_round_s",
    "EdgeSelection", "edge_assisted_search", "query_keywords",
    "select_edge", "Arm", "PAPER_ARMS", "QueryContext", "context_features",
    "CONTEXT_DIM", "CollaborativeGate", "Decision", "GPHypers", "GPState",
    "gp_add", "gp_init", "gp_posterior", "SafeOBO", "SafeOBOConfig",
    "AdaptiveKnowledgeUpdater", "KnowledgeUpdateConfig", "UpdateStats",
]
