"""EACO-RAG cost model (paper §4.1, Tables 1 & 3).

Total cost  u_t = δ1·u_r + δ2·u_d  with
  u_r: resource cost in TFLOPs from token counts (Pope et al.: ~2·N FLOPs
       per token for inference of an N-parameter dense model),
  u_d: time cost, *scaled into TFLOPs* by the peak throughput of the tier
       that served the query — the paper's unit-unification trick, which
       makes edge time cheap and cloud time expensive.

Fidelity vs deployment: the paper normalizes with FP64 GPU peaks (Table 3).
We keep that table to reproduce the paper's arithmetic and add a TPU v5e
table (bf16) as the deployment default (DESIGN.md §3).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

# Table 3 (FP64 TFLOPS) — retained for paper-faithful reproduction
GPU_PEAK_TFLOPS_FP64: Dict[str, float] = {
    "rtx4090": 1.29,
    "p100": 4.70,
    "v100": 7.80,
    "a100": 9.70,
    "h100": 60.00,
}

# TPU deployment table (bf16 TFLOPS per chip)
TPU_PEAK_TFLOPS_BF16: Dict[str, float] = {
    "v5e": 197.0,
    "v5e_pod_slice_8": 8 * 197.0,
}


@dataclass(frozen=True)
class TierSpec:
    """One serving tier (edge node or cloud)."""
    name: str
    model_params_b: float          # model size in billions
    peak_tflops: float             # normalization peak for time cost
    tokens_per_s: float            # generation throughput
    prefill_tokens_per_s: float    # prompt-processing throughput
    base_delay_s: float            # network + loading latency


# Paper prototype: edge = RTX4090 + 3B SLM; cloud = "8xH100" + 72B LLM.
PAPER_EDGE = TierSpec("edge-3b", 3.0, GPU_PEAK_TFLOPS_FP64["rtx4090"],
                      tokens_per_s=90.0, prefill_tokens_per_s=7000.0,
                      base_delay_s=0.02)

# per-(retrieval, generation) retrieval-path latency: graph queries pay a
# community-search cost (larger when the context must ship to the edge)
RETRIEVAL_DELAY_S = {("none", "local"): 0.0, ("edge", "local"): 0.02,
                     ("graph", "local"): 0.9, ("graph", "cloud"): 0.2}
PAPER_CLOUD = TierSpec("cloud-72b", 72.0, 8 * GPU_PEAK_TFLOPS_FP64["h100"],
                       tokens_per_s=280.0, prefill_tokens_per_s=24000.0,
                       base_delay_s=0.30)

# TPU deployment tiers (qwen2-0.5b .. qwen2-72b from the assigned configs)
TPU_EDGE = TierSpec("edge-v5e", 3.0, TPU_PEAK_TFLOPS_BF16["v5e"],
                    tokens_per_s=120.0, prefill_tokens_per_s=8000.0,
                    base_delay_s=0.02)
TPU_CLOUD = TierSpec("cloud-v5e-pod", 72.0, TPU_PEAK_TFLOPS_BF16["v5e_pod_slice_8"],
                     tokens_per_s=200.0, prefill_tokens_per_s=30000.0,
                     base_delay_s=0.30)


def inference_tflops(model_params_b: float, in_tokens: float,
                     out_tokens: float) -> float:
    """~2·N FLOPs per token (Pope et al. 2023), in TFLOPs."""
    return 2.0 * model_params_b * 1e9 * (in_tokens + out_tokens) / 1e12


def generation_delay(tier: TierSpec, in_tokens: float, out_tokens: float,
                     network_delay_s: float) -> float:
    return (tier.base_delay_s + network_delay_s
            + in_tokens / tier.prefill_tokens_per_s
            + out_tokens / tier.tokens_per_s)


def time_cost_tflops(tier: TierSpec, delay_s: float) -> float:
    """The paper's unit unification: seconds x tier peak TFLOP/s."""
    return delay_s * tier.peak_tflops


def modeled_prefill_s(tier: TierSpec, tokens: float) -> float:
    """Virtual-clock service time for prefilling ``tokens`` prompt tokens
    on this tier (used when real engines run on a logical timeline: the
    engine supplies the true token counts, the tier spec the rate)."""
    return max(float(tokens), 0.0) / tier.prefill_tokens_per_s


def modeled_decode_round_s(tier: TierSpec) -> float:
    """Virtual-clock duration of one fused decode step on this tier (every
    resident request emits one token per step, so a round costs one
    token-time regardless of batch occupancy)."""
    return 1.0 / tier.tokens_per_s


def modeled_mixed_step_s(tier: TierSpec, chunk_tokens: float) -> float:
    """Virtual-clock duration of one FUSED chunked-prefill + decode step:
    a decode round for the resident batch plus ``chunk_tokens`` prompt
    tokens of one request's bounded prefill chunk, priced at the tier's
    prefill rate. This is how ``pump_engines`` and the serving benches
    charge token-budget steps on the logical timeline — a step's cost is
    additive in its decode round and its chunk, so summing per-step costs
    equals ``modeled_prefill_s`` over the chunked tokens plus
    ``modeled_decode_round_s`` over the rounds (the delta formula the
    simulator already uses stays exact under chunking)."""
    return (modeled_decode_round_s(tier)
            + max(float(chunk_tokens), 0.0) / tier.prefill_tokens_per_s)


@dataclass(frozen=True)
class CostWeights:
    """delta2 default 0.1 reproduces the paper's Table 4 arithmetic
    (e.g. 72B+GraphRAG ~ 690 u_r + 0.1*(1.0s x 480 TFLOP/s) ~ 740)."""
    delta1: float = 1.0            # resource weight
    delta2: float = 0.1            # time weight


def total_cost(u_r: float, u_d: float, w: CostWeights) -> float:
    return w.delta1 * u_r + w.delta2 * u_d


# Table 1 token statistics (mean, std) per retrieval strategy — used by the
# workload simulator to draw realistic token counts for a 3B model.
TABLE1_TOKENS = {
    "llm_only": {"in": (16.01, 5.01), "out": (27.21, 14.83)},
    "naive_rag": {"in": (3632.0, 28.95), "out": (26.59, 19.81)},
    "graph_rag": {"in": (9017.0, 2529.0), "out": (142.7, 91.58)},
}


__all__ = [
    "TierSpec", "CostWeights", "GPU_PEAK_TFLOPS_FP64", "TPU_PEAK_TFLOPS_BF16",
    "PAPER_EDGE", "PAPER_CLOUD", "TPU_EDGE", "TPU_CLOUD",
    "inference_tflops", "generation_delay", "time_cost_tflops", "total_cost",
    "modeled_prefill_s", "modeled_decode_round_s", "modeled_mixed_step_s",
    "TABLE1_TOKENS",
]
