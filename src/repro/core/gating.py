"""Hierarchical collaborative gating (paper §3.3/§4, contribution C3).

Context  c_t = [d_t, s_t, q_t]:
  d_t: network delays (cloud, best-edge),
  s_t: highest keyword-overlap ratio + which edge dataset,
  q_t: query complexity (single/multi-hop, length, #entities).

Control x_t = [r_t, g_t]: retrieval source x generation location. The paper's
prototype evaluates four strategies; we keep the full 3x2 space definable.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.edge_assist import query_keywords
from repro.core.safeobo import SafeOBO, SafeOBOConfig
from repro.retrieval.embedder import content_words

# ---- arms -------------------------------------------------------------------

@dataclass(frozen=True)
class Arm:
    idx: int
    retrieval: str     # "none" | "edge" | "graph"
    generation: str    # "local" | "cloud"
    name: str


PAPER_ARMS: Tuple[Arm, ...] = (
    Arm(0, "none", "local", "slm-only"),
    Arm(1, "edge", "local", "edge-rag+slm"),
    Arm(2, "graph", "local", "graphrag+slm"),
    Arm(3, "graph", "cloud", "graphrag+llm"),
)


# ---- query analysis -----------------------------------------------------------

_MULTIHOP_CUES = re.compile(
    r"\b(impact|effect|influence|relationship|compare|both|because|lead to|"
    r"result in|contribute|connection|differ|why|how does|through)\b", re.I)


@dataclass
class QueryContext:
    query: str
    d_cloud: float                 # cloud network delay (s)
    d_edge: float                  # best-edge network delay (s)
    overlap: float                 # highest keyword overlap ratio
    edge_id: str                   # edge dataset achieving it
    edge_index: int = 0
    multihop: bool = False
    n_tokens: int = 0
    n_entities: int = 0

    @staticmethod
    def analyze(query: str, d_cloud: float, d_edge: float, overlap: float,
                edge_id: str, edge_index: int = 0) -> "QueryContext":
        toks = query.split()
        ents = content_words(query)
        return QueryContext(
            query=query, d_cloud=d_cloud, d_edge=d_edge, overlap=overlap,
            edge_id=edge_id, edge_index=edge_index,
            multihop=bool(_MULTIHOP_CUES.search(query)) or len(ents) >= 6,
            n_tokens=len(toks), n_entities=len(set(ents)),
        )


# ARD-style per-feature scales: the GP kernel is isotropic, so feature
# scaling doubles as automatic-relevance weighting. Keyword overlap and
# multi-hop structure are the strong accuracy predictors (they determine
# retrieval hit probability and reasoning depth); network delays and lengths
# are compressed so they do not dilute the safe-set evidence density.
ARD_WEIGHTS = np.array([0.25, 0.25, 2.8, 0.25, 2.0, 0.5, 0.5], np.float32)


def context_features(qc: QueryContext, n_edges: int = 8) -> np.ndarray:
    """Standardized, relevance-weighted feature vector for the GPs."""
    raw = np.array([
        min(qc.d_cloud / 0.5, 2.0),
        min(qc.d_edge / 0.1, 2.0),
        qc.overlap,
        qc.edge_index / max(n_edges - 1, 1),
        1.0 if qc.multihop else 0.0,
        min(qc.n_tokens / 30.0, 2.0),
        min(qc.n_entities / 8.0, 2.0),
    ], np.float32)
    return raw * ARD_WEIGHTS


CONTEXT_DIM = 7


# ---- gate ----------------------------------------------------------------------

@dataclass
class Decision:
    arm: Arm
    info: dict = field(default_factory=dict)


class CollaborativeGate:
    """The paper's gate: SafeOBO over (context, arm)."""

    def __init__(self, *, qos_min_acc: float = 0.9, qos_max_delay: float = 5.0,
                 warmup_steps: int = 300, beta: float = 2.0, seed: int = 0,
                 arms: Tuple[Arm, ...] = PAPER_ARMS, n_edges: int = 8,
                 use_pallas: bool = False):
        self.arms = arms
        self.n_edges = n_edges
        self.obo = SafeOBO(SafeOBOConfig(
            n_arms=len(arms), context_dim=CONTEXT_DIM,
            warmup_steps=warmup_steps, beta=beta,
            qos_min_acc=qos_min_acc, qos_max_delay=qos_max_delay,
            safe_seed_arm=len(arms) - 1, use_pallas=use_pallas,
        ), seed=seed)

    def decide(self, qc: QueryContext,
               available: Optional[Tuple[bool, ...]] = None) -> Decision:
        """Pick an arm. ``available`` masks arms the infrastructure cannot
        serve right now (open circuit breaker on the backing tier, an
        edge<->cloud partition cutting off cloud generation): a masked arm
        is never selected, and because callers also never ``update`` on
        failed work, infrastructure outages never pollute the GP
        posterior. ``None`` = all arms reachable (legacy path, identical
        RNG stream)."""
        ctx = context_features(qc, self.n_edges)
        idx, info = self.obo.select(ctx, available=available)
        return Decision(self.arms[idx], info)

    def update(self, qc: QueryContext, arm: Arm, *, cost: float,
               accuracy: float, delay: float) -> None:
        ctx = context_features(qc, self.n_edges)
        self.obo.update(ctx, arm.idx, cost=cost, accuracy=accuracy,
                        delay=delay)

    @property
    def in_warmup(self) -> bool:
        return self.obo.in_warmup


__all__ = ["Arm", "PAPER_ARMS", "QueryContext", "context_features",
           "CONTEXT_DIM", "CollaborativeGate", "Decision"]
