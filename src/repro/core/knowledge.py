"""Adaptive knowledge update (paper §5, contribution C2).

The cloud accumulates recent QA traffic per edge node; every
``update_trigger`` (=20) new QA pairs at an edge, the cloud:
  1. extracts keywords from that edge's recent queries,
  2. ranks GraphRAG communities by keyword/entity matches,
  3. ships up to ``max_chunks_per_update`` (=500) chunks from the top-k
     communities to the edge store, which applies FIFO eviction
     (capacity 1000).

**Knowledge epochs** (partition tolerance): every triggered update —
shipped or not — bumps the updater's monotone ``latest_epoch``; a
successful ship stamps the target store's ``epoch`` to match. When the
edge<->cloud link is down (``link_up=False``) the update is DEFERRED: the
edge keeps serving from its old chunk set, its answers flagged
``stale_epoch`` (:meth:`is_stale`), until :meth:`sync` — anti-entropy on
partition heal — replays the pending refresh and catches the store up to
the newest epoch. Availability beats freshness; staleness is never
silent.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.retrieval.graph_rag import KnowledgeGraph
from repro.retrieval.store import Chunk, VectorStore


@dataclass
class KnowledgeUpdateConfig:
    update_trigger: int = 20           # new QA pairs per update (paper: 20)
    max_chunks_per_update: int = 500   # paper: up to 500
    top_k_communities: int = 3
    recent_window: int = 60            # queries considered for relevance


@dataclass
class UpdateStats:
    updates: int = 0
    chunks_shipped: int = 0
    chunks_evicted: int = 0
    deferred: int = 0             # updates blocked by a partition
    synced: int = 0               # anti-entropy reconciliations on heal


class AdaptiveKnowledgeUpdater:
    """Cloud-side component driving per-edge knowledge refresh."""

    def __init__(self, graph: KnowledgeGraph,
                 cfg: Optional[KnowledgeUpdateConfig] = None):
        self.graph = graph
        self.cfg = cfg or KnowledgeUpdateConfig()
        self._pending: Dict[str, List[str]] = {}
        self._recent: Dict[str, List[str]] = {}
        self.stats: Dict[str, UpdateStats] = {}
        self.latest_epoch = 0             # newest knowledge version, monotone
        self.deferred: set = set()        # edges owed an update (partition)

    def observe_query(self, edge_id: str, query: str,
                      store: VectorStore, now: float = 0.0,
                      link_up: bool = True) -> bool:
        """Record one served QA pair; trigger an update when due.
        Returns True if an update became due (it ships immediately when
        ``link_up``, otherwise defers until :meth:`sync`)."""
        self._pending.setdefault(edge_id, []).append(query)
        rec = self._recent.setdefault(edge_id, [])
        rec.append(query)
        if len(rec) > self.cfg.recent_window:
            del rec[: len(rec) - self.cfg.recent_window]
        if len(self._pending[edge_id]) < self.cfg.update_trigger:
            return False
        self._pending[edge_id] = []
        self.push_update(edge_id, store, now, link_up=link_up)
        return True

    def push_update(self, edge_id: str, store: VectorStore,
                    now: float = 0.0, link_up: bool = True) -> int:
        """Ship community chunks relevant to the edge's recent queries and
        stamp the store with the new epoch. With the link down, the epoch
        still advances (the cloud's knowledge moved on) but nothing ships:
        the edge is marked deferred and reconciles via :meth:`sync`."""
        queries = self._recent.get(edge_id, [])
        if not queries:
            return 0
        self.latest_epoch += 1
        st = self.stats.setdefault(edge_id, UpdateStats())
        if not link_up:
            self.deferred.add(edge_id)
            st.deferred += 1
            return 0
        chunks = self.graph.community_chunks_for_queries(
            queries, self.cfg.top_k_communities,
            self.cfg.max_chunks_per_update)
        existing = {c.text for c in store.chunks}
        fresh = [Chunk(c.text, c.keywords, c.source, c.topic, now)
                 for c in chunks if c.text not in existing]
        evicted = store.add(fresh)
        store.epoch = self.latest_epoch
        self.deferred.discard(edge_id)
        st.updates += 1
        st.chunks_shipped += len(fresh)
        st.chunks_evicted += evicted
        return len(fresh)

    def sync(self, edge_id: str, store: VectorStore,
             now: float = 0.0) -> int:
        """Anti-entropy reconciliation after a partition heals: replay the
        deferred refresh for this edge, catching its store up to the
        newest epoch. No-op for edges that aren't owed anything."""
        if edge_id not in self.deferred:
            return 0
        st = self.stats.setdefault(edge_id, UpdateStats())
        st.synced += 1
        return self.push_update(edge_id, store, now, link_up=True)

    def is_stale(self, store: VectorStore) -> bool:
        """Is this store serving knowledge older than the newest epoch?"""
        return store.epoch < self.latest_epoch

    def snapshot(self, stores: Optional[Dict[str, VectorStore]] = None
                 ) -> dict:
        """Machine-readable epoch state for DST oracle snapshots: the
        monotone ``latest_epoch``, the deferred-edge set (sorted — trace
        artifacts must not depend on set iteration order), and, when the
        per-edge stores are passed in, each store's stamped epoch. The
        DST epoch oracle checks these never regress and that every store
        epoch stays <= ``latest_epoch``."""
        snap: dict = {"latest_epoch": self.latest_epoch,
                      "deferred": sorted(self.deferred)}
        if stores is not None:
            snap["stores"] = {eid: stores[eid].epoch
                              for eid in sorted(stores)}
        return snap


__all__ = ["AdaptiveKnowledgeUpdater", "KnowledgeUpdateConfig", "UpdateStats"]
