"""Adaptive knowledge update (paper §5, contribution C2).

The cloud accumulates recent QA traffic per edge node; every
``update_trigger`` (=20) new QA pairs at an edge, the cloud:
  1. extracts keywords from that edge's recent queries,
  2. ranks GraphRAG communities by keyword/entity matches,
  3. ships up to ``max_chunks_per_update`` (=500) chunks from the top-k
     communities to the edge store, which applies FIFO eviction
     (capacity 1000).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.retrieval.graph_rag import KnowledgeGraph
from repro.retrieval.store import Chunk, VectorStore


@dataclass
class KnowledgeUpdateConfig:
    update_trigger: int = 20           # new QA pairs per update (paper: 20)
    max_chunks_per_update: int = 500   # paper: up to 500
    top_k_communities: int = 3
    recent_window: int = 60            # queries considered for relevance


@dataclass
class UpdateStats:
    updates: int = 0
    chunks_shipped: int = 0
    chunks_evicted: int = 0


class AdaptiveKnowledgeUpdater:
    """Cloud-side component driving per-edge knowledge refresh."""

    def __init__(self, graph: KnowledgeGraph,
                 cfg: Optional[KnowledgeUpdateConfig] = None):
        self.graph = graph
        self.cfg = cfg or KnowledgeUpdateConfig()
        self._pending: Dict[str, List[str]] = {}
        self._recent: Dict[str, List[str]] = {}
        self.stats: Dict[str, UpdateStats] = {}

    def observe_query(self, edge_id: str, query: str,
                      store: VectorStore, now: float = 0.0) -> bool:
        """Record one served QA pair; trigger an update when due.
        Returns True if an update was shipped."""
        self._pending.setdefault(edge_id, []).append(query)
        rec = self._recent.setdefault(edge_id, [])
        rec.append(query)
        if len(rec) > self.cfg.recent_window:
            del rec[: len(rec) - self.cfg.recent_window]
        if len(self._pending[edge_id]) < self.cfg.update_trigger:
            return False
        self._pending[edge_id] = []
        self.push_update(edge_id, store, now)
        return True

    def push_update(self, edge_id: str, store: VectorStore,
                    now: float = 0.0) -> int:
        """Ship community chunks relevant to the edge's recent queries."""
        queries = self._recent.get(edge_id, [])
        if not queries:
            return 0
        chunks = self.graph.community_chunks_for_queries(
            queries, self.cfg.top_k_communities,
            self.cfg.max_chunks_per_update)
        existing = {c.text for c in store.chunks}
        fresh = [Chunk(c.text, c.keywords, c.source, c.topic, now)
                 for c in chunks if c.text not in existing]
        evicted = store.add(fresh)
        st = self.stats.setdefault(edge_id, UpdateStats())
        st.updates += 1
        st.chunks_shipped += len(fresh)
        st.chunks_evicted += evicted
        return len(fresh)


__all__ = ["AdaptiveKnowledgeUpdater", "KnowledgeUpdateConfig", "UpdateStats"]
