"""Safe Online Bayesian Optimization — Algorithm 1 of the paper.

Three GP families model cost (i=0), accuracy (i=1) and delay (i=2) over the
(context, arm) space. Since arms are categorical, the joint GP over
(context, one-hot(arm)) factorizes into one GP per (objective, arm) — an
exact reparameterization that also makes the observation ring buffers
per-arm, so exploitation traffic on one arm can never evict another arm's
warmup evidence (a failure mode we hit with a single shared buffer).

Warm-up phase: uniform-random arms. Exploitation:
  safe set S_t = S_0 ∪ {x : μ1-βσ1 ≥ QoS_acc ∧ μ2+βσ2 ≤ QoS_delay}
  x_t = argmin_{x∈S_t} μ0 - β σ0           (LCB on cost)

``select`` optionally takes an ARM-AVAILABILITY MASK (open circuit
breaker, network partition): unavailable arms are excluded from both the
warmup draw and the exploit safe set, including the S_0 seed arm — an
unreachable arm is never "safe". Availability is an infrastructure fact,
not a learned quantity, so it must never enter the GP posterior: callers
simply don't ``update`` on failures (the PR-5 shed rule), and the mask
guarantees the optimizer can't route into a known-dead arm in the first
place. With ``available=None`` the selection path — including the RNG
stream — is bit-identical to the unmasked behavior.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gp import (
    GPHypers, GPState, gp_add, gp_init, gp_posterior, refresh_lengthscale,
)


@dataclass
class SafeOBOConfig:
    n_arms: int = 4
    context_dim: int = 6
    capacity: int = 256            # GP observation window PER ARM
    warmup_steps: int = 300        # T0
    beta: float = 2.0              # acquisition LCB exploration
    beta_safe: float = 1.0         # safety confidence bound
    qos_min_acc: float = 0.9
    qos_max_delay: float = 5.0
    safe_seed_arm: int = 3         # cloud GraphRAG + cloud LLM (always safe)
    cost_scale: float = 500.0      # normalize cost obs into O(1)
    hyper_refresh_every: int = 64
    use_pallas: bool = False


class SafeOBO:
    """Host-side driver; posteriors/updates are jit'd JAX."""

    N_OBJ = 3  # cost, accuracy, delay

    def __init__(self, cfg: SafeOBOConfig, seed: int = 0):
        self.cfg = cfg
        self.gps: List[List[GPState]] = [
            [gp_init(cfg.capacity, cfg.context_dim)
             for _ in range(cfg.n_arms)]
            for _ in range(self.N_OBJ)
        ]
        # per-objective noise: accuracy observations are Bernoulli draws.
        # The accuracy GP's hypers are FIXED: marginal-likelihood refresh on
        # 0/1 targets collapses the lengthscale (overfits the noise), which
        # destroys safe-set generalization.
        self.hypers = [
            GPHypers(lengthscale=1.0, signal_var=1.0, noise_var=0.05),   # cost
            GPHypers(lengthscale=2.0, signal_var=1.0, noise_var=0.05),   # acc
            GPHypers(lengthscale=1.0, signal_var=1.0, noise_var=0.05),   # delay
        ]
        self.t = 0
        self.rng = np.random.default_rng(seed)

    # ---- Algorithm 1, lines 4-5 / 14-19 -------------------------------------
    def posteriors(self, ctx: np.ndarray) -> np.ndarray:
        """[N_OBJ, n_arms, 2] (mu, sigma) at this context."""
        cfg = self.cfg
        Xq = jnp.asarray(ctx, jnp.float32)[None]
        out = np.zeros((self.N_OBJ, cfg.n_arms, 2), np.float32)
        for i in range(self.N_OBJ):
            h = self.hypers[i]
            for a in range(cfg.n_arms):
                mu, sd = gp_posterior(self.gps[i][a], Xq, h.lengthscale,
                                      h.signal_var, h.noise_var,
                                      use_pallas=cfg.use_pallas)
                out[i, a] = (float(mu[0]), float(sd[0]))
        return out

    def select(self, ctx: np.ndarray,
               available: Optional[Sequence[bool]] = None
               ) -> Tuple[int, dict]:
        """Pick an arm for this context. ``available[a] = False`` (open
        breaker, partition) removes arm ``a`` from consideration entirely;
        ``None`` keeps the legacy unmasked path bit-for-bit (same RNG
        draws in warmup)."""
        cfg = self.cfg
        avail = None if available is None else np.asarray(available, bool)
        if avail is not None and avail.shape != (cfg.n_arms,):
            raise ValueError(
                f"availability mask must have shape ({cfg.n_arms},), "
                f"got {avail.shape}")
        if avail is not None and not avail.any():
            raise ValueError("availability mask excludes every arm")
        if self.t < cfg.warmup_steps:
            if avail is None:
                arm = int(self.rng.integers(cfg.n_arms))
            else:
                opts = np.flatnonzero(avail)
                arm = int(opts[self.rng.integers(len(opts))])
            return arm, {"phase": "warmup",
                         "safe": (list(range(cfg.n_arms)) if avail is None
                                  else np.flatnonzero(avail).tolist())}
        p = self.posteriors(ctx)
        mu0, sd0 = p[0, :, 0], p[0, :, 1]
        mu1, sd1 = p[1, :, 0], p[1, :, 1]
        mu2, sd2 = p[2, :, 0], p[2, :, 1]
        safe = ((mu1 - cfg.beta_safe * sd1 >= cfg.qos_min_acc)
                & (mu2 + cfg.beta_safe * sd2 <= cfg.qos_max_delay))
        safe[cfg.safe_seed_arm] = True            # S_0 seed
        if avail is not None:
            safe &= avail                 # an unreachable arm is never safe
            if not safe.any():
                # nothing provably safe is reachable: degrade to the best
                # reachable arm rather than routing into a dead one
                safe = avail.copy()
        lcb = mu0 - cfg.beta * sd0
        lcb_masked = np.where(safe, lcb, np.inf)
        arm = int(np.argmin(lcb_masked))
        return arm, {
            "phase": "exploit", "safe": np.flatnonzero(safe).tolist(),
            "mu_cost": mu0.tolist(), "sd_cost": sd0.tolist(),
            "mu_acc": mu1.tolist(), "sd_acc": sd1.tolist(),
            "mu_delay": mu2.tolist(),
        }

    # ---- Algorithm 1, lines 6-11 / 20-25 ------------------------------------
    def update(self, ctx: np.ndarray, arm: int, *, cost: float,
               accuracy: float, delay: float) -> None:
        cfg = self.cfg
        x = jnp.asarray(ctx, jnp.float32)
        ys = (cost / cfg.cost_scale, accuracy, delay)
        for i in range(self.N_OBJ):
            self.gps[i][arm] = gp_add(self.gps[i][arm], x, ys[i])
        self.t += 1
        if (self.t % cfg.hyper_refresh_every == 0
                and self.t >= cfg.warmup_steps // 2):
            for i in (0, 2):       # cost & delay only; accuracy stays fixed
                self.hypers[i] = refresh_lengthscale(
                    self.gps[i][self.t % cfg.n_arms], self.hypers[i],
                    grid=(0.75, 1.0, 1.5, 2.5, 4.0))

    @property
    def in_warmup(self) -> bool:
        return self.t < self.cfg.warmup_steps


__all__ = ["SafeOBO", "SafeOBOConfig"]
