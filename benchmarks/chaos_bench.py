"""Chaos benchmark: crash/recovery, circuit breakers, hedging and
epoch-versioned knowledge under injected hard failures.

Where ``overload_bench`` stresses the scheduler with too much WORK, this
bench stresses it with broken MACHINES: engines that crash (losing all
device state), one pinned flaky pool member, stall spikes, and an
edge<->cloud partition — all driven by the deterministic
:class:`~repro.cluster.faults.FaultInjector` schedules on one virtual
clock, so every case replays bit-identically per seed.

The hand-authored schedules here are fixed event TIMELINES: each
``FaultConfig`` period/duration formula expands into explicit
:class:`~repro.cluster.faults.FaultEvent` records (same windows, same
victims as the original closed forms). The randomized counterpart —
seeded schedules over the same event vocabulary, with per-pump invariant
oracles and failing-trace shrinking — lives in ``benchmarks/dst_bench.py``
(``make fuzz``). Every case additionally ends with an engine page-arena
audit (``assert_quiescent``): no chaos schedule may leak KV pages.

Cases:

1. ``crash-requeue`` — a 2-engine edge pool with a rotating crash/restart
   schedule, ``requeue_lost=True``. Residents that die with their engine
   are re-enqueued (banked tokens ride the prefix-cache resume path) and
   re-served after restart.
2. ``flaky-breaker`` / ``flaky-nobreaker`` — the SAME pinned-flaky-node
   schedule (``crash_rotate=False``: engine 0 crashes every cycle) with
   and without the per-engine circuit breaker. The breaker quarantines
   the flaky member after ``threshold`` consecutive losses, so work stops
   landing on a machine that keeps eating it.
3. ``spike-hedge`` / ``spike-nohedge`` — an interactive stream through a
   single edge engine with periodic stall spikes, with and without
   edge->cloud hedging. Past ``hedge_s`` of no progress a backup fires on
   the cloud tier; first completion wins, the loser is cancelled.
4. ``cluster-chaos`` — the full EACO loop (``backend="engines"``) under
   simultaneous edge crashes AND partitions: typed engine_lost sheds flow
   through failover, tier breakers + hedging route around the damage,
   knowledge updates due during a partition are deferred (answers flagged
   ``stale_epoch``) and reconciled by anti-entropy on heal.
5. ``mask`` — direct SafeOBO sweep: random availability masks across
   warmup and exploit phases; the gate must never select a masked arm.

``--check`` gates (the crash-tolerance contract):
  * a crash-and-restart run loses ZERO requests: every submission reaches
    a completion (token-identical to the uncontended greedy reference) or
    a typed shed; conservation holds in every case;
  * the breaker keeps post-crash p95 within the no-breaker baseline and
    cuts requeue churn;
  * hedging cuts tail p99 under stall spikes vs the no-hedge baseline;
  * cluster chaos conserves every query, crashes AND restarts engines,
    runs anti-entropy at least once, and never serves a stale-epoch
    answer without flagging it (``stale_served`` == flagged log rows);
  * the gate never selects a masked arm.

Usage:  PYTHONPATH=src:. python benchmarks/chaos_bench.py \
            [--smoke] [--check] [--seed N]
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from benchmarks.common import emit
from repro.core.clock import VirtualClock
from repro.core.cost_model import (
    PAPER_CLOUD, PAPER_EDGE, modeled_decode_round_s, modeled_prefill_s,
)
from repro.core.safeobo import SafeOBO, SafeOBOConfig
from repro.cluster.faults import FaultConfig, FaultInjector
from repro.serving import Request, TierScheduler, make_edge_engine

MAX_SEQ = 128
MAX_BATCH = 2
INTERACTIVE_SLO_S = 30.0    # loose: chaos cases measure latency, not sheds
BATCH_SLO_S = 120.0
WEDGE_IDLE_S = 30.0         # virtual idle time with zero progress = wedge
TIER_SPEC = {"edge": PAPER_EDGE, "cloud": PAPER_CLOUD}


def chaos_workload(n: int, seed: int, interactive_only: bool = False):
    """Deterministic request stream: (slo, prompt, max_new) specs."""
    rng = np.random.default_rng(seed)
    specs = []
    for k in range(n):
        if interactive_only or k % 2 == 0:
            plen = int(rng.integers(12, 36))
            new = int(rng.integers(4, 9))
            slo = "interactive"
        else:
            # long-running decodes: these are the residents the crash
            # windows catch mid-flight (short work slips between windows)
            plen = int(rng.integers(24, 48))
            new = int(rng.integers(40, 65))
            slo = "batch"
        prompt = f"q{k} " + "".join(rng.choice(list("abcdefgh "), plen))
        specs.append((slo, prompt, new))
    return specs


def make_requests(specs):
    return [Request(prompt, max_new_tokens=new, slo=slo)
            for slo, prompt, new in specs]


def arrival_times(specs, span_s: float):
    """Deterministic arrivals evenly paced over ``span_s`` virtual
    seconds — long enough for the fault schedules to cycle several times
    while the stream is in flight (the point of a chaos bench is the
    overlap of work and failure windows, not raw load)."""
    dt = span_s / len(specs)
    return [k * dt for k in range(len(specs))]


def run_sched_case(pools, specs, span_s: float, *,
                   faults=None, crash_schedule: bool = False,
                   requeue_lost: bool = True,
                   breaker_threshold=None, breaker_reset_s: float = 30.0,
                   hedge_s=None):
    """Drive one chaos case at the scheduler level. The fault injector's
    crash windows are applied to the real engines each round (crash when a
    window opens, restart when it closes — mirroring the cluster's
    ``_apply_fault_transitions``); stall windows go in via the scheduler's
    ``stalled`` hook. Modeled service time per round is the slowest
    tier's, exactly as the cluster simulator computes it."""
    clock = VirtualClock()
    sched = TierScheduler(pools, clock=clock, preempt=True,
                          requeue_lost=requeue_lost,
                          breaker_threshold=breaker_threshold,
                          breaker_reset_s=breaker_reset_s,
                          hedge_s=hedge_s, hedge_from="edge",
                          hedge_to="cloud")
    reqs = make_requests(specs)
    arrivals = list(zip(arrival_times(specs, span_s), reqs))
    slack = {"interactive": INTERACTIVE_SLO_S, "batch": BATCH_SLO_S}
    index = {id(r): k for k, r in enumerate(reqs)}
    flat = [(t, i, e) for t, pool in pools.items()
            for i, e in enumerate(pool)]
    crashed, n_crashes, n_restarts = set(), 0, 0

    completions, idle_since = [], None
    while arrivals or sched.pending() or sched.in_flight():
        now = clock.now()
        if crash_schedule and faults is not None:
            for tier, i, e in flat:
                want_dead = faults.crashed(tier, i, now, len(pools[tier]))
                if want_dead and not e.dead:
                    e.crash()
                    crashed.add((tier, i))
                    n_crashes += 1
                elif not want_dead and e.dead and (tier, i) in crashed:
                    e.restart()
                    crashed.discard((tier, i))
                    n_restarts += 1
        while arrivals and arrivals[0][0] <= now:
            t_arr, r = arrivals.pop(0)
            sched.submit(r, "edge", deadline_s=t_arr + slack[r.slo], now=now)
        stalled = None
        if faults is not None:
            def stalled(tier, i, _now=now):        # noqa: E731
                return faults.stalled(tier, i, _now, len(pools[tier]))
        pre = [(e.prefill_tokens, e.decode_rounds) for _, _, e in flat]
        before = (sched.pending(), sched.in_flight(),
                  tuple(sched.counters.values()))
        comps = sched.pump(now=now, stalled=stalled)
        completions.extend(comps)
        dt = 0.0
        for (tier, _, e), (p0, r0) in zip(flat, pre):
            spec = TIER_SPEC[tier]
            dt = max(dt, modeled_prefill_s(spec, e.prefill_tokens - p0)
                     + (e.decode_rounds - r0) * modeled_decode_round_s(spec))
        after = (sched.pending(), sched.in_flight(),
                 tuple(sched.counters.values()))
        if dt > 0:
            clock.advance(dt)
            idle_since = None
            continue
        if after != before:
            idle_since = None
            continue
        # nothing moved: tick through the fault window / idle to the next
        # arrival; a long plateau with work outstanding is a wedge
        idle_since = now if idle_since is None else idle_since
        if now - idle_since > WEDGE_IDLE_S:
            raise RuntimeError(
                f"chaos case wedged at t={now:.2f}:\n{sched.debug_state()}")
        clock.advance(min(max(arrivals[0][0] - now, 0.05), 0.25)
                      if arrivals else 0.05)

    # a drained case must leave every surviving engine's page arena clean:
    # refcounts match slot mappings, free + cached + active == num_pages
    for _, _, e in flat:
        e.assert_quiescent()

    def lat(c):
        return c.queue_wait_s + c.time_in_engine_s

    lats = [lat(c) for c in completions]
    sheds = sched.pop_sheds()
    return {
        "completions": completions,
        "index": index,
        "conservation": sched.conservation_ok(),
        "counters": dict(sched.counters),
        "shed_reasons": sorted({s.reason for s in sheds}),
        "crashes": n_crashes,
        "restarts": n_restarts,
        "p95_s": float(np.percentile(lats, 95)) if lats else float("nan"),
        "p99_s": float(np.percentile(lats, 99)) if lats else float("nan"),
        "hedged_wins": sum(c.hedged for c in completions),
        "makespan_s": clock.now(),
    }


def run_cluster_case(*, smoke: bool, seed: int):
    """Full EACO loop under simultaneous crashes and partitions."""
    from repro.cluster.simulator import EACOCluster, SimConfig
    from repro.data.corpus import wiki_like

    steps = 30 if smoke else 60
    cfg = SimConfig(
        seed=seed, n_edges=2, warmup_steps=8, qos_min_acc=0.85,
        n_edge_engines=2, edge_max_seq=128, edge_max_batch=2,
        cloud_max_seq=128, cloud_max_batch=2, max_new_slm=8,
        max_new_graph=12, mean_arrivals=1.5, max_arrivals=4,
        update_trigger=4, hot_topic_boost=0.3,
        engine_breaker_threshold=3, breaker_threshold=3,
        breaker_reset_s=4.0, hedge_s=1.5, failover_max_retries=3)
    faults = FaultInjector(FaultConfig(
        crash_period_s=12.0, crash_duration_s=2.0, crash_start_s=5.0,
        crash_tiers=("edge",),
        partition_period_s=16.0, partition_duration_s=5.0,
        partition_start_s=6.0, seed=seed))
    cluster = EACOCluster(wiki_like(seed=seed), cfg, policy="eaco",
                          backend="engines", faults=faults)
    logs = cluster.run(steps)
    for pool in cluster.sched.pools.values():
        for e in pool:
            e.assert_quiescent()
    ok = [l for l in logs if l.outcome == "ok"]
    return {
        "cluster": cluster,
        "logs": logs,
        "conservation": cluster.conservation_ok(),
        "counters": dict(cluster.counters),
        "served": len(ok),
        "dropped": len(logs) - len(ok),
        "stale_flagged": sum(l.stale_epoch for l in ok),
        "untyped_outcomes": sorted({l.outcome for l in logs}
                                   - {"ok", "shed", "failed"}),
        "final_epoch": cluster.updater.latest_epoch,
        "unreconciled": sorted(cluster.updater.deferred),
    }


def run_mask_sweep(seed: int, n: int = 300):
    """The gate must never select a masked arm — random masks across both
    the warmup (uniform) and exploit (GP posterior) phases."""
    cfg = SafeOBOConfig(n_arms=4, context_dim=3, warmup_steps=n // 3)
    obo = SafeOBO(cfg, seed=seed)
    rng = np.random.default_rng(seed + 1)
    violations = 0
    for _ in range(n):
        ctx = rng.random(3).astype(np.float32)
        mask = rng.random(4) < 0.7
        if not mask.any():
            mask[int(rng.integers(4))] = True
        arm, _ = obo.select(ctx, available=tuple(bool(b) for b in mask))
        if not mask[arm]:
            violations += 1
        obo.update(ctx, arm, cost=float(rng.random()),
                   delay=float(rng.random()),
                   accuracy=float(rng.random() > 0.3))
    return {"selections": n, "violations": violations}


def run(quick: bool = False, check: bool = False, seed: int = 0):
    n = 32 if quick else 80
    specs = chaos_workload(n, seed)
    inter_specs = chaos_workload(24 if quick else 48, seed + 1,
                                 interactive_only=True)

    # shared-seed engine pools: pool members are replicas (same weights),
    # so completions are comparable against one reference regardless of
    # which engine — or which restart generation — served them
    def edge(s=0):
        return make_edge_engine(max_seq=MAX_SEQ, max_batch=MAX_BATCH, seed=s)

    ref_eng = edge()
    ref_eng.warmup(len(ref_eng.tok.encode(p))
                   for _, p, _ in specs + inter_specs)
    ref_texts, _ = ref_eng.generate(make_requests(specs))
    ref_eng.invalidate_prefix_cache()

    results, rows = {}, []

    # -- case 1: rotating crash/restart, lost residents requeued --------
    pools = {"edge": [ref_eng, edge()]}
    res = run_sched_case(
        pools, specs, span_s=20.0,
        faults=FaultInjector(FaultConfig(
            crash_period_s=6.0, crash_duration_s=1.5, crash_start_s=1.0)),
        crash_schedule=True, requeue_lost=True)
    res["mismatched"] = sum(c.text != ref_texts[res["index"][id(c.request)]]
                            for c in res["completions"])
    results["crash-requeue"] = res
    ref_eng.invalidate_prefix_cache()

    # -- case 2: pinned flaky node, breaker vs none ---------------------
    # fast crash cycling: the breaker trips after its first two losses
    # and sits out the remaining windows the no-breaker run keeps losing
    # residents to
    # crash_start_s is offset off the 0.5s arrival grid so the windows
    # open mid-service (a batch decode runs ~0.6s modeled) and catch the
    # flaky member with residents
    def flaky_faults():
        return FaultInjector(FaultConfig(
            crash_period_s=3.0, crash_duration_s=1.0, crash_start_s=0.8,
            crash_rotate=False))

    # tight arrival pacing keeps the flaky member busy, so every crash
    # window catches residents: without the breaker the scheduler keeps
    # feeding a machine that keeps eating its work. Threshold 1 because
    # the breaker counts CONSECUTIVE failures and the flaky engine
    # completes work between windows, resetting a higher threshold
    for name, thresh in [("flaky-breaker", 1), ("flaky-nobreaker", None)]:
        pools = {"edge": [edge(), edge()]}
        results[name] = run_sched_case(
            pools, specs, span_s=16.0,
            faults=flaky_faults(), crash_schedule=True,
            requeue_lost=True, breaker_threshold=thresh,
            breaker_reset_s=60.0)

    # -- case 3: stall spikes, hedge vs none ----------------------------
    for name, h in [("spike-hedge", 0.4), ("spike-nohedge", None)]:
        pools = {"edge": [edge()], "cloud": [edge()]}
        results[name] = run_sched_case(
            pools, inter_specs, span_s=24.0,
            faults=FaultInjector(FaultConfig(
                stall_period_s=8.0, stall_duration_s=2.5,
                stall_start_s=2.0, stall_tiers=("edge",))),
            hedge_s=h)

    # -- case 4 + 5 -----------------------------------------------------
    results["cluster-chaos"] = run_cluster_case(smoke=quick, seed=seed)
    results["mask"] = run_mask_sweep(seed)

    for name in ["crash-requeue", "flaky-breaker", "flaky-nobreaker",
                 "spike-hedge", "spike-nohedge"]:
        r = results[name]
        c = r["counters"]
        rows.append({
            "name": name,
            "submitted": c["submitted"],
            "completed": c["completed"],
            "engine_lost": c["engine_lost"],
            "requeued_lost": c["requeued_lost"],
            "hedged": c["hedged"],
            "cancelled": c["cancelled"],
            "crashes": r["crashes"],
            "restarts": r["restarts"],
            "p95_s": round(r["p95_s"], 3),
            "p99_s": round(r["p99_s"], 3),
            "conservation": r["conservation"],
            "makespan_s": round(r["makespan_s"], 2),
        })
    cc = results["cluster-chaos"]
    rows.append({
        "name": "cluster-chaos",
        "served": cc["served"],
        "dropped": cc["dropped"],
        "engine_crashes": cc["counters"]["engine_crashes"],
        "engine_restarts": cc["counters"]["engine_restarts"],
        "anti_entropy_syncs": cc["counters"]["anti_entropy_syncs"],
        "stale_served": cc["counters"]["stale_served"],
        "hedged_served": cc["counters"]["hedged_served"],
        "breaker_reroutes": cc["counters"]["breaker_reroutes"],
        "final_epoch": cc["final_epoch"],
        "conservation": cc["conservation"],
    })
    ms = results["mask"]
    rows.append({"name": "mask", "selections": ms["selections"],
                 "violations": ms["violations"]})
    emit(rows, "chaos_bench")

    if not check:
        return 0

    failures = []

    def gate(cond, msg):
        print(f"  [{'PASS' if cond else 'FAIL'}] {msg}")
        if not cond:
            failures.append(msg)

    print("chaos gates:")
    r = results["crash-requeue"]
    gate(r["crashes"] >= 2 and r["restarts"] >= 2,
         f"crash-requeue exercises the schedule "
         f"({r['crashes']} crashes, {r['restarts']} restarts)")
    gate(r["counters"]["completed"] == r["counters"]["submitted"],
         f"crash-and-restart loses zero requests "
         f"({r['counters']['completed']}/{r['counters']['submitted']})")
    gate(r["counters"]["requeued_lost"] >= 1,
         f"lost residents were re-enqueued "
         f"({r['counters']['requeued_lost']})")
    gate(r["mismatched"] == 0,
         f"every re-served completion is token-identical to the reference "
         f"({r['mismatched']} mismatched)")
    for name in ["crash-requeue", "flaky-breaker", "flaky-nobreaker",
                 "spike-hedge", "spike-nohedge"]:
        gate(results[name]["conservation"],
             f"{name}: hedge-aware conservation holds")
        lost = (results[name]["counters"]["submitted"]
                - results[name]["counters"]["completed"]
                - sum(results[name]["counters"][k] for k in
                      ("shed", "timed_out", "overload_shed", "engine_lost")))
        gate(lost == 0, f"{name}: every outcome is typed (0 untracked)")

    b, nb = results["flaky-breaker"], results["flaky-nobreaker"]
    gate(b["counters"]["completed"] == b["counters"]["submitted"],
         "flaky-breaker completes the full stream")
    gate(b["p95_s"] <= nb["p95_s"],
         f"breaker keeps post-crash p95 within the no-breaker baseline "
         f"({b['p95_s']:.2f}s vs {nb['p95_s']:.2f}s)")
    gate(b["counters"]["requeued_lost"] < nb["counters"]["requeued_lost"],
         f"breaker cuts requeue churn on the flaky node "
         f"({b['counters']['requeued_lost']} vs "
         f"{nb['counters']['requeued_lost']})")

    h, nh = results["spike-hedge"], results["spike-nohedge"]
    gate(h["counters"]["hedged"] >= 1 and h["hedged_wins"] >= 1,
         f"hedges fired and won ({h['counters']['hedged']} fired, "
         f"{h['hedged_wins']} won)")
    gate(h["p99_s"] < nh["p99_s"],
         f"hedging cuts tail p99 under spikes "
         f"({h['p99_s']:.2f}s vs {nh['p99_s']:.2f}s)")

    gate(cc["conservation"], "cluster-chaos: query conservation holds")
    gate(not cc["untyped_outcomes"],
         f"cluster-chaos: all terminal outcomes typed "
         f"({cc['untyped_outcomes'] or 'ok/shed/failed'})")
    gate(cc["counters"]["engine_crashes"] >= 1
         and cc["counters"]["engine_restarts"] >= 1,
         f"cluster-chaos crashes AND restarts engines "
         f"({cc['counters']['engine_crashes']}/"
         f"{cc['counters']['engine_restarts']})")
    gate(cc["counters"]["anti_entropy_syncs"] >= 1,
         f"partition heal runs anti-entropy "
         f"({cc['counters']['anti_entropy_syncs']} syncs)")
    gate(cc["counters"]["stale_served"] == cc["stale_flagged"],
         f"no unflagged stale-epoch completions "
         f"({cc['counters']['stale_served']} counted, "
         f"{cc['stale_flagged']} flagged)")
    gate(cc["counters"]["stale_served"] >= 1,
         f"stale-epoch serving occurred and was flagged "
         f"({cc['counters']['stale_served']})")
    gate(not cc["unreconciled"],
         f"every deferred edge reconciled by run end "
         f"(pending: {cc['unreconciled'] or 'none'})")

    gate(ms["violations"] == 0,
         f"gate never selects a masked arm "
         f"({ms['selections']} masked selections checked)")

    if failures:
        print(f"{len(failures)} gate(s) FAILED")
        return 1
    print("all chaos gates passed")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small stream / short cluster run")
    ap.add_argument("--check", action="store_true",
                    help="evaluate acceptance gates; exit 1 on failure")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    return run(quick=args.smoke, check=args.check, seed=args.seed)


if __name__ == "__main__":
    sys.exit(main())
