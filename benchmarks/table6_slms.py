"""Paper Table 6: EACO-RAG with different edge SLMs (size/origin).

Larger SLMs raise per-call edge cost but resolve more queries at the edge
(the gate escalates less); distilled models (llama3.2-3b) have weaker
contextual reasoning and underperform at equal size — both effects flow
through the quality oracle and the tier specs.
"""
from __future__ import annotations

from dataclasses import replace

from benchmarks.common import emit
from repro.cluster.oracle import AccuracyOracle, ArmQuality, DEFAULT_QUALITY
from repro.cluster.simulator import EACOCluster, SimConfig
from repro.core.cost_model import PAPER_EDGE, TierSpec
from repro.data.corpus import wiki_like

# (tier override, edge-arm hit-accuracy delta, slm-only base)
SLM_VARIANTS = {
    "qwen2.5-7b": (TierSpec("edge-7b", 7.0, 1.29, tokens_per_s=55.0,
                            prefill_tokens_per_s=3800.0, base_delay_s=0.02),
                   +0.012, 0.42),
    "qwen2.5-3b": (PAPER_EDGE, 0.0, 0.34),
    "llama3.2-3b": (TierSpec("edge-l3b", 3.0, 1.29, tokens_per_s=110.0,
                             prefill_tokens_per_s=8000.0, base_delay_s=0.02),
                    -0.05, 0.30),
    "qwen2.5-1.5b": (TierSpec("edge-1.5b", 1.5, 1.29, tokens_per_s=140.0,
                              prefill_tokens_per_s=11000.0, base_delay_s=0.02),
                     -0.09, 0.25),
}


def _oracle_for(delta: float, slm_base: float, seed: int) -> AccuracyOracle:
    q = dict(DEFAULT_QUALITY)
    for arm in ("edge-rag+slm", "graphrag+slm"):
        base = q[arm]
        q[arm] = ArmQuality(min(base.p_hit + delta, 0.995),
                            max(base.p_miss + delta, 0.05),
                            base.multihop_factor)
    q["slm-only"] = ArmQuality(slm_base, slm_base, 0.55)
    return AccuracyOracle(q, seed=seed + 1)


def run(n: int = 1200, seed: int = 0, quick: bool = False):
    if quick:
        n = 500
    corpus = wiki_like(seed)
    rows = []
    for name, (tier, delta, slm_base) in SLM_VARIANTS.items():
        cfg = SimConfig(seed=seed, warmup_steps=300, qos_min_acc=0.85,
                        qos_max_delay=5.0)
        sim = EACOCluster(corpus, cfg, policy="eaco", edge_tier=tier,
                          oracle=_oracle_for(delta, slm_base, seed))
        sim.run(n)
        m = sim.metrics()
        rows.append({
            "name": name,
            "accuracy": round(m["accuracy"], 4),
            "delay_s": round(m["delay_mean"], 3),
            "cost_tflops": round(m["cost_mean"], 2),
            "edge_frac": round(sum(m["arm_fracs"][:3]), 3),
        })
    emit(rows, "table6_slms")
    return rows


if __name__ == "__main__":
    run()
