"""Closed-loop cluster benchmark: EACO vs fixed-arm policies through REAL
engines.

Every policy (eaco + the four fixed arms, the paper's Table 4 rows) serves
a bursty multi-user workload end-to-end with ``backend="engines"``: gate
decision -> real retrieval -> real prompt -> TierScheduler -> per-tier
ServingEngine pools (edge SLM engines with paged KV + prefix cache, one
cloud-tier engine) -> completion -> cost model + SafeOBO update. All of it
runs on ONE virtual clock (``engine_time="modeled"``: tier-spec rates
applied to the real token counts, deterministic per seed), so queue waits,
engine service time and network transit compose into the reported delay.

The engine pools are built ONCE and shared across all five policies — the
jitted functions must not retrace as five different traffic mixes stream
through them (checked: <=1 decode trace per engine for the whole bench).

Reported per policy: accuracy / delay / cost (Table 4 structure) plus the
queueing + serving telemetry the oracle backend cannot see (queue wait,
real token counts, prefix-cache hit rate).

Usage:  PYTHONPATH=src:. python benchmarks/cluster_bench.py [--smoke] [--check]
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import emit
from repro.cluster.simulator import EACOCluster, SimConfig
from repro.data.corpus import wiki_like

POLICIES = ["fixed:0", "fixed:1", "fixed:2", "fixed:3", "eaco"]
ARM_NAMES = {0: "slm_only", 1: "edge_rag_slm", 2: "graphrag_slm",
             3: "graphrag_llm"}


def make_cfg(*, smoke: bool, seed: int) -> SimConfig:
    if smoke:
        return SimConfig(
            seed=seed, n_edges=3, warmup_steps=10, qos_min_acc=0.85,
            n_edge_engines=2, edge_max_seq=128, edge_max_batch=2,
            cloud_max_seq=128, cloud_max_batch=2, max_new_slm=8,
            max_new_graph=12, mean_arrivals=1.5, max_arrivals=4,
            hot_topic_boost=0.3)
    return SimConfig(
        seed=seed, n_edges=4, warmup_steps=40, qos_min_acc=0.85,
        n_edge_engines=2, edge_max_seq=192, edge_max_batch=4,
        cloud_max_seq=256, cloud_max_batch=4, max_new_slm=16,
        max_new_graph=48, mean_arrivals=2.0, max_arrivals=6,
        hot_topic_boost=0.3)


def run(smoke: bool = False, steps: int = 0, seed: int = 0,
        check: bool = False):
    steps = steps or (12 if smoke else 60)
    corpus = wiki_like(seed=seed)
    cfg = make_cfg(smoke=smoke, seed=seed)

    # one set of engine pools shared by every policy: build + warm once,
    # then require compile stability across all five traffic mixes
    pools = EACOCluster(corpus, cfg, backend="engines").sched.pools
    for pool in pools.values():
        for e in pool:
            e.warmup([e.max_seq])
    traces0 = {id(e): e.decode_traces
               for pool in pools.values() for e in pool}

    rows = []
    by_policy = {}
    for policy in POLICIES:
        sim = EACOCluster(corpus, cfg, policy=policy, backend="engines",
                          engines=pools)
        t0 = time.perf_counter()
        sim.run(steps)
        wall = time.perf_counter() - t0
        m = sim.metrics(skip_warmup=False)
        by_policy[policy] = (sim, m)
        rows.append({
            "name": policy,
            "n": m["n"],
            "accuracy": round(m["accuracy"], 4),
            "delay_s": round(m["delay_mean"], 3),
            "delay_std": round(m["delay_std"], 3),
            "cost_tflops": round(m["cost_mean"], 2),
            "cost_std": round(m["cost_std"], 2),
            "queue_wait_s": round(m["queue_wait_mean"], 4),
            "in_tokens_mean": round(m["in_tokens_mean"], 1),
            "out_tokens_mean": round(m["out_tokens_mean"], 1),
            "arm_fracs": [round(a, 3) for a in m["arm_fracs"]],
            "virtual_s": round(sim.clock.now(), 2),
            "bench_wall_s": round(wall, 2),
            "unserved": sim.sched.pending() + sim.sched.in_flight(),
        })

    ref = next(r for r in rows if r["name"] == "fixed:3")
    eaco = next(r for r in rows if r["name"] == "eaco")
    red = 100.0 * (1 - eaco["cost_tflops"] / ref["cost_tflops"]) \
        if ref["cost_tflops"] else 0.0
    rows.append({"name": "summary",
                 "eaco_cost_reduction_vs_72b_pct": round(red, 1)})
    for tier_name, pool in pools.items():
        for j, e in enumerate(pool):
            rows.append({
                "name": f"engine/{tier_name}[{j}]",
                "decode_traces": e.decode_traces,
                "decode_retraces": e.decode_traces - traces0[id(e)],
                "decode_rounds": e.decode_rounds,
                "prefill_tokens": e.prefill_tokens,
                "prefix_hits": e.prefix_hits,
                "prefix_misses": e.prefix_misses,
                "prefix_tokens_shared": e.prefix_tokens_shared,
                "peak_resident": e.peak_active,
            })
    emit(rows, "cluster_bench")
    if check:
        _check(rows, by_policy)
        # shared pools served five policies back-to-back; every arena must
        # end quiescent (refcounts match mappings, zero leaked pages)
        for tier_name, pool in pools.items():
            for e in pool:
                e.assert_quiescent()
        print("CLUSTER ARENA OK: all pool engines quiescent, page audits "
              "clean after the full policy sweep")
    return rows


def _check(rows, by_policy):
    ok = True
    msgs = []
    for policy, (sim, m) in by_policy.items():
        if m.get("n", 0) <= 0:
            ok = False
            msgs.append(f"{policy}: served no queries")
            continue
        if sim.sched.pending() or sim.sched.in_flight() or sim._pending:
            ok = False
            msgs.append(f"{policy}: left queries unserved")
        # request conservation: everything submitted reached a typed
        # terminal outcome (completed, shed, or failed) — nothing vanished
        c = sim.counters
        if not sim.conservation_ok() or c["submitted"] != (
                c["completed"] + c["shed"] + c["failed"]):
            ok = False
            msgs.append(f"{policy}: conservation violated: {c}")
        if not sim.sched.conservation_ok():
            ok = False
            msgs.append(f"{policy}: scheduler conservation violated: "
                        f"{sim.sched.counters}")
        if m["delay_mean"] <= 0 or m["cost_mean"] <= 0:
            ok = False
            msgs.append(f"{policy}: non-positive delay/cost")
        fracs = m["arm_fracs"]
        if policy.startswith("fixed:"):
            arm = int(policy.split(":")[1])
            if fracs[arm] != 1.0:
                ok = False
                msgs.append(f"{policy}: served off-policy arms {fracs}")
    for r in rows:
        if r["name"].startswith("engine/") and r["decode_retraces"] != 0:
            ok = False
            msgs.append(f"{r['name']}: {r['decode_retraces']} decode "
                        "retraces across the policy sweep")
    # the cost structure that makes the gate's problem non-trivial must
    # survive the engines backend: always-72B costs far more than SLM-only
    c0 = next(r for r in rows if r["name"] == "fixed:0")["cost_tflops"]
    c3 = next(r for r in rows if r["name"] == "fixed:3")["cost_tflops"]
    if not c3 > 5 * c0:
        ok = False
        msgs.append(f"cost structure collapsed: fixed:3={c3} vs fixed:0={c0}")
    if not ok:
        print("CLUSTER CHECK FAILED: " + "; ".join(msgs))
        sys.exit(1)
    s = next(r for r in rows if r["name"] == "summary")
    print(f"CLUSTER CHECK OK: all policies served end-to-end through real "
          f"engine pools on one virtual clock, request conservation holds "
          f"(submitted == completed + shed + failed), zero decode retraces "
          f"per engine, eaco cost reduction vs 72B "
          f"{s['eaco_cost_reduction_vs_72b_pct']}%")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI smoke runs")
    ap.add_argument("--steps", type=int, default=0,
                    help="arrival steps per policy (0 = size default)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless every policy serves all "
                         "queries through the engines with zero decode "
                         "retraces and a sane cost structure")
    args = ap.parse_args()
    run(smoke=args.smoke, steps=args.steps, seed=args.seed, check=args.check)
