"""Paper Table 4: overall comparison of EACO-RAG vs fixed baselines on both
corpora under cost-efficient (delay<=5s) and delay-oriented (delay<=1s)
settings. Reports accuracy / delay / cost and the cost reduction vs the
always-72B+GraphRAG baseline (the paper's 84.6% / 65.3% claims)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.cluster.simulator import EACOCluster, SimConfig
from repro.data.corpus import specialized_like, wiki_like

BASELINES = {
    "3b_llm_only": "fixed:0",
    "3b_naive_rag": "fixed:1",
    "3b_graphrag": "fixed:2",
    "72b_graphrag": "fixed:3",
}

# (setting name, qos_min_acc, qos_max_delay, warmup).
# Delay-oriented uses the strict 1 s bound: on wiki-like traffic the gate
# keeps fast edge paths for covered queries and shifts the rest cloud-ward;
# on the specialized corpus (longer retrieval prompts push the edge path
# over 1 s) it escalates much harder — the paper's wiki/HP asymmetry
# (their delay-oriented costs: 247 vs 496 TFLOPs).
EACO_SETTINGS = [
    ("eaco_cost_efficient", 0.85, 5.0, 300),
    ("eaco_delay_oriented", 0.85, 1.0, 300),
]


def run(n_fixed: int = 400, n_eaco: int = 1500, seed: int = 0,
        quick: bool = False):
    if quick:
        n_fixed, n_eaco = 150, 500
    rows = []
    for corpus_name, corpus_fn in [("wiki", wiki_like), ("hp", specialized_like)]:
        corpus = corpus_fn(seed)
        ref_cost = None
        for name, pol in BASELINES.items():
            sim = EACOCluster(corpus, SimConfig(seed=seed), policy=pol)
            sim.run(n_fixed)
            m = sim.metrics(skip_warmup=False)
            if name == "72b_graphrag":
                ref_cost = m["cost_mean"]
            rows.append({
                "name": f"{corpus_name}/{name}",
                "accuracy": round(m["accuracy"], 4),
                "delay_s": round(m["delay_mean"], 3),
                "delay_std": round(m["delay_std"], 3),
                "cost_tflops": round(m["cost_mean"], 2),
                "cost_std": round(m["cost_std"], 2),
            })
        for name, qa, qd, warm in EACO_SETTINGS:
            sim = EACOCluster(
                corpus, SimConfig(seed=seed, qos_min_acc=qa,
                                  qos_max_delay=qd, warmup_steps=warm),
                policy="eaco")
            sim.run(n_eaco)
            m = sim.metrics()
            red = 100.0 * (1 - m["cost_mean"] / ref_cost) if ref_cost else 0.0
            rows.append({
                "name": f"{corpus_name}/{name}",
                "accuracy": round(m["accuracy"], 4),
                "delay_s": round(m["delay_mean"], 3),
                "cost_tflops": round(m["cost_mean"], 2),
                "cost_reduction_vs_72b_pct": round(red, 1),
                "arm_fracs": [round(a, 3) for a in m["arm_fracs"]],
            })
    emit(rows, "table4_overall")
    return rows


if __name__ == "__main__":
    run()
