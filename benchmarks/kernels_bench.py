"""Kernel microbenchmarks: Pallas (interpret mode on CPU) vs pure-jnp ref.

On CPU interpret mode measures Python-level emulation (NOT TPU perf); the
derived column reports the kernel's analytic FLOPs so the roofline math can
be checked. On a real TPU backend the same harness times the compiled
kernels.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels.decode_attention.kernel import (
    decode_attention_pallas, paged_decode_attention_pallas,
)
from repro.kernels.decode_attention.ref import (
    decode_attention_ref, paged_decode_attention_ref,
)
from repro.kernels.retrieval_topk.kernel import retrieval_topk_pallas
from repro.kernels.retrieval_topk.ref import retrieval_topk_ref
from repro.kernels.rbf.kernel import rbf_matrix_pallas
from repro.kernels.rbf.ref import rbf_matrix_ref


def _time(fn, *args, iters: int = 5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def run(quick: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)

    B, H, KV, hd, S = 4, 8, 2, 128, 1024 if not quick else 256
    q = jax.random.normal(key, (B, H, hd), jnp.float32)
    k = jax.random.normal(key, (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(key, (B, S, KV, hd), jnp.float32)
    lens = jnp.full((B,), S, jnp.int32)
    flops = 4 * B * H * hd * S
    rows.append({
        "name": "decode_attention/pallas-interpret",
        "us_per_call": round(_time(decode_attention_pallas, q, k, v, lens), 1),
        "derived_flops": flops,
    })
    rows.append({
        "name": "decode_attention/jnp-ref",
        "us_per_call": round(_time(decode_attention_ref, q, k, v, lens), 1),
        "derived_flops": flops,
    })

    # paged variant at the same (B, H, KV, hd, S) geometry: S split into
    # page_size chunks scattered across a 2x-overprovisioned arena
    ps = 16
    n_pages = S // ps
    P = 2 * B * n_pages + 1
    rng = np.random.default_rng(0)
    perm = rng.permutation(np.arange(1, P))[: B * n_pages]
    pt = jnp.asarray(perm.reshape(B, n_pages).astype(np.int32))
    k_arena = jax.random.normal(key, (P, ps, KV, hd), jnp.float32)
    v_arena = jax.random.normal(key, (P, ps, KV, hd), jnp.float32)
    flops = 4 * B * H * hd * S
    rows.append({
        "name": "paged_decode_attention/pallas-interpret",
        "us_per_call": round(_time(paged_decode_attention_pallas,
                                   q, k_arena, v_arena, pt, lens), 1),
        "derived_flops": flops,
    })
    rows.append({
        "name": "paged_decode_attention/jnp-ref",
        "us_per_call": round(_time(paged_decode_attention_ref,
                                   q, k_arena, v_arena, pt, lens), 1),
        "derived_flops": flops,
    })

    N, D, K = (4096 if not quick else 1024), 384, 5
    emb = jax.random.normal(key, (N, D), jnp.float32)
    qv = jax.random.normal(key, (D,), jnp.float32)
    flops = 2 * N * D
    rows.append({
        "name": "retrieval_topk/pallas-interpret",
        "us_per_call": round(_time(
            lambda e, x: retrieval_topk_pallas(e, x, K), emb, qv), 1),
        "derived_flops": flops,
    })
    rows.append({
        "name": "retrieval_topk/jnp-ref",
        "us_per_call": round(_time(
            lambda e, x: retrieval_topk_ref(e, x, K), emb, qv), 1),
        "derived_flops": flops,
    })

    M = 512 if not quick else 128
    x1 = jax.random.normal(key, (M, 11), jnp.float32)
    flops = 2 * M * M * 11
    rows.append({
        "name": "rbf/pallas-interpret",
        "us_per_call": round(_time(
            lambda a: rbf_matrix_pallas(a, a, 1.0, 1.0), x1), 1),
        "derived_flops": flops,
    })
    rows.append({
        "name": "rbf/jnp-ref",
        "us_per_call": round(_time(
            lambda a: rbf_matrix_ref(a, a, 1.0, 1.0), x1), 1),
        "derived_flops": flops,
    })
    emit(rows, "kernels_bench")
    return rows


if __name__ == "__main__":
    run()
