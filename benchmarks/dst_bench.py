"""DST seed-sweep driver: randomized chaos schedules + always-on oracles.

Where ``chaos_bench`` replays a handful of hand-authored fault schedules,
this bench runs the :mod:`repro.cluster.dst` fuzzer: for each seed it
generates a random timeline composing the full fault vocabulary (engine
crash/restart, partition/heal, stalls, net-delay spikes, completion
drops, knowledge-update bursts, arrival bursts, SLO-mix shifts), drives
real engine pools + scheduler + knowledge layer through it on the virtual
clock, and re-checks every invariant oracle after every pump: request
conservation, generation-fence legality, breaker state-machine legality,
monotone knowledge epochs (no unflagged ``stale_epoch`` completions),
page-arena audit (free+cached+active == num_pages, refcount == slot
mappings, zero leaks at quiescence), greedy token identity, and a
virtual-time wedge guard.

``--check`` gates:
  * every seed in the sweep is green (any failure is auto-shrunk and the
    minimized trace written under ``results/dst/`` for CI to upload);
  * the sweep exercised the whole fault vocabulary and the recovery
    machinery actually ran (crashes AND restarts, partition heals,
    knowledge ships, deliveries);
  * replaying recorded traces reproduces their oracle snapshot streams
    BYTE-identically (canonical JSON compare);
  * the fuzzer catches an intentionally planted bug (a skipped refcount
    decrement), ddmin-shrinks the failing schedule to <= 5 events, the
    minimized schedule still fails with the same oracle, and the same
    schedule without the bug passes (the failure is the bug, not noise).

Usage:  PYTHONPATH=src:. python benchmarks/dst_bench.py \
            [--smoke] [--check] [--seed N] [--seeds K] [--bug NAME]
        PYTHONPATH=src:. python benchmarks/dst_bench.py --replay TRACE.json
        PYTHONPATH=src:. python benchmarks/dst_bench.py --shrink TRACE.json
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from benchmarks.common import emit
from repro.cluster.dst import (
    BUGS, DSTHarness, FaultEvent, generate_schedule, load_trace,
    make_failure_predicate, replay_trace, run_dst, save_trace,
    shrink_schedule,
)

TRACE_DIR = Path(__file__).resolve().parents[1] / "results" / "dst"


def _sweep(harness, seed0: int, n_seeds: int, bug=None):
    """Run ``n_seeds`` schedules; shrink + persist any failure."""
    agg = {"seeds": n_seeds, "failures": 0, "pumps": 0, "events": 0,
           "crashes": 0, "restarts": 0, "partitions": 0, "heals": 0,
           "ships": 0, "defers": 0, "syncs": 0, "delivered": 0,
           "dropped": 0, "shed": 0, "stale_served": 0, "hedged": 0,
           "preempted": 0, "requeued_lost": 0}
    kinds = set()
    results = []
    for s in range(seed0, seed0 + n_seeds):
        res = run_dst(s, harness=harness, bug=bug)
        results.append(res)
        agg["pumps"] += res.n_pumps
        agg["events"] += len(res.events)
        kinds.update(e.kind for e in res.events)
        for k in ("crashes", "restarts", "partitions", "heals", "ships",
                  "defers", "syncs", "delivered", "dropped", "shed",
                  "stale_served"):
            agg[k] += res.ledger[k]
        for k in ("hedged", "preempted", "requeued_lost"):
            agg[k] += res.counters[k]
        if res.failure is not None:
            agg["failures"] += 1
            print(f"  seed {s} FAILED [{res.failure_oracle}]: "
                  f"{res.failure[:160]}")
            pred = make_failure_predicate(harness, inj_seed=s, bug=bug,
                                          oracle=res.failure_oracle)
            mini = shrink_schedule(res.events, pred)
            mres = harness.run(mini, seed=s, inj_seed=s, bug=bug)
            path = save_trace(mres, str(TRACE_DIR / f"seed{s}.min.json"))
            print(f"  seed {s}: shrunk {len(res.events)} -> {len(mini)} "
                  f"events; minimized trace at {path}")
    agg["kinds_covered"] = len(kinds)
    return agg, results


def run_drill(harness, seed0: int):
    """Plant the skipped-refcount-decrement bug, prove the fuzzer catches
    it, shrink to a minimal repro, and verify the minimized schedule is
    the bug (fails with it, passes without it)."""
    drill = {"name": "drill-leak_page", "caught_seed": None,
             "events_before": 0, "events_after": 0,
             "min_still_fails": False, "clean_passes": False,
             "oracle": None}
    for s in range(seed0, seed0 + 10):
        events = generate_schedule(s, harness.cfg)
        res = harness.run(events, seed=s, inj_seed=s, bug="leak_page")
        if res.failure is not None:
            drill["caught_seed"] = s
            drill["oracle"] = res.failure_oracle
            drill["events_before"] = len(events)
            pred = make_failure_predicate(harness, inj_seed=s,
                                          bug="leak_page",
                                          oracle=res.failure_oracle)
            mini = shrink_schedule(events, pred)
            drill["events_after"] = len(mini)
            mres = harness.run(mini, seed=s, inj_seed=s, bug="leak_page")
            drill["min_still_fails"] = (
                mres.failure_oracle == res.failure_oracle)
            clean = harness.run(mini, seed=s, inj_seed=s)
            drill["clean_passes"] = clean.failure is None
            save_trace(mres, str(TRACE_DIR / "drill_leak_page.min.json"))
            break
    return drill


def run(quick: bool = False, check: bool = False, seed: int = 0,
        n_seeds=None, bug=None):
    n_seeds = (8 if quick else 50) if n_seeds is None else n_seeds
    harness = DSTHarness()
    print(f"dst sweep: {n_seeds} seeds from {seed}"
          + (f" with planted bug {bug!r}" if bug else ""))
    agg, results = _sweep(harness, seed, n_seeds, bug=bug)

    n_replay = min(2 if quick else 3, len(results))
    replay = {"name": "replay", "replayed": 0, "matched": 0}
    for res in results[:n_replay]:
        _, ok = replay_trace(res.trace(), harness)
        replay["replayed"] += 1
        replay["matched"] += int(ok)

    drill = run_drill(harness, seed)

    rows = [dict(name="sweep", **agg), replay, drill]
    emit(rows, "dst_bench")

    if not check:
        return 0

    failures = []

    def gate(cond, msg):
        print(f"  [{'PASS' if cond else 'FAIL'}] {msg}")
        if not cond:
            failures.append(msg)

    print("dst gates:")
    gate(agg["failures"] == 0,
         f"all {n_seeds} seeds green, every oracle, every pump "
         f"({agg['pumps']} pumps checked; {agg['failures']} failures)")
    gate(agg["kinds_covered"] >= 8,
         f"schedules cover the full event vocabulary "
         f"({agg['kinds_covered']}/8 kinds)")
    gate(agg["crashes"] >= 1 and agg["restarts"] >= 1,
         f"crash/restart machinery exercised "
         f"({agg['crashes']}/{agg['restarts']})")
    gate(agg["partitions"] >= 1 and agg["heals"] >= 1,
         f"partition/heal exercised ({agg['partitions']}/{agg['heals']})")
    gate(agg["ships"] >= 1 and agg["delivered"] >= 1,
         f"knowledge ships and deliveries occurred "
         f"({agg['ships']} ships, {agg['delivered']} delivered)")
    gate(replay["matched"] == replay["replayed"] and replay["replayed"] > 0,
         f"replay-from-trace byte-identical "
         f"({replay['matched']}/{replay['replayed']})")
    gate(drill["caught_seed"] is not None,
         f"planted refcount-decrement bug caught by oracle "
         f"{drill['oracle']} (seed {drill['caught_seed']})")
    gate(0 < drill["events_after"] <= 5,
         f"failing schedule shrunk to <= 5 events "
         f"({drill['events_before']} -> {drill['events_after']})")
    gate(drill["min_still_fails"],
         "minimized schedule still fails with the same oracle")
    gate(drill["clean_passes"],
         "minimized schedule passes without the planted bug")

    if failures:
        print(f"{len(failures)} gate(s) FAILED")
        return 1
    print("all dst gates passed")
    return 0


def do_replay(path: str) -> int:
    trace = load_trace(path)
    res, ok = replay_trace(trace, DSTHarness())
    print(f"replayed {len(trace['events'])} events, "
          f"{res.n_pumps} pumps, outcome "
          f"{res.failure_oracle or 'green'} "
          f"(recorded: {trace.get('failure_oracle') or 'green'})")
    print("byte-identical snapshots" if ok else "SNAPSHOT MISMATCH")
    return 0 if ok else 1


def do_shrink(path: str) -> int:
    trace = load_trace(path)
    harness = DSTHarness()
    events = [FaultEvent.from_dict(d) for d in trace["events"]]
    pred = make_failure_predicate(
        harness, inj_seed=int(trace.get("inj_seed", 0)),
        bug=trace.get("bug"), oracle=trace.get("failure_oracle"))
    mini = shrink_schedule(events, pred, log=print)
    res = harness.run(mini, seed=trace.get("seed"),
                      inj_seed=int(trace.get("inj_seed", 0)),
                      bug=trace.get("bug"))
    out = str(Path(path).with_suffix("")) + ".min.json"
    save_trace(res, out)
    print(f"shrunk {len(events)} -> {len(mini)} events; minimized trace "
          f"at {out}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep (8 seeds)")
    ap.add_argument("--check", action="store_true",
                    help="evaluate acceptance gates; exit 1 on failure")
    ap.add_argument("--seed", type=int, default=0, help="first seed")
    ap.add_argument("--seeds", type=int, default=None,
                    help="number of seeds (default 50; 8 with --smoke)")
    ap.add_argument("--bug", choices=BUGS, default=None,
                    help="plant a known bug and watch the fuzzer find it")
    ap.add_argument("--replay", metavar="TRACE",
                    help="replay a recorded trace; exit 1 on divergence")
    ap.add_argument("--shrink", metavar="TRACE",
                    help="ddmin-minimize a failing recorded trace")
    args = ap.parse_args(argv)
    if args.replay:
        return do_replay(args.replay)
    if args.shrink:
        return do_shrink(args.shrink)
    return run(quick=args.smoke, check=args.check, seed=args.seed,
               n_seeds=args.seeds, bug=args.bug)


if __name__ == "__main__":
    sys.exit(main())
