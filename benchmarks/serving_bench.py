"""Serving benchmarks on a heavy-tailed mixed-length stream.

Four comparisons (reduced qwen2-0.5b, byte tokenizer):

1. static vs continuous batching (PR 1): rigid ``max_batch`` batches with
   head-of-line blocking vs a TierScheduler streaming the slot pool.
2. paged vs contiguous KV layout (PR 2): a contiguous engine reserves a
   worst-case ``[max_batch, max_seq]`` lane per slot; the paged engine gets
   the SAME KV token capacity as a page arena but 4x the slots, so resident
   requests are bounded by actual token demand instead of worst-case lanes.
   Reports tokens/s (target: within 5%), peak resident requests (target:
   >=2x at equal cache memory), KV bytes, and decode re-traces (must be 0).
3. prefix-cached vs plain paged (PR 3): the EACO-RAG edge scenario — N
   requests grounded in the SAME retrieved context, sharing a long prompt
   prefix at 0% / 50% / 90% share fractions. The prefix cache maps shared
   pages + CoW tail and prefills only the unique suffix, so aggregate
   prefill throughput (prompt tokens per engine prefill-second; shared
   tokens count — they were served) rises with the share fraction and the
   smaller per-request footprint packs more concurrent residents into the
   same arena. Targets at 90% share: >=2x prefill throughput, more peak
   residents, token-identical greedy output, zero decode retraces, prefill
   traces bounded by the power-of-two bucket count.
4. fused chunked-prefill + decode (this PR): a mixed 70/30
   interactive/batch arrival stream on the virtual clock (PAPER_EDGE
   modeled service times, exactly the cluster simulator's pricing), whole-
   suffix admission vs the token-budget fused step at several budgets.
   Batch prompts are long (prefill-heavy), interactive prompts short:
   whole-suffix admission charges every co-admitted prompt's FULL prefill
   to the round the interactive request's first token lands in, while the
   fused step admits host-only and steers the chunk budget interactive-
   first, so interactive TTFT collapses to ~one mixed step. Targets at
   full size: interactive p95 TTFT >=1.5x better than whole-suffix,
   aggregate decode tokens/s within 10%, greedy token-identical output,
   zero decode/fused retraces after warmup.

All paths share warmed-up fixed-shape jitted functions, so the measured
deltas are pure scheduling / memory layout / prefill compute.

Usage:  PYTHONPATH=src:. python benchmarks/serving_bench.py [--smoke] [--check]
"""
from __future__ import annotations

import argparse
import bisect
import sys
import time

import numpy as np

from benchmarks.common import emit
from repro.serving import Request, TierScheduler, make_edge_engine

PAGE_SIZE = 16
PAGED_SLOT_MULT = 4          # paged engine: 4x slots at equal KV memory


def mixed_workload(n: int, seed: int, min_prompt=8, max_prompt=200,
                   min_new=4, max_new=64):
    """Serving-shaped mix: lengths are log-uniform over the given ranges
    (heavy-tailed, like real chat traffic — many short requests, a long
    tail), which is what makes static batching pay for head-of-line
    blocking and contiguous lanes pay for worst-case reservation."""
    rng = np.random.default_rng(seed)

    def log_uniform(lo, hi):
        return int(np.exp(rng.uniform(np.log(lo), np.log(hi))))

    reqs = []
    for i in range(n):
        plen = log_uniform(min_prompt, max_prompt)
        new = log_uniform(min_new, max_new)
        # byte tokenizer: prompt length in tokens == chars + BOS
        reqs.append(Request("q" * plen, max_new_tokens=new))
    return reqs


def run_static(eng, reqs):
    """Rigid batches of max_batch; per-request latency = its batch's end."""
    lat = []
    tokens = 0
    t0 = time.perf_counter()
    for i in range(0, len(reqs), eng.max_batch):
        _, stats = eng.generate_static(reqs[i:i + eng.max_batch])
        t_batch = time.perf_counter() - t0
        lat.extend([t_batch] * min(eng.max_batch, len(reqs) - i))
        tokens += stats.new_tokens
    return tokens, time.perf_counter() - t0, lat


def run_continuous(eng, reqs):
    """All requests queued up front; the scheduler keeps the lanes full."""
    sched = TierScheduler({"edge": eng})
    t0 = time.perf_counter()
    for r in reqs:
        sched.submit(r, "edge")
    lat = []
    tokens = 0
    while sched.pending() or sched.in_flight():
        for c in sched.pump():
            lat.append(time.perf_counter() - t0)
            tokens += c.new_tokens
    return tokens, time.perf_counter() - t0, lat


def _row(name, tokens, wall, lat, **extra):
    r = {
        "name": name,
        "requests": len(lat),
        "new_tokens": tokens,
        "tokens_per_s": round(tokens / wall, 1),
        "wall_s": round(wall, 2),
        "p50_latency_s": round(float(np.percentile(lat, 50)), 2),
        "p95_latency_s": round(float(np.percentile(lat, 95)), 2),
    }
    r.update(extra)
    return r


def run(quick: bool = False, n_requests: int = 64, max_batch: int = 8,
        max_seq: int = 384, seed: int = 0, check: bool = False):
    if quick:
        n_requests, max_batch, max_seq = 10, 4, 128
    eng = make_edge_engine(max_seq=max_seq, max_batch=max_batch, seed=0)
    kw = dict(max_prompt=min(200, max_seq - 70)) if max_seq < 280 else {}
    reqs = mixed_workload(n_requests, seed, **kw)
    # compile everything (decode/sample/insert + every prefill bucket) so the
    # timed phases compare scheduling, not tracing
    eng.warmup(len(eng.tok.encode(r.prompt)) for r in reqs)
    traces0 = dict(eng.trace_counts)

    tok_s, wall_s, lat_s = run_static(eng, reqs)
    tok_c, wall_c, lat_c = run_continuous(eng, reqs)
    eng.assert_quiescent()   # page arena must be leak-free after the stream
    retraces = eng.trace_counts["decode"] - traces0["decode"]

    speedup = (tok_c / wall_c) / (tok_s / wall_s)
    rows = [
        _row("static", tok_s, wall_s, lat_s),
        _row("continuous", tok_c, wall_c, lat_c),
        {"name": "summary", "throughput_speedup": round(speedup, 2),
         "decode_retraces_after_warmup": retraces,
         "decode_traces_total": eng.decode_traces},
    ]
    rows += run_paged_vs_contiguous(n_requests=n_requests,
                                    base_batch=max_batch, max_seq=max_seq,
                                    seed=seed, quick=quick)
    rows += run_prefix_scenarios(n_requests=n_requests,
                                 max_batch=max_batch, max_seq=max_seq,
                                 seed=seed, quick=quick)
    rows += run_fused_scenarios(n_requests=n_requests, max_seq=max_seq,
                                seed=seed, quick=quick)
    emit(rows, "serving_bench")
    if check:
        # tiny smoke runs are noisy: only the full-size bench gates on perf
        need = 1.0 if quick else 1.5
        ok = speedup >= need and retraces == 0 and tok_s == tok_c
        if not ok:
            print(f"CHECK FAILED: speedup={speedup:.2f} (need >={need}), "
                  f"retraces={retraces} (need 0), "
                  f"tokens {tok_s} vs {tok_c} (must match)")
            sys.exit(1)
        print(f"CHECK OK: speedup={speedup:.2f} (>={need}), zero decode "
              f"retraces, token counts match, page arenas quiescent")
        _check_paged(rows, quick)
        _check_prefix(rows, quick)
        _check_fused(rows, quick)
    return rows


def run_paged_vs_contiguous(*, n_requests: int, base_batch: int,
                            max_seq: int, seed: int, quick: bool):
    """Same stream, equal KV token capacity: contiguous ``base_batch`` lanes
    vs a page arena of ``base_batch * max_seq / PAGE_SIZE`` pages behind
    ``PAGED_SLOT_MULT * base_batch`` slots."""
    kw = dict(max_prompt=min(200, max_seq - 70)) if max_seq < 280 else {}
    reqs = mixed_workload(n_requests, seed, **kw)

    def build(layout, mb, **ekw):
        e = make_edge_engine(max_seq=max_seq, max_batch=mb, seed=0,
                             kv_layout=layout, **ekw)
        e.warmup(len(e.tok.encode(r.prompt)) for r in reqs)
        return e

    cont = build("contiguous", base_batch)
    paged = build("paged", PAGED_SLOT_MULT * base_batch, page_size=PAGE_SIZE,
                  num_pages=base_batch * (max_seq // PAGE_SIZE))
    assert paged.kv_cache_tokens == cont.kv_cache_tokens

    rows = []
    for name, e in (("kv-contiguous", cont), ("kv-paged", paged)):
        t0 = dict(e.trace_counts)
        tokens, wall, lat = run_continuous(e, reqs)
        e.assert_quiescent()
        rows.append(_row(
            name, tokens, wall, lat,
            max_batch=e.max_batch,
            peak_resident=e.peak_active,
            kv_capacity_tokens=e.kv_cache_tokens,
            kv_cache_mib=round(e.kv_cache_bytes / 2**20, 2),
            decode_retraces=e.trace_counts["decode"] - t0["decode"]))
    c, p = rows
    rows.append({
        "name": "paged-summary",
        "tokens_per_s_ratio": round(p["tokens_per_s"] / c["tokens_per_s"], 3),
        "resident_ratio": round(p["peak_resident"] / c["peak_resident"], 2),
        "equal_kv_capacity": p["kv_capacity_tokens"] == c["kv_capacity_tokens"],
    })
    return rows


def prefix_workload(n: int, share: float, prompt_len: int, max_new: int,
                    seed: int):
    """The EACO-RAG edge pattern: every request is grounded in the SAME
    retrieved context (``share`` of the prompt) followed by a unique
    question. share=0 degenerates to fully distinct prompts."""
    rng = np.random.default_rng(seed)
    letters = "abcdefghijklmnopqrstuvwxyz "
    ctx_len = int(prompt_len * share)
    ctx = "".join(letters[i] for i in rng.integers(len(letters), size=ctx_len))
    reqs = []
    for i in range(n):
        tail_len = max(prompt_len - ctx_len, 4)
        uniq = f"Q{i}:" + "".join(
            letters[j] for j in rng.integers(len(letters), size=tail_len))
        reqs.append(Request(ctx + uniq[:tail_len], max_new_tokens=max_new))
    return reqs


def run_prefix_scenarios(*, n_requests: int, max_batch: int, max_seq: int,
                         seed: int, quick: bool):
    """Prefix-heavy RAG scenario at several share fractions: prefix cache on
    vs off on the SAME page arena, deliberately sized so page capacity (not
    slots) binds residency — sharing must both cut prefill compute and pack
    more concurrent residents."""
    n_requests = max(8, n_requests // 2)   # gates don't need the full mix
    prompt_len = 48 if quick else max(96, min(192, max_seq - 96))
    max_new = 4 if quick else 8
    pages_per_req = -(-(prompt_len + 1 + max_new) // PAGE_SIZE)
    num_pages = max(max_seq // PAGE_SIZE,
                    (2 if quick else 3) * pages_per_req)

    rows = []
    for share in (0.0, 0.5, 0.9):
        reqs = prefix_workload(n_requests, share, prompt_len, max_new, seed)
        outs = {}
        for mode in ("off", "on"):
            eng = make_edge_engine(max_seq=max_seq, max_batch=max_batch,
                                   seed=0, page_size=PAGE_SIZE,
                                   num_pages=num_pages,
                                   prefix_cache=(mode == "on"))
            eng.warmup([prompt_len + 1])   # every pow2 bucket <= its pad
            traces0 = dict(eng.trace_counts)
            t0 = time.perf_counter()
            texts, stats = eng.generate(reqs)
            wall = time.perf_counter() - t0
            eng.assert_quiescent()
            outs[mode] = texts
            prefill_tput = (stats.prompt_tokens / stats.prefill_s
                            if stats.prefill_s > 0 else 0.0)
            rows.append({
                "name": f"prefix-{mode}-{int(share * 100)}",
                "share": share,
                "requests": len(reqs),
                "prompt_tokens": stats.prompt_tokens,
                "prefill_s": round(stats.prefill_s, 3),
                "prefill_tokens_per_s": round(prefill_tput, 1),
                "wall_s": round(wall, 2),
                "peak_resident": eng.peak_active,
                "prefix_hits": stats.prefix_hits,
                "prefix_misses": stats.prefix_misses,
                "prefix_tokens_shared": stats.prefix_tokens_shared,
                "prefix_hit_rate": round(stats.prefix_hit_rate, 3),
                "prefill_traces_total": eng.trace_counts["prefill"],
                "prefill_retraces_after_warmup":
                    eng.trace_counts["prefill"] - traces0["prefill"],
                "decode_retraces":
                    eng.trace_counts["decode"] - traces0["decode"],
                "pow2_buckets": len(eng.pad_buckets),
            })
        on = rows[-1]
        off = rows[-2]
        rows.append({
            "name": f"prefix-summary-{int(share * 100)}",
            "share": share,
            "prefill_speedup": round(
                on["prefill_tokens_per_s"] / off["prefill_tokens_per_s"], 2),
            "resident_gain": on["peak_resident"] - off["peak_resident"],
            "tokens_identical": outs["on"] == outs["off"],
            "hit_rate": on["prefix_hit_rate"],
        })
    return rows


def fused_workload(n: int, seed: int, max_seq: int):
    """~70/30 interactive/batch arrival stream. Interactive prompts are
    short with few new tokens (TTFT is what matters); batch prompts are
    long (prefill-heavy — the EACO-RAG retrieved-context shape) with more
    decode work. Most batch arrivals carry an interactive request in the
    same burst (zero gap), which is what makes whole-suffix admission
    co-admit the batch prompt's full prefill into the interactive
    request's first round. Prompts are unique (no prefix sharing) so both
    engine modes do identical prefill work."""
    rng = np.random.default_rng(seed)
    letters = list("abcdefgh ")
    b_lo = int(max_seq * 0.60)
    b_hi = min(int(max_seq * 0.85), max_seq - 30)

    def spec(slo, plen, new, k):
        prompt = f"{slo[0]}{k} " + "".join(
            rng.choice(letters, max(plen - 5, 1)))
        return (slo, prompt, new)

    specs, arrivals = [], []
    t = 0.0
    while len(specs) < n:
        if rng.random() < 0.3:
            t += float(rng.exponential(0.10))
            specs.append(spec("batch", int(rng.integers(b_lo, b_hi)),
                              int(rng.integers(16, 25)), len(specs)))
            arrivals.append(t)
            if len(specs) < n and rng.random() < 0.8:
                # an interactive request rides the same burst
                specs.append(spec("interactive", int(rng.integers(8, 28)),
                                  8, len(specs)))
                arrivals.append(t)
        else:
            t += float(rng.exponential(0.045))
            specs.append(spec("interactive", int(rng.integers(8, 28)),
                              8, len(specs)))
            arrivals.append(t)
    return specs, arrivals


def run_fused_scenarios(*, n_requests: int, max_seq: int, seed: int,
                        quick: bool):
    """Whole-suffix admission vs the fused token-budget step on the SAME
    arrival stream, each arm on its own virtual clock with PAPER_EDGE
    modeled service times (the cluster simulator's pricing): per pump,
    the clock advances by ``modeled_prefill_s(Δprefill_tokens) + Δrounds *
    modeled_decode_round_s``. TTFT comes from ``Completion.ttft_s``
    (scheduler clock), snapped to the end of the round that computed the
    first token (engine timestamps are round STARTS — the clock only
    advances after the pump that did the work)."""
    from repro.core.clock import VirtualClock
    from repro.core.cost_model import (
        PAPER_EDGE, modeled_decode_round_s, modeled_prefill_s,
    )

    n = max(12, (2 * n_requests) // 3)
    max_batch = 8
    chunk = 16 if quick else 64
    budgets = [16] if quick else [32, 64]
    specs, arrivals = fused_workload(n, seed, max_seq)

    def drive(budget):
        clock = VirtualClock()
        kw = {} if budget is None else dict(step_token_budget=budget,
                                            prefill_chunk=chunk)
        eng = make_edge_engine(max_seq=max_seq, max_batch=max_batch,
                               seed=0, clock=clock, **kw)
        eng.warmup(len(eng.tok.encode(p)) for _, p, _ in specs)
        traces0 = dict(eng.trace_counts)
        d0 = eng.decode_rounds
        reqs = [Request(p, max_new_tokens=new, slo=slo)
                for slo, p, new in specs]
        sched = TierScheduler({"edge": eng}, clock=clock)
        pend = list(zip(arrivals, reqs))
        sub_t, comps, bounds = {}, {}, []
        idle_since = None
        while pend or sched.pending() or sched.in_flight():
            now = clock.now()
            while pend and pend[0][0] <= now + 1e-12:
                _, r = pend.pop(0)
                sub_t[id(r)] = now
                sched.submit(r, "edge", now=now)
            pp, dd = eng.prefill_tokens, eng.decode_rounds
            for c in sched.pump(now=now):
                comps[id(c.request)] = c
            dt = (modeled_prefill_s(PAPER_EDGE, eng.prefill_tokens - pp)
                  + (eng.decode_rounds - dd)
                  * modeled_decode_round_s(PAPER_EDGE))
            if dt > 0:
                clock.advance(dt)
                bounds.append(clock.now())
                idle_since = None
                continue
            idle_since = now if idle_since is None else idle_since
            if now - idle_since > 30.0:
                raise RuntimeError(
                    f"fused scenario wedged at t={now:.2f}: "
                    f"{sched.pending()} queued, {sched.in_flight()} resident")
            clock.advance(max(pend[0][0] - now, 1e-3) if pend else 1e-3)
        eng.assert_quiescent()

        ttft = {}
        for r in reqs:
            tau = sub_t[id(r)] + comps[id(r)].ttft_s
            j = bisect.bisect_right(bounds, tau + 1e-9)
            end = bounds[j] if j < len(bounds) else bounds[-1]
            ttft[id(r)] = end - sub_t[id(r)]

        def p95(xs):
            return float(np.percentile(xs, 95)) if xs else 0.0

        inter = [ttft[id(r)] for r in reqs if r.slo == "interactive"]
        batch = [ttft[id(r)] for r in reqs if r.slo == "batch"]
        new_tokens = sum(c.new_tokens for c in comps.values())
        rounds = eng.decode_rounds - d0
        return {
            "texts": [comps[id(r)].text for r in reqs],
            "interactive_p95_ttft_s": p95(inter),
            "batch_p95_ttft_s": p95(batch),
            "decode_tokens_per_s":
                new_tokens / max(rounds * modeled_decode_round_s(PAPER_EDGE),
                                 1e-9),
            "new_tokens": new_tokens,
            "makespan_s": clock.now(),
            "decode_retraces": eng.trace_counts["decode"] - traces0["decode"],
            "fused_retraces": eng.trace_counts["fused"] - traces0["fused"],
            "mixed_steps": eng.mixed_steps,
            "prefill_chunks": eng.prefill_chunks,
            "budget_utilization": eng.budget_utilization,
            "preempted": sched.counters["preempted"],
        }

    arms = [("whole-suffix", None)] + [(f"budget-{b}", b) for b in budgets]
    res = {}
    rows = []
    for name, budget in arms:
        r = drive(budget)
        res[name] = r
        rows.append({
            "name": f"fused-{name}",
            "requests": n,
            "interactive_p95_ttft_ms":
                round(r["interactive_p95_ttft_s"] * 1e3, 1),
            "batch_p95_ttft_ms": round(r["batch_p95_ttft_s"] * 1e3, 1),
            "decode_tokens_per_s": round(r["decode_tokens_per_s"], 1),
            "new_tokens": r["new_tokens"],
            "makespan_virtual_s": round(r["makespan_s"], 2),
            "decode_retraces": r["decode_retraces"],
            "fused_retraces": r["fused_retraces"],
            "mixed_steps": r["mixed_steps"],
            "prefill_chunks": r["prefill_chunks"],
            "budget_utilization": round(r["budget_utilization"], 3),
            "preempted": r["preempted"],
        })
    whole = res["whole-suffix"]
    gate = res[f"budget-{budgets[-1]}"]
    rows.append({
        "name": "fused-summary",
        "gate_budget": budgets[-1],
        "ttft_p95_improvement": round(
            whole["interactive_p95_ttft_s"]
            / max(gate["interactive_p95_ttft_s"], 1e-9), 2),
        "decode_tokens_per_s_ratio": round(
            gate["decode_tokens_per_s"]
            / max(whole["decode_tokens_per_s"], 1e-9), 3),
        "tokens_identical": all(res[f"budget-{b}"]["texts"] == whole["texts"]
                                for b in budgets),
    })
    return rows


def _check_fused(rows, quick: bool):
    """Acceptance gates for the fused chunked-prefill scenario. Identity
    and retrace gates always run; the TTFT/throughput gates only at full
    size (tiny smoke streams are burst-dominated noise)."""
    s = next(r for r in rows if r["name"] == "fused-summary")
    arms = [r for r in rows if r["name"].startswith("fused-")
            and r["name"] != "fused-summary"]
    ok = True
    msgs = []
    if not s["tokens_identical"]:
        ok = False
        msgs.append("fused outputs differ from whole-suffix admission")
    for r in arms:
        if r["decode_retraces"] or r["fused_retraces"]:
            ok = False
            msgs.append(f"{r['name']}: retraced after warmup "
                        f"(decode {r['decode_retraces']}, "
                        f"fused {r['fused_retraces']})")
    if not quick:
        if s["ttft_p95_improvement"] < 1.5:
            ok = False
            msgs.append(f"interactive p95 TTFT improvement "
                        f"{s['ttft_p95_improvement']} < 1.5")
        if s["decode_tokens_per_s_ratio"] < 0.9:
            ok = False
            msgs.append(f"decode tokens/s ratio "
                        f"{s['decode_tokens_per_s_ratio']} < 0.9")
    if not ok:
        print("FUSED CHECK FAILED: " + "; ".join(msgs))
        sys.exit(1)
    print(f"FUSED CHECK OK: interactive p95 TTFT "
          f"{s['ttft_p95_improvement']}x better at budget "
          f"{s['gate_budget']}, decode tokens/s ratio "
          f"{s['decode_tokens_per_s_ratio']}, token-identical, zero "
          f"decode/fused retraces")


def _check_prefix(rows, quick: bool):
    """Acceptance gates for the prefix scenario. Timing gates only run at
    full size (smoke runs are noise-dominated); identity/trace gates always
    run."""
    ok = True
    msgs = []
    for share in (0, 50, 90):
        s = next(r for r in rows if r["name"] == f"prefix-summary-{share}")
        on = next(r for r in rows if r["name"] == f"prefix-on-{share}")
        off = next(r for r in rows if r["name"] == f"prefix-off-{share}")
        if not s["tokens_identical"]:
            ok = False
            msgs.append(f"share {share}%: outputs differ with cache on")
        if on["decode_retraces"] or off["decode_retraces"]:
            ok = False
            msgs.append(f"share {share}%: decode retraced")
        for r in (on, off):
            if r["prefill_traces_total"] > r["pow2_buckets"]:
                ok = False
                msgs.append(f"share {share}%: {r['name']} prefill traces "
                            f"{r['prefill_traces_total']} > bucket bound "
                            f"{r['pow2_buckets']}")
    s90 = next(r for r in rows if r["name"] == "prefix-summary-90")
    if not quick:
        if s90["prefill_speedup"] < 2.0:
            ok = False
            msgs.append(f"90% share prefill speedup {s90['prefill_speedup']} "
                        "< 2.0")
        if s90["resident_gain"] <= 0:
            ok = False
            msgs.append("90% share did not raise peak residents")
        if s90["hit_rate"] < 0.9:
            ok = False
            msgs.append(f"90% share hit rate {s90['hit_rate']} < 0.9")
    if not ok:
        print("PREFIX CHECK FAILED: " + "; ".join(msgs))
        sys.exit(1)
    print(f"PREFIX CHECK OK: 90% share prefill speedup "
          f"{s90['prefill_speedup']}x, +{s90['resident_gain']} peak "
          f"residents, hit rate {s90['hit_rate']}, token-identical, zero "
          f"decode retraces, prefill traces within the pow2 bucket bound")


def _check_paged(rows, quick: bool):
    s = next(r for r in rows if r["name"] == "paged-summary")
    paged = next(r for r in rows if r["name"] == "kv-paged")
    cont = next(r for r in rows if r["name"] == "kv-contiguous")
    retraces = paged["decode_retraces"] + cont["decode_retraces"]
    tok_match = paged["new_tokens"] == cont["new_tokens"]
    # tiny smoke runs are timing-noisy; gate throughput at full size only
    need_tps = 0.0 if quick else 0.95
    ok = (s["equal_kv_capacity"] and retraces == 0 and tok_match
          and s["resident_ratio"] >= 2.0
          and s["tokens_per_s_ratio"] >= need_tps)
    if not ok:
        print(f"PAGED CHECK FAILED: tokens_per_s_ratio="
              f"{s['tokens_per_s_ratio']} (need >={need_tps}), "
              f"resident_ratio={s['resident_ratio']} (need >=2.0), "
              f"retraces={retraces}, tokens_match={tok_match}")
        sys.exit(1)
    print(f"PAGED CHECK OK: tokens/s ratio {s['tokens_per_s_ratio']} "
          f"(>={need_tps}), {s['resident_ratio']}x residents at equal KV "
          f"memory, zero decode retraces, token counts match")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI smoke runs")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=384)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless continuous >=1.5x static AND "
                         "paged holds >=2x residents at tokens/s within 5% "
                         "of contiguous, all with zero decode retraces")
    args = ap.parse_args()
    run(quick=args.smoke, n_requests=args.requests, max_batch=args.max_batch,
        max_seq=args.max_seq, seed=args.seed, check=args.check)
