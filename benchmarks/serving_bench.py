"""Serving benchmark: static vs continuous batching on a mixed-length stream.

A single engine (reduced qwen2-0.5b, byte tokenizer) serves the SAME
request set — prompt lengths 8..200, max_new_tokens 4..64 — two ways:

* static   — requests are chunked into rigid batches of ``max_batch``; each
             batch blocks until its longest sequence finishes (head-of-line
             blocking), exactly the seed engine's behaviour.
* continuous — a TierScheduler streams requests through the engine's slot
             pool, admitting a queued request the moment a slot frees.

Both paths share the engine's fixed-shape jitted functions (warmed up
before timing), so the measured delta is pure scheduling: slot reuse vs
batch barriers. Reports tokens/s and p50/p95 request latency, plus the
decode-step trace count, which must stay at 1 across the whole run.

Usage:  PYTHONPATH=src:. python benchmarks/serving_bench.py [--smoke] [--check]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.common import emit
from repro.serving import Request, TierScheduler, make_edge_engine


def mixed_workload(n: int, seed: int, min_prompt=8, max_prompt=200,
                   min_new=4, max_new=64):
    """Serving-shaped mix: lengths are log-uniform over the given ranges
    (heavy-tailed, like real chat traffic — many short requests, a long
    tail), which is what makes static batching pay for head-of-line
    blocking."""
    rng = np.random.default_rng(seed)

    def log_uniform(lo, hi):
        return int(np.exp(rng.uniform(np.log(lo), np.log(hi))))

    reqs = []
    for i in range(n):
        plen = log_uniform(min_prompt, max_prompt)
        new = log_uniform(min_new, max_new)
        # byte tokenizer: prompt length in tokens == chars + BOS
        reqs.append(Request("q" * plen, max_new_tokens=new))
    return reqs


def run_static(eng, reqs):
    """Rigid batches of max_batch; per-request latency = its batch's end."""
    lat = []
    tokens = 0
    t0 = time.perf_counter()
    for i in range(0, len(reqs), eng.max_batch):
        _, stats = eng.generate_static(reqs[i:i + eng.max_batch])
        t_batch = time.perf_counter() - t0
        lat.extend([t_batch] * min(eng.max_batch, len(reqs) - i))
        tokens += stats.new_tokens
    return tokens, time.perf_counter() - t0, lat


def run_continuous(eng, reqs):
    """All requests queued up front; the scheduler keeps the lanes full."""
    sched = TierScheduler({"edge": eng})
    t0 = time.perf_counter()
    for r in reqs:
        sched.submit(r, "edge")
    lat = []
    tokens = 0
    while sched.pending() or sched.in_flight():
        for c in sched.pump():
            lat.append(time.perf_counter() - t0)
            tokens += c.new_tokens
    return tokens, time.perf_counter() - t0, lat


def run(quick: bool = False, n_requests: int = 64, max_batch: int = 8,
        max_seq: int = 384, seed: int = 0, check: bool = False):
    if quick:
        n_requests, max_batch, max_seq = 10, 4, 128
    eng = make_edge_engine(max_seq=max_seq, max_batch=max_batch, seed=0)
    kw = dict(max_prompt=min(200, max_seq - 70)) if max_seq < 280 else {}
    reqs = mixed_workload(n_requests, seed, **kw)
    # compile everything (decode/sample/insert + every prefill bucket) so the
    # timed phases compare scheduling, not tracing
    eng.warmup(len(eng.tok.encode(r.prompt)) for r in reqs)
    traces0 = dict(eng.trace_counts)

    tok_s, wall_s, lat_s = run_static(eng, reqs)
    tok_c, wall_c, lat_c = run_continuous(eng, reqs)
    retraces = eng.trace_counts["decode"] - traces0["decode"]

    def row(name, tokens, wall, lat):
        return {
            "name": name,
            "requests": len(lat),
            "new_tokens": tokens,
            "tokens_per_s": round(tokens / wall, 1),
            "wall_s": round(wall, 2),
            "p50_latency_s": round(float(np.percentile(lat, 50)), 2),
            "p95_latency_s": round(float(np.percentile(lat, 95)), 2),
        }

    speedup = (tok_c / wall_c) / (tok_s / wall_s)
    rows = [
        row("static", tok_s, wall_s, lat_s),
        row("continuous", tok_c, wall_c, lat_c),
        {"name": "summary", "throughput_speedup": round(speedup, 2),
         "decode_retraces_after_warmup": retraces,
         "decode_traces_total": eng.decode_traces},
    ]
    emit(rows, "serving_bench")
    if check:
        # tiny smoke runs are noisy: only the full-size bench gates on 1.5x
        need = 1.0 if quick else 1.5
        ok = speedup >= need and retraces == 0 and tok_s == tok_c
        if not ok:
            print(f"CHECK FAILED: speedup={speedup:.2f} (need >={need}), "
                  f"retraces={retraces} (need 0), "
                  f"tokens {tok_s} vs {tok_c} (must match)")
            sys.exit(1)
        print(f"CHECK OK: speedup={speedup:.2f} (>={need}), zero decode "
              f"retraces, token counts match")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI smoke runs")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=384)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless speedup >= 1.5x with zero "
                         "decode retraces")
    args = ap.parse_args()
    run(quick=args.smoke, n_requests=args.requests, max_batch=args.max_batch,
        max_seq=args.max_seq, seed=args.seed, check=args.check)
