"""Paper Fig. 2: model size vs inference cost / accuracy / delay trade-off
for LLM-only serving (the motivation plot), from the cost model + quality
calibration (Qwen2.5 family proxies)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.cost_model import inference_tflops

# (params_b, llm-only accuracy proxy, tokens/s on the edge GPU)
FAMILY = {
    "0.5b": (0.5, 0.18, 260.0),
    "1.5b": (1.5, 0.25, 140.0),
    "3b": (3.0, 0.33, 90.0),
    "7b": (7.0, 0.42, 55.0),
    "14b": (14.0, 0.50, 30.0),
    "32b": (32.0, 0.58, 14.0),
    "72b": (72.0, 0.65, 7.0),
}

IN_TOK, OUT_TOK = 16.0, 27.0


def run(quick: bool = False):
    rows = []
    for name, (pb, acc, tps) in FAMILY.items():
        rows.append({
            "name": name,
            "params_b": pb,
            "tflops": round(inference_tflops(pb, IN_TOK, OUT_TOK), 3),
            "accuracy_proxy": acc,
            "delay_s": round(OUT_TOK / tps, 3),
        })
    emit(rows, "fig2_modelsize")
    return rows


if __name__ == "__main__":
    run()
