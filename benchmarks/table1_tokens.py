"""Paper Table 1: token utilization + inference TFLOPs per strategy on a
3B model (LLM-only vs Naive RAG vs GraphRAG)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.cluster.simulator import EACOCluster, SimConfig
from repro.data.corpus import wiki_like

STRATS = {"llm_only": "fixed:0", "naive_rag": "fixed:1",
          "graph_rag": "fixed:2"}

PAPER = {  # (in_mean, out_mean, tflops)
    "llm_only": (16.01, 27.21, 0.65),
    "naive_rag": (3632.0, 26.59, 22.98),
    "graph_rag": (9017.0, 142.7, 58.57),
}


def run(n: int = 250, seed: int = 0, quick: bool = False):
    if quick:
        n = 100
    corpus = wiki_like(seed)
    rows = []
    for name, pol in STRATS.items():
        sim = EACOCluster(corpus, SimConfig(seed=seed), policy=pol)
        sim.run(n)
        m = sim.metrics(skip_warmup=False)
        pin, pout, ptf = PAPER[name]
        rows.append({
            "name": name,
            "in_tokens": round(m["in_tokens_mean"], 1),
            "out_tokens": round(m["out_tokens_mean"], 1),
            "tflops": round(m["u_r_mean"], 2),
            "paper_in": pin, "paper_out": pout, "paper_tflops": ptf,
        })
    emit(rows, "table1_tokens")
    return rows


if __name__ == "__main__":
    run()
