"""Paper Table 5: effect of warm-up steps on EACO-RAG's gating decisions."""
from __future__ import annotations

from benchmarks.common import emit
from repro.cluster.simulator import EACOCluster, SimConfig
from repro.data.corpus import specialized_like, wiki_like


def run(n_post: int = 1000, seed: int = 0, quick: bool = False):
    if quick:
        n_post = 400
    rows = []
    for corpus_name, corpus_fn, warmups in [
        ("wiki", wiki_like, (100, 200, 300)),
        ("hp", specialized_like, (100, 300, 500)),
    ]:
        corpus = corpus_fn(seed)
        for w in warmups:
            sim = EACOCluster(
                corpus, SimConfig(seed=seed, warmup_steps=w,
                                  qos_min_acc=0.85, qos_max_delay=5.0),
                policy="eaco")
            sim.run(w + n_post)
            m = sim.metrics()
            # early window right after warm-up: this is where the amount of
            # exploration data shows (the gate keeps learning online, so a
            # long average dilutes the effect the paper's Table 5 measures)
            exploit = [l for l in sim.logs if l.phase == "exploit"]
            early = exploit[: min(300, len(exploit))]
            import numpy as np
            rows.append({
                "name": f"{corpus_name}/eaco-{w}",
                "warmup": w,
                "accuracy": round(m["accuracy"], 4),
                "delay_s": round(m["delay_mean"], 3),
                "cost_tflops": round(m["cost_mean"], 2),
                "early300_cost": round(float(np.mean([l.cost for l in early])), 2),
                "early300_acc": round(float(np.mean([l.correct for l in early])), 4),
                "arm_fracs": [round(a, 3) for a in m["arm_fracs"]],
            })
    emit(rows, "table5_warmup")
    return rows


if __name__ == "__main__":
    run()
