"""Shared benchmark utilities: result records + CSV emission."""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Any, Dict, List

RESULTS_DIR = Path(__file__).resolve().parents[1] / "results" / "benchmarks"


def emit(rows: List[Dict[str, Any]], name: str) -> None:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    (RESULTS_DIR / f"{name}.json").write_text(json.dumps(rows, indent=1))
    for r in rows:
        fields = ",".join(f"{k}={v}" for k, v in r.items() if k != "name")
        print(f"{name}/{r.get('name', '?')},{fields}")


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.elapsed = time.perf_counter() - self.t0
