"""Benchmark harness — one module per paper table/figure. Prints CSV rows
``<table>/<name>,k=v,...`` and writes JSON under results/benchmarks/.

Usage:  PYTHONPATH=src python -m benchmarks.run [--quick] [--only table4]
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    fig2_modelsize, fig4_ablation, kernels_bench, table1_tokens,
    table4_overall, table5_warmup, table6_slms,
)

MODULES = {
    "fig2": fig2_modelsize,
    "table1": table1_tokens,
    "table4": table4_overall,
    "table5": table5_warmup,
    "table6": table6_slms,
    "fig4": fig4_ablation,
    "kernels": kernels_bench,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced step counts (CI)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    args = ap.parse_args()
    names = list(MODULES) if not args.only else args.only.split(",")
    for name in names:
        mod = MODULES[name]
        t0 = time.time()
        print(f"# === {name} ({mod.__name__}) ===", flush=True)
        mod.run(quick=args.quick)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
