"""Overload benchmark: SLO-aware preemption / shedding under 1x-5x load.

A single paged edge engine (reduced qwen2-0.5b, fused chunked-prefill +
decode ON: ``STEP_TOKEN_BUDGET`` tokens/step) runs on a virtual clock
with PAPER_EDGE modeled service times — the same deterministic timeline the
cluster simulator uses — while a deterministic arrival process offers a
mixed stream at a chosen multiple of the engine's token capacity:

* ``interactive`` requests: short prompts, 4-8 new tokens, tight deadline
  (must finish within ``INTERACTIVE_SLO_S`` of arrival);
* ``batch`` requests: longer prompts, 24-48 new tokens, loose deadline.

Cases:

1. ``1x`` / ``2x`` / ``5x`` — preemption + overdue shedding ON. At 2x+
   every interactive arrival that finds the slot pool full of batch work
   preempts the worst resident (which later RESUMES via the prefix cache).
2. ``2x-nopreempt`` — identical 2x stream with preemption OFF: interactive
   requests wait for a slot behind resident batch decodes. The interactive
   p95 gap vs case 2x isolates what preemption buys.
3. ``2x-faults`` — 2x stream plus a periodically stalling engine and a
   stuck-resident timeout: residents caught in a long stall are reclaimed
   as typed ``Shed("timeout")`` outcomes and their pages come back.

``--check`` gates (the robustness contract):
  * zero wedges — every case drains; no scheduler/drain errors;
  * conservation — submitted == completed + shed (typed) in every case;
  * token-identical service — EVERY completed text equals the same
    request's uncontended reference output (greedy, same seed), including
    requests that were preempted and resumed mid-decode (>= 1 such must
    occur at 2x, else the bench isn't testing anything);
  * interactive p95 at 2x meets the SLO and beats the no-preemption
    baseline.

Usage:  PYTHONPATH=src:. python benchmarks/overload_bench.py \
            [--smoke] [--check] [--seed N]
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from benchmarks.common import emit
from repro.core.clock import VirtualClock
from repro.core.cost_model import (
    PAPER_EDGE, modeled_decode_round_s, modeled_prefill_s,
)
from repro.serving import Request, TierScheduler, make_edge_engine

MAX_SEQ = 128
MAX_BATCH = 4
STEP_TOKEN_BUDGET = 16      # fused step: decode rows + chunked prefill
PREFILL_CHUNK = 16
INTERACTIVE_SLO_S = 2.0     # deadline slack for interactive arrivals
BATCH_SLO_S = 60.0          # loose deadline for batch arrivals
WEDGE_IDLE_S = 30.0         # virtual idle time with zero progress = wedge


def overload_workload(n: int, seed: int):
    """Deterministic mixed stream: ~half interactive, half batch. Returns
    a list of (slo, prompt, max_new) specs; Request objects are built
    fresh per case (engine plan memos key on request identity)."""
    rng = np.random.default_rng(seed)
    specs = []
    for k in range(n):
        if k % 2 == 0:
            plen = int(rng.integers(12, 40))
            new = int(rng.integers(4, 9))
            slo = "interactive"
        else:
            plen = int(rng.integers(30, 70))
            new = int(rng.integers(24, 49))
            slo = "batch"
        prompt = f"q{k} " + "".join(rng.choice(list("abcdefgh "), plen))
        specs.append((slo, prompt, new))
    return specs


def make_requests(specs):
    return [Request(prompt, max_new_tokens=new, slo=slo)
            for slo, prompt, new in specs]


def arrival_times(specs, load: float):
    """Deterministic arrivals at ``load`` times the engine's modeled token
    capacity (MAX_BATCH slots each emitting one token per decode round)."""
    mean_new = float(np.mean([new for _, _, new in specs]))
    cap_rps = MAX_BATCH * PAPER_EDGE.tokens_per_s / mean_new
    dt = 1.0 / (load * cap_rps)
    return [k * dt for k in range(len(specs))]


def run_case(eng, specs, load: float, *, preempt: bool, faults=None,
             request_timeout_s=None):
    """Drive one overload case on the virtual clock; modeled service time
    is derived from the engine's true prefill/decode work, exactly as the
    cluster simulator does. Returns per-case stats."""
    clock = VirtualClock()
    sched = TierScheduler({"edge": eng}, clock=clock, preempt=preempt,
                          shed_overdue=True,
                          request_timeout_s=request_timeout_s)
    reqs = make_requests(specs)
    arrivals = list(zip(arrival_times(specs, load), reqs))
    slack = {"interactive": INTERACTIVE_SLO_S, "batch": BATCH_SLO_S}
    index = {id(r): k for k, r in enumerate(reqs)}

    completions, idle_since = [], None
    while arrivals or sched.pending() or sched.in_flight():
        now = clock.now()
        while arrivals and arrivals[0][0] <= now:
            t_arr, r = arrivals.pop(0)
            sched.submit(r, "edge", deadline_s=t_arr + slack[r.slo], now=now)
        stalled = None
        if faults is not None:
            def stalled(tier, i, _now=now):        # noqa: E731
                return faults.stalled(tier, i, _now, 1)
        p0, d0 = eng.prefill_tokens, eng.decode_rounds
        before = (sched.pending(), sched.in_flight(),
                  tuple(sched.counters.values()))
        comps = sched.pump(now=now, stalled=stalled)
        completions.extend(comps)
        dt = (modeled_prefill_s(PAPER_EDGE, eng.prefill_tokens - p0)
              + (eng.decode_rounds - d0) * modeled_decode_round_s(PAPER_EDGE))
        after = (sched.pending(), sched.in_flight(),
                 tuple(sched.counters.values()))
        if dt > 0:
            clock.advance(dt)
            idle_since = None
            continue
        if after != before:
            idle_since = None
            continue
        # nothing moved: jump to the next arrival, or tick through a
        # stall window; a long idle plateau with work outstanding = wedge
        idle_since = now if idle_since is None else idle_since
        if now - idle_since > WEDGE_IDLE_S:
            raise RuntimeError(
                f"overload case wedged at t={now:.2f}: "
                f"{sched.pending()} queued, {sched.in_flight()} resident")
        clock.advance(max(arrivals[0][0] - now, 0.05) if arrivals else 0.05)

    def lat(c):
        return c.queue_wait_s + c.time_in_engine_s

    def p95(xs):
        return float(np.percentile(xs, 95)) if xs else float("nan")

    inter = [c for c in completions if c.slo == "interactive"]
    sheds = sched.pop_sheds()
    return {
        "completions": completions,
        "index": index,
        "conservation": sched.conservation_ok(),
        "counters": dict(sched.counters),
        "shed_reasons": sorted({s.reason for s in sheds}),
        "preempted_completed": sum(c.preemptions > 0 for c in completions),
        "interactive_p95_s": p95([lat(c) for c in inter]),
        "interactive_done": len(inter),
        "batch_done": len(completions) - len(inter),
        "makespan_s": clock.now(),
    }


def run(quick: bool = False, check: bool = False, seed: int = 0):
    n = 36 if quick else 120
    specs = overload_workload(n, seed)
    eng = make_edge_engine(max_seq=MAX_SEQ, max_batch=MAX_BATCH, seed=0,
                           step_token_budget=STEP_TOKEN_BUDGET,
                           prefill_chunk=PREFILL_CHUNK)
    eng.warmup(len(eng.tok.encode(p)) for _, p, _ in specs)

    # uncontended greedy reference — the token-identity yardstick
    ref_texts, _ = eng.generate(make_requests(specs))
    eng.invalidate_prefix_cache()

    from repro.cluster.faults import FaultConfig, FaultInjector
    cases = [
        ("1x", dict(load=1.0, preempt=True)),
        ("2x", dict(load=2.0, preempt=True)),
        ("5x", dict(load=5.0, preempt=True)),
        ("2x-nopreempt", dict(load=2.0, preempt=False)),
        # one long stall landing once work is resident: its victims exceed
        # the 1.0s no-progress timeout and come back as typed sheds
        ("2x-faults", dict(load=2.0, preempt=True, request_timeout_s=1.0,
                           faults=FaultInjector(FaultConfig(
                               stall_period_s=30.0, stall_duration_s=1.3,
                               stall_start_s=1.6)))),
    ]
    rows, results = [], {}
    for name, kw in cases:
        res = run_case(eng, specs, **kw)
        eng.assert_quiescent()   # drained case must leave zero leaked pages
        eng.invalidate_prefix_cache()
        mismatched = sum(
            c.text != ref_texts[res["index"][id(c.request)]]
            for c in res["completions"])
        results[name] = dict(res, mismatched=mismatched)
        c = res["counters"]
        rows.append({
            "name": name,
            "submitted": c["submitted"],
            "completed": c["completed"],
            "shed": c["shed"] + c["overload_shed"],
            "timed_out": c["timed_out"],
            "preempted": c["preempted"],
            "resumed": c["resumed"],
            "preempted_completed": res["preempted_completed"],
            "mismatched_texts": mismatched,
            "conservation_ok": res["conservation"],
            "interactive_p95_s": round(res["interactive_p95_s"], 3),
            "interactive_done": res["interactive_done"],
            "batch_done": res["batch_done"],
            "makespan_virtual_s": round(res["makespan_s"], 2),
        })

    p95_pre = results["2x"]["interactive_p95_s"]
    p95_base = results["2x-nopreempt"]["interactive_p95_s"]
    rows.append({
        "name": "summary",
        "interactive_p95_2x_preempt_s": round(p95_pre, 3),
        "interactive_p95_2x_baseline_s": round(p95_base, 3),
        "p95_improvement": round(p95_base / max(p95_pre, 1e-9), 2),
        "slo_s": INTERACTIVE_SLO_S,
    })
    emit(rows, "overload_bench")

    if check:
        ok = True

        def gate(cond, msg):
            nonlocal ok
            print(f"  [{'PASS' if cond else 'FAIL'}] {msg}")
            ok = ok and bool(cond)

        for name, res in results.items():
            gate(res["conservation"], f"{name}: request conservation")
            gate(res["mismatched"] == 0,
                 f"{name}: all completed texts token-identical to reference")
        gate(results["2x"]["preempted_completed"] >= 1,
             "2x: >=1 preempted request completed (resume path exercised)")
        gate(results["2x-faults"]["counters"]["timed_out"] >= 1,
             "2x-faults: stalled residents timed out (typed)")
        gate(p95_pre <= INTERACTIVE_SLO_S,
             f"2x: interactive p95 {p95_pre:.3f}s within "
             f"{INTERACTIVE_SLO_S}s SLO")
        gate(p95_pre < p95_base,
             f"2x: preemption beats baseline p95 "
             f"({p95_pre:.3f}s < {p95_base:.3f}s)")
        eng.assert_quiescent()
        gate(eng.audit()["active"] == 0,
             "engine quiescent after all cases: page audit clean, no leaks")
        print("overload_bench check:", "PASS" if ok else "FAIL")
        return 0 if ok else 1
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless the robustness gates pass")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args(argv)
    return run(quick=a.smoke, check=a.check, seed=a.seed)


if __name__ == "__main__":
    sys.exit(main())
