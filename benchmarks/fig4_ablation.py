"""Paper Fig. 4 ablation: with the gate and cloud arms removed, how do
(a) the local adaptive-update trigger interval and (b) the edge chunk-store
capacity affect accuracy, with and without edge-assisted retrieval?"""
from __future__ import annotations

from benchmarks.common import emit
from repro.cluster.simulator import EACOCluster, SimConfig
from repro.data.corpus import wiki_like


def _acc(corpus, *, trigger: int, capacity: int, assist: bool,
         n: int, seed: int) -> float:
    cfg = SimConfig(seed=seed, update_trigger=trigger,
                    edge_capacity=capacity, edge_assist_enabled=assist)
    sim = EACOCluster(corpus, cfg, policy="fixed:1")   # naive edge RAG only
    sim.run(n)
    return sim.metrics(skip_warmup=False)["accuracy"]


def run(n: int = 350, seed: int = 0, quick: bool = False):
    if quick:
        n = 150
    corpus = wiki_like(seed)
    rows = []
    for trigger in (10, 20, 40, 80, 10 ** 9):
        for assist in (True, False):
            acc = _acc(corpus, trigger=trigger, capacity=1000,
                       assist=assist, n=n, seed=seed)
            label = "assist" if assist else "local-only"
            tname = "never" if trigger >= 10 ** 9 else trigger
            rows.append({"name": f"update-{tname}/{label}",
                         "update_trigger": tname, "edge_assist": assist,
                         "accuracy": round(acc, 4)})
    # capacity sweep: our synthetic chunks are ~95 tokens vs the paper's
    # ~500, and the corpus holds ~112 chunks per store-coverage unit, so the
    # sweep spans 20..140 (the paper's 200..1400 scaled by corpus size)
    for cap in (20, 40, 60, 100, 140):
        for assist in (True, False):
            acc = _acc(corpus, trigger=20, capacity=cap, assist=assist,
                       n=n, seed=seed)
            label = "assist" if assist else "local-only"
            rows.append({"name": f"chunks-{cap}/{label}",
                         "capacity": cap, "edge_assist": assist,
                         "accuracy": round(acc, 4)})
    emit(rows, "fig4_ablation")
    return rows


if __name__ == "__main__":
    run()
