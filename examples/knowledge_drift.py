"""Adaptive knowledge update under interest drift — the paper's core C2
mechanism, isolated (no gate, no cloud generation).

Edge stores start seeded with each edge's initially-popular topics. The
workload's regional interests then drift every `--period` steps. With
adaptive updates ON, the cloud ships GraphRAG community chunks matched to
each edge's recent queries (FIFO, 20-query trigger); with updates OFF the
stores go stale. We plot retrieval hit-rate over time for both, plus the
edge-assisted variant.

Run:  PYTHONPATH=src python examples/knowledge_drift.py --steps 600
"""
import argparse

import numpy as np

from repro.cluster.simulator import EACOCluster, SimConfig
from repro.data.corpus import wiki_like


def run(corpus, *, updates: bool, assist: bool, steps: int, period: float,
        seed: int = 0):
    cfg = SimConfig(
        seed=seed,
        update_trigger=20 if updates else 10 ** 9,
        edge_assist_enabled=assist,
        drift_period=period,
        initial_fill=0.5,
        edge_capacity=120,
    )
    sim = EACOCluster(corpus, cfg, policy="fixed:1")
    sim.run(steps)
    hits = np.array([l.hit for l in sim.logs], dtype=float)
    return hits


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--period", type=float, default=150.0)
    ap.add_argument("--window", type=int, default=100)
    args = ap.parse_args()

    corpus = wiki_like(seed=0)
    runs = {
        "no-update, local-only": run(corpus, updates=False, assist=False,
                                     steps=args.steps, period=args.period),
        "adaptive,  local-only": run(corpus, updates=True, assist=False,
                                     steps=args.steps, period=args.period),
        "adaptive,  edge-assist": run(corpus, updates=True, assist=True,
                                      steps=args.steps, period=args.period),
    }
    W = args.window
    n_win = args.steps // W
    print(f"retrieval hit-rate per {W}-step window "
          f"(interest drift every {args.period:.0f} steps):\n")
    header = "window:".ljust(24) + "".join(f"{i:>7d}" for i in range(n_win))
    print(header)
    for name, hits in runs.items():
        cells = "".join(f"{hits[i*W:(i+1)*W].mean():>7.2f}"
                        for i in range(n_win))
        print(name.ljust(24) + cells + f"   | overall {hits.mean():.3f}")
    print("\nAs interests drift, the stale store's hit-rate decays; the "
          "FIFO updates track the drift; edge-assist adds cross-region "
          "coverage on top (paper Fig. 4 mechanics).")


if __name__ == "__main__":
    main()
