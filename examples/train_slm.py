"""Train an edge SLM on the synthetic corpus — the end-to-end training
driver (data pipeline -> packed batches -> AdamW -> checkpoint).

Default is a CPU-feasible tiny model; ``--size 100m`` builds a ~100M-param
qwen2-family model (the config the pod launcher trains via
``repro.launch.train`` with real meshes).

Run:  PYTHONPATH=src python examples/train_slm.py --steps 60
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.corpus import wiki_like
from repro.data.pipeline import PackedLMDataset
from repro.models import build_model
from repro.training.checkpointing import load_checkpoint, save_checkpoint
from repro.training.optimizer import AdamWConfig
from repro.training.steps import init_train_state, make_train_step


def make_cfg(size: str):
    base = get_config("qwen2-0.5b", reduced=True)
    if size == "tiny":
        return dataclasses.replace(base, n_layers=2, d_model=128, n_heads=4,
                                   n_kv_heads=2, d_ff=256, vocab=512,
                                   head_dim=32)
    if size == "100m":   # ~100M params, qwen2 family
        return dataclasses.replace(base, n_layers=12, d_model=768,
                                   n_heads=12, n_kv_heads=4, d_ff=2048,
                                   vocab=32768, head_dim=64,
                                   tie_embeddings=True)
    raise SystemExit(f"unknown --size {size}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--size", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/eaco_slm.ckpt")
    args = ap.parse_args()

    cfg = make_cfg(args.size)
    model = build_model(cfg, max_seq=args.seq)
    print(f"model: {model.n_params():,} params ({args.size})")

    ds = PackedLMDataset(wiki_like(0), seq_len=args.seq, batch=args.batch,
                         vocab_cap=cfg.vocab)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
    params, opt_state = init_train_state(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, opt_cfg))

    it = iter(ds)
    t0 = time.time()
    first = last = None
    for step in range(args.steps):
        x, y = next(it)
        batch = {"tokens": jnp.asarray(x), "targets": jnp.asarray(y)}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        if first is None:
            first = loss
        last = loss
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={loss:7.4f} "
                  f"acc={float(metrics['acc']):.3f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)")
    assert last < first, "loss must decrease"
    save_checkpoint(args.ckpt, params, opt_state, meta={"step": args.steps})
    print(f"checkpoint saved to {args.ckpt}")
    p2, o2, meta = load_checkpoint(args.ckpt, params, opt_state)
    assert meta["step"] == args.steps
    print("checkpoint round-trip ok; final loss",
          f"{last:.4f} (from {first:.4f})")


if __name__ == "__main__":
    main()
