"""End-to-end tiered serving driver (the paper's deployment, miniaturized).

``--backend engines`` (the default) runs the CLOSED loop: the collaborative
gate routes each query to {local SLM, edge RAG + SLM, cloud GraphRAG + SLM,
cloud LLM}, and every decision is served by a REAL JAX engine — a pool of
edge SLM engines (reduced qwen2-0.5b, paged KV + prefix cache) and one
cloud-tier engine (reduced qwen2-72b family) behind a TierScheduler.
Arrivals are bursty multi-user; arrival stamps, queue waits, engine service
time and network transit all compose on ONE virtual clock
(``--engine-time modeled`` is deterministic per seed; ``wall`` advances by
the measured jit seconds instead). Completions flow back asynchronously
with real token counts feeding the cost model and the gate's SafeOBO
update. Quality scoring uses the calibrated oracle (DESIGN.md §5).

``--backend oracle`` is the original analytic fast path: the same gate and
retrieval, but cost/delay come from the paper's cost model and Table 1
token draws; the retrieved texts ride on ``StepLog.retrieved``.

Run:  PYTHONPATH=src python examples/serve_cluster.py [--steps 12]
      PYTHONPATH=src python examples/serve_cluster.py --backend oracle \
          --policy fixed:3 --steps 40
"""
import argparse

from repro.cluster.simulator import EACOCluster, SimConfig
from repro.data.corpus import wiki_like


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=12,
                    help="arrival steps (one virtual arrival period each)")
    ap.add_argument("--warmup", type=int, default=20,
                    help="gate warmup steps (SafeOBO)")
    ap.add_argument("--backend", choices=("engines", "oracle"),
                    default="engines")
    ap.add_argument("--policy", default="eaco",
                    help="'eaco' or 'fixed:<0..3>'")
    ap.add_argument("--edge-engines", type=int, default=2,
                    help="edge SLM pool size (engines backend)")
    ap.add_argument("--mean-arrivals", type=float, default=1.5,
                    help="Poisson mean queries per arrival step")
    ap.add_argument("--hot-topic-boost", type=float, default=0.2,
                    help="extra interest mass on each edge's hot topic")
    ap.add_argument("--engine-time", choices=("modeled", "wall"),
                    default="modeled",
                    help="virtual-clock service time: tier-spec rates on "
                         "real token counts, or measured jit seconds")
    args = ap.parse_args()

    corpus = wiki_like(seed=0)
    cfg = SimConfig(seed=0, warmup_steps=args.warmup, qos_min_acc=0.85,
                    qos_max_delay=5.0, n_edges=4,
                    n_edge_engines=args.edge_engines,
                    mean_arrivals=args.mean_arrivals,
                    hot_topic_boost=args.hot_topic_boost,
                    engine_time=args.engine_time)
    sim = EACOCluster(corpus, cfg, policy=args.policy, backend=args.backend)

    if args.backend == "oracle":
        for i, ev in enumerate(sim.workload.stream(args.steps)):
            log = sim.step(ev)
            print(f"[{i:03d}] {ev.edge_id} arm={log.arm_name:<13} "
                  f"hit={int(log.hit)} ok={int(log.correct)} "
                  f"delay={log.delay:.2f}s cost={log.cost:7.1f} "
                  f"retrieved={len(log.retrieved)} chunks")
    else:
        for pool_name, pool in sim.sched.pools.items():
            for j, e in enumerate(pool):
                print(f"{pool_name}[{j}]: {e.cfg.arch_id} (reduced) "
                      f"{e.model.n_params():,} params, {e.max_batch} slots, "
                      f"{e.num_pages} KV pages")
        # drive the loop by hand (sim.run does the same) so completions can
        # be printed as they surface on the virtual clock
        for step, events in enumerate(sim.workload.bursts(args.steps,
                                                          clock=sim.clock)):
            for ev in events:
                sim.submit_query(ev)
                print(f"[{step:03d} t={sim.clock.now():7.2f}s] {ev.edge_id} "
                      f"arrive: {ev.qa.question[:48]!r}")
            target = sim.clock.now() + cfg.arrival_period_s
            while ((sim.sched.pending() or sim.sched.in_flight())
                   and sim.clock.now() < target):
                before = sim.clock.now()
                for log in sim.pump_engines():
                    print(f"      <- {log.tier} done arm={log.arm_name:<13} "
                          f"queue {log.queue_wait_s*1e3:5.0f}ms | engine "
                          f"{log.engine_s*1e3:5.0f}ms | delay "
                          f"{log.delay:.2f}s | {log.out_tokens:.0f} tok | "
                          f"cost {log.cost:7.1f}")
                if sim.clock.now() <= before:
                    break
            if sim.clock.now() < target:
                sim.clock.advance(target - sim.clock.now())
        for log in sim.drain_engines():
            print(f"      <- {log.tier} done arm={log.arm_name:<13} "
                  f"queue {log.queue_wait_s*1e3:5.0f}ms | engine "
                  f"{log.engine_s*1e3:5.0f}ms | delay {log.delay:.2f}s | "
                  f"{log.out_tokens:.0f} tok | cost {log.cost:7.1f}")

    m = sim.metrics(skip_warmup=False)
    print(f"\nserved {m['n']} queries: acc={m['accuracy']:.3f} "
          f"delay={m['delay_mean']:.2f}s cost={m['cost_mean']:.1f} TFLOPs "
          f"queue_wait={m['queue_wait_mean']*1e3:.0f}ms")
    if args.backend == "engines":
        for pool_name, pool in sim.sched.pools.items():
            for j, e in enumerate(pool):
                print(f"{pool_name}[{j}]: prefilled {e.prefill_tokens} tok, "
                      f"{e.decode_rounds} decode rounds, prefix hits "
                      f"{e.prefix_hits}/{e.prefix_hits + e.prefix_misses}, "
                      f"decode traces {e.decode_traces} (untrained weights "
                      f"-> text is noise, the engines are real)")


if __name__ == "__main__":
    main()
