"""End-to-end tiered serving driver (the paper's deployment, miniaturized).

Edge nodes run a REAL JAX serving engine (reduced qwen2-0.5b, byte
tokenizer, slot-pool continuous-batching decode); the collaborative gate
routes each query to {local SLM, edge RAG + SLM, cloud GraphRAG + SLM,
cloud LLM}. Queries routed to a local arm are submitted to a
TierScheduler, which streams them through the engine's KV-cache slots
while the simulation keeps stepping — completions surface asynchronously
with their queue-wait and time-in-engine. Quality scoring uses the
calibrated oracle (DESIGN.md §5).

Run:  PYTHONPATH=src python examples/serve_cluster.py [--steps 40]
"""
import argparse

from repro.cluster.simulator import EACOCluster, SimConfig
from repro.data.corpus import wiki_like
from repro.serving import Request, TierScheduler, make_edge_engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--max-real", type=int, default=6,
                    help="max queries actually decoded on the edge engine")
    args = ap.parse_args()

    corpus = wiki_like(seed=0)
    sim = EACOCluster(
        corpus, SimConfig(seed=0, warmup_steps=args.warmup,
                          qos_min_acc=0.85, qos_max_delay=5.0),
        policy="eaco")
    engine = make_edge_engine(max_seq=384, max_batch=2, seed=0)
    sched = TierScheduler({"edge": engine})
    print("edge engine:", engine.cfg.arch_id, "(reduced)",
          f"{engine.model.n_params():,} params,",
          f"{engine.max_batch} KV-cache slots")

    n_real = 0
    for i, ev in enumerate(sim.workload.stream(args.steps)):
        log = sim.step(ev)
        line = (f"[{i:03d}] {ev.edge_id} arm={log.arm_name:<13} "
                f"hit={int(log.hit)} ok={int(log.correct)} "
                f"delay={log.delay:.2f}s cost={log.cost:7.1f}")
        if log.arm_name in ("slm-only", "edge-rag+slm") and n_real < args.max_real:
            # REAL generation: enqueue for the continuous edge engine; the
            # scheduler admits it whenever a slot frees up.
            retrieved, _, _ = sim._retrieve(sim.gate.arms[log.arm], ev)
            ctx_text = " ".join(retrieved[:2])[:256]
            prompt = f"Context: {ctx_text}\nQ: {ev.qa.question}\nA:"
            sched.submit(Request(prompt, max_new_tokens=12), "edge",
                         deadline_s=sim.cfg.qos_max_delay)
            n_real += 1
            line += "  | submitted to edge engine"
        print(line)
        # pump the slot pool once per sim step: admissions + one decode
        for c in sched.pump():
            print(f"      <- edge decode done: {c.new_tokens} tok "
                  f"(queue {c.queue_wait_s*1e3:.0f}ms, "
                  f"engine {c.time_in_engine_s*1e3:.0f}ms)")

    done = sched.drain()
    for c in done:
        print(f"      <- edge decode done: {c.new_tokens} tok "
              f"(queue {c.queue_wait_s*1e3:.0f}ms, "
              f"engine {c.time_in_engine_s*1e3:.0f}ms)")

    m = sim.metrics(skip_warmup=False)
    print(f"\nserved {m['n']} queries: acc={m['accuracy']:.3f} "
          f"delay={m['delay_mean']:.2f}s cost={m['cost_mean']:.1f} TFLOPs")
    if n_real:
        print(f"real edge decodes: {n_real} via {engine.max_batch}-slot "
              f"continuous batching (engine time: prefill "
              f"{engine.prefill_s:.1f}s + decode {engine.decode_s:.1f}s on "
              f"CPU; untrained weights -> text is noise, the engine is "
              f"real); decode traces: {engine.decode_traces}")


if __name__ == "__main__":
    main()
