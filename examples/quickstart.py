"""Quickstart: the EACO-RAG core loop in ~40 lines.

Builds a synthetic wiki-like corpus, runs the collaborative gate (SafeOBO)
against fixed baselines, and prints the cost/accuracy trade-off — the
paper's Table 4 in miniature.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.cluster.simulator import EACOCluster, SimConfig
from repro.data.corpus import wiki_like


def main():
    corpus = wiki_like(seed=0)
    print(f"corpus: {len(corpus.chunks)} chunks, {len(corpus.qa)} QA pairs, "
          f"{len(corpus.topics)} topics\n")

    print(f"{'policy':<22}{'accuracy':>9}{'delay(s)':>10}{'cost(TFLOPs)':>14}")
    baseline_cost = None
    for policy, steps in [("fixed:0", 250), ("fixed:1", 250),
                          ("fixed:3", 250), ("eaco", 1000)]:
        sim = EACOCluster(
            corpus,
            SimConfig(seed=0, warmup_steps=250, qos_min_acc=0.85,
                      qos_max_delay=5.0),
            policy=policy)
        sim.run(steps)
        m = sim.metrics(skip_warmup=(policy == "eaco"))
        label = {"fixed:0": "3B SLM only", "fixed:1": "edge RAG + SLM",
                 "fixed:3": "72B + GraphRAG", "eaco": "EACO-RAG (gate)"}[policy]
        print(f"{label:<22}{m['accuracy']:>9.3f}{m['delay_mean']:>10.2f}"
              f"{m['cost_mean']:>14.1f}")
        if policy == "fixed:3":
            baseline_cost = m["cost_mean"]
        if policy == "eaco" and baseline_cost:
            red = 100 * (1 - m["cost_mean"] / baseline_cost)
            print(f"\nEACO-RAG cost reduction vs always-cloud: {red:.1f}% "
                  f"(paper: up to 84.6%)")
            print(f"arm usage (slm/edge/graph+slm/graph+llm): "
                  f"{[round(a, 2) for a in m['arm_fracs']]}")


if __name__ == "__main__":
    main()
