"""Distribution-layer tests runnable on one device: sharding resolution,
EP-MoE equivalence + gradients, checkpoint round-trip, HLO slice accounting,
launch report plumbing."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_local_mesh, rules_for
from repro.models.moe import moe_defs, moe_ffn
from repro.models.pdefs import (
    ParamDef, init_from_defs, pspecs_from_defs, resolve_axes,
)
from repro.models.shardctx import activation_sharding
from repro.training.checkpointing import load_checkpoint, save_checkpoint


def test_resolve_axes_multi_axis_batch():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = resolve_axes(("batch", None, "embed"), (8, 4, 16), mesh,
                        rules_for(None))
    # batch grabs data; embed cannot reuse it -> drops to None
    flat = [s for s in spec if s is not None]
    names = []
    for s in flat:
        names.extend(s if isinstance(s, tuple) else [s])
    assert len(names) == len(set(names))


def test_pspecs_cover_all_leaves():
    defs = {"a": ParamDef((4, 8), ("embed", "ff")),
            "b": {"c": ParamDef((8,), ("embed",))}}
    mesh = make_local_mesh()
    specs = pspecs_from_defs(defs, mesh)
    assert len(jax.tree.leaves(specs,
               is_leaf=lambda x: hasattr(x, "index"))) >= 1


def test_moe_ep_gradients_match_auto():
    """d(loss)/d(params) must agree between auto and EP paths (1x1 mesh)."""
    m = MoEConfig(n_experts=4, top_k=2, expert_ff=16)
    defs = moe_defs(8, m, jnp.float32)
    params = init_from_defs(defs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 8))

    def loss(p, mode):
        mm = dataclasses.replace(m, shard_mode=mode)
        out, aux = moe_ffn(p, x, mm, group_size=16, dtype=jnp.float32)
        return jnp.sum(out ** 2) + aux

    g_auto = jax.grad(lambda p: loss(p, "auto"))(params)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh, activation_sharding(mesh):
        g_ep = jax.grad(lambda p: loss(p, "ep"))(params)
    for ka, ke in zip(jax.tree.leaves(g_auto), jax.tree.leaves(g_ep)):
        np.testing.assert_allclose(np.asarray(ka), np.asarray(ke),
                                   atol=1e-5, rtol=1e-4)


def test_checkpoint_roundtrip_bf16(tmp_path):
    params = {"w": jnp.ones((3, 4), jnp.bfloat16),
              "b": {"x": jnp.arange(5, dtype=jnp.float32)}}
    opt = {"mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
           "step": jnp.zeros((), jnp.int32)}
    path = str(tmp_path / "ck.msgpack")
    save_checkpoint(path, params, opt, meta={"k": 1})
    p2, o2, meta = load_checkpoint(path, params, opt)
    assert meta["k"] == 1
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_hlo_cost_slice_awareness():
    """Reading one row per scan step must not count the full matrix."""
    N, D = 64, 128

    def f(big):
        def body(acc, i):
            row = jax.lax.dynamic_slice_in_dim(big, i, 1, axis=0)
            return acc + jnp.sum(row), None
        acc, _ = jax.lax.scan(body, 0.0, jnp.arange(N))
        return acc

    txt = jax.jit(f).lower(
        jax.ShapeDtypeStruct((N, D), jnp.float32)).compile().as_text()
    c = analyze_hlo(txt)
    full_matrix_per_step = N * N * D * 4
    # slice-aware accounting keeps total bytes near N rows, far below
    # N x full-matrix
    assert c.bytes < 0.2 * full_matrix_per_step, c.bytes


def test_dryrun_results_complete():
    """All 80 (arch x shape x mesh) dry-run results exist with ok/skip."""
    from pathlib import Path
    from repro.configs import ARCHS, INPUT_SHAPES
    d = Path(__file__).resolve().parents[1] / "results" / "dryrun"
    if not d.exists():
        pytest.skip("dry-run results not generated yet")
    missing, bad = [], []
    for mesh in ("16x16", "2x16x16"):
        for a in ARCHS:
            for s in INPUT_SHAPES:
                p = d / f"{a}__{s}__{mesh}.json"
                if not p.exists():
                    missing.append(p.name)
                    continue
                r = json.loads(p.read_text())
                if r["status"] not in ("ok", "skipped"):
                    bad.append((p.name, r.get("error", "")[:80]))
    assert not missing, missing
    assert not bad, bad
