"""Paged KV-cache engine: refcounted allocator + prefix-cache lifecycle,
page-gated admission, copy-on-write tail sharing, token-identity with the
contiguous layout (and with the prefix cache off), and compile stability."""
import numpy as np
import pytest

from repro.serving.engine import Request, make_edge_engine
from repro.serving.paging import (
    PageAllocator, PagingError, PrefixCache, pages_needed,
)
from repro.serving.scheduler import TierScheduler


# ---------------------------------------------------------------------------
# Allocator: refcounts, guards, LRU retention
# ---------------------------------------------------------------------------

def test_allocator_distinct_ids_and_recycling():
    a = PageAllocator(8)
    x = a.alloc(3)
    y = a.alloc(5)
    ids = np.concatenate([x, y])
    assert len(set(ids.tolist())) == 8 and 0 not in ids    # distinct, no trash
    assert a.free_pages == 0
    with pytest.raises(PagingError):
        a.alloc(1)
    a.free(x)
    assert a.free_pages == 3
    z = a.alloc(3)
    assert sorted(z.tolist()) == sorted(x.tolist())        # recycled

def test_allocator_guards_raise_real_exceptions():
    """Bookkeeping violations raise PagingError (a RuntimeError), not bare
    asserts that vanish under ``python -O``."""
    a = PageAllocator(4)
    ids = a.alloc(2)
    with pytest.raises(PagingError):
        a.free([int(ids[0]), int(ids[0])])                 # double free
    with pytest.raises(PagingError):
        a.free([0])                                        # trash page
    with pytest.raises(PagingError):
        a.free([99])                                       # foreign id
    with pytest.raises(PagingError):
        a.ref([int(a._free[-1])])                          # ref of free page
    assert issubclass(PagingError, RuntimeError)

def test_pages_needed_rounding():
    assert pages_needed(1, 16) == 1
    assert pages_needed(16, 16) == 1
    assert pages_needed(17, 16) == 2
    assert pages_needed(0, 16) == 1

def test_refcount_share_fork_free_lifecycle():
    """A page mapped by several slots is released only by the LAST free."""
    a = PageAllocator(4)
    (pid,) = a.alloc(1).tolist()
    a.ref([pid])                      # second slot maps the same page
    a.ref([pid])                      # third
    assert a.refcount(pid) == 3
    a.free([pid])
    a.free([pid])
    assert a.refcount(pid) == 1 and a.free_pages == 3      # still mapped
    with pytest.raises(PagingError):
        a.alloc(4)                    # page is not reclaimable while mapped
    a.free([pid])
    assert a.free_pages == 4          # decrement-to-zero released it

def test_lru_retention_and_demand_eviction():
    """retain=True parks refcount-0 pages in the LRU pool: available but not
    free; ``ref`` revives them; alloc evicts oldest-first via evict_cb."""
    a = PageAllocator(4)
    evicted = []
    a.evict_cb = evicted.append
    keep = {1, 2, 3, 4}
    p1 = a.alloc(2)          # say pages [4, 3]
    p2 = a.alloc(2)
    a.free(p1, retain=keep.__contains__)
    assert a.free_pages == 0 and a.cached_pages == 2 and a.available_pages == 2
    # revival: ref pulls a cached page back to refcount 1 with no device work
    a.ref([int(p1[0])])
    assert a.cached_pages == 1 and a.refcount(int(p1[0])) == 1
    a.free([int(p1[0])], retain=keep.__contains__)
    # demand eviction: alloc(2) must evict both cached pages, oldest first
    got = a.alloc(2)
    assert sorted(got.tolist()) == sorted(p1.tolist())
    assert a.cached_pages == 0 and sorted(evicted) == sorted(p1.tolist())
    a.free(got)
    a.free(p2)

def test_can_reserve_counts_revived_pages_once():
    a = PageAllocator(3)
    ids = a.alloc(3)
    a.free(ids, retain=lambda p: True)        # all cached
    assert a.available_pages == 3
    reuse = [int(ids[0])]
    assert a.can_reserve(2, reuse)            # revive 1, evict 2 -> fits
    assert not a.can_reserve(3, reuse)        # 3 fresh + 1 revived > pool


# ---------------------------------------------------------------------------
# PrefixCache: chain hashes, tails, eviction
# ---------------------------------------------------------------------------

def test_prefix_cache_match_insert_roundtrip():
    pc = PrefixCache(4)
    toks = list(range(11))                    # 2 full blocks + 3-token tail
    pc.insert(toks, [10, 11, 12])
    pages, tail = pc.match(toks[:10])         # capped at L-1
    assert pages == [10, 11]
    assert tail == (12, 2)                    # 2 of the 3 tail tokens usable
    # diverging second block breaks the chain after block 0
    pages, tail = pc.match([0, 1, 2, 3, 9, 9, 9, 9])
    assert pages == [10] and tail is None
    # partial tail match: first token of the tail agrees
    pages, tail = pc.match(toks[:8] + [8, 77])
    assert pages == [10, 11] and tail == (12, 1)

def test_prefix_cache_forget_drops_all_keys():
    pc = PrefixCache(4)
    pc.insert(list(range(6)), [5, 6])
    assert pc.owns(5) and pc.owns(6)
    pc.forget(5)
    assert not pc.owns(5)
    pages, tail = pc.match(list(range(5)))
    assert pages == [] and tail is None       # chain root gone -> full miss
    pc.forget(6)
    assert len(pc) == 0

def test_prefix_cache_first_writer_wins():
    pc = PrefixCache(2)
    pc.insert([1, 2, 3, 4], [7, 8])
    pc.insert([1, 2, 3, 4], [9, 9])           # same blocks, other pages
    pages, _ = pc.match([1, 2, 3])
    assert pages == [7]                       # canonical page kept


# ---------------------------------------------------------------------------
# Engine: paged layout end-to-end
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def paged():
    eng = make_edge_engine(max_seq=96, max_batch=3, seed=0)   # auto -> paged
    assert eng.kv_layout == "paged" and eng._prefix is not None
    return eng

@pytest.fixture(scope="module")
def contiguous():
    return make_edge_engine(max_seq=96, max_batch=3, seed=0,
                            kv_layout="contiguous")


REQS = [Request("What is the capital of France?", max_new_tokens=6),
        Request("Hello", max_new_tokens=9),
        Request("a" * 60, max_new_tokens=30),
        Request("tiered rag serving", max_new_tokens=4),
        Request("edge node", max_new_tokens=12),
        Request("q" * 30, max_new_tokens=7)]


def test_paged_greedy_token_identical_to_contiguous(paged, contiguous):
    """The tentpole acceptance: greedy decode through the page arena —
    prefix sharing, CoW tails and suffix prefill included — emits exactly
    the tokens the contiguous per-slot lanes emit."""
    out_p, _ = paged.generate(REQS)
    out_c, _ = contiguous.generate(REQS)
    assert out_p == out_c
    # and the static path through the paged engine agrees with itself
    static, _ = paged.generate_static(REQS[:3])
    assert static == out_p[:3]

def test_prefix_sharing_on_vs_off_token_identical():
    """Greedy outputs must not depend on whether prompts were prefilled
    from scratch or assembled from shared pages + CoW tail + suffix."""
    ctx = "shared retrieved context: the Eiffel Tower is in Paris. "
    reqs = [Request(ctx + q, max_new_tokens=8)
            for q in ("who?", "where?", "when?", "why?")]
    on = make_edge_engine(max_seq=128, max_batch=4, seed=0)
    off = make_edge_engine(max_seq=128, max_batch=4, seed=0,
                           prefix_cache=False)
    out_on, st_on = on.generate(reqs)
    out_off, st_off = off.generate(reqs)
    assert out_on == out_off
    assert st_on.prefix_hits == 3 and st_on.prefix_misses == 1
    assert st_on.prefix_tokens_shared >= 3 * (len(ctx) // on.page_size
                                              * on.page_size)
    assert st_off.prefix_hits == 0 and st_off.prefix_tokens_shared == 0

def test_shared_pages_counted_once(paged):
    """Two residents sharing a prefix hold the shared pages at refcount 2
    and together consume fewer pages than two independent requests."""
    drain(paged)
    base = paged.available_pages
    r1 = Request("z" * 40, max_new_tokens=4)
    r2 = Request("z" * 40, max_new_tokens=4)
    need = pages_needed(41 + 4, paged.page_size)
    paged.admit(r1)
    used1 = base - paged.available_pages
    assert used1 == need
    paged.admit(r2)
    used2 = base - paged.available_pages
    # second request allocates fresh pages only for CoW tail + budget
    assert used2 < 2 * need
    shared = paged._page_tables[0][: 41 // paged.page_size]
    for pid in shared:
        assert paged._allocator.refcount(int(pid)) == 2
    drain(paged)
    assert paged.available_pages == base

def drain(eng):
    while eng.has_active:
        eng.step()

def test_pages_recycled_after_drain(paged):
    drain(paged)
    assert paged.available_pages == paged.num_pages
    paged.generate(REQS)
    assert paged.available_pages == paged.num_pages
    assert not paged.has_active
    assert (paged._page_tables == 0).all()
    # retained prefix pages are CACHED (reclaimable), not leaked or free
    assert paged.cached_pages > 0
    assert paged.free_pages + paged.cached_pages == paged.num_pages

def test_page_reservation_matches_prompt_plus_budget():
    """While a request is resident it holds exactly
    ceil((prompt + budget) / page_size) pages (prefix cache off: every page
    is private)."""
    eng = make_edge_engine(max_seq=96, max_batch=3, seed=0,
                           prefix_cache=False)
    r = Request("hello world", max_new_tokens=10)
    L = len(eng.tok.encode(r.prompt))
    need = pages_needed(L + 10, eng.page_size)
    eng.admit(r)
    assert eng.free_pages == eng.num_pages - need
    drain(eng)
    assert eng.free_pages == eng.num_pages

def test_decode_never_retraces_across_mixed_stream(paged):
    before = paged.trace_counts["decode"]
    reqs = [Request("x" * (3 + 7 * i), max_new_tokens=1 + i % 5)
            for i in range(8)]
    paged.generate(reqs)
    assert paged.trace_counts["decode"] == before
    # the paged path writes prefill straight into pages: no insert ever
    assert paged.trace_counts["insert"] == 0
    assert paged.trace_counts["copy"] <= 1

def test_lru_eviction_under_page_pressure():
    """A pool far smaller than the distinct-prompt working set must keep
    admitting (evicting stale cached prefixes) and never corrupt outputs."""
    eng = make_edge_engine(max_seq=64, max_batch=2, seed=0,
                           num_pages=2 * (64 // 16))
    ref = make_edge_engine(max_seq=64, max_batch=2, seed=0,
                           prefix_cache=False,
                           num_pages=2 * (64 // 16))
    reqs = [Request(f"distinct prompt number {i} padded out", max_new_tokens=3)
            for i in range(6)]
    out, _ = eng.generate(reqs)
    out_ref, _ = ref.generate(reqs)
    assert out == out_ref
    assert eng.available_pages == eng.num_pages
    # the tiny pool cannot retain every prompt: evictions must have fired
    assert eng.cached_pages <= eng.num_pages

def test_cached_prefix_survives_completion_and_rehits():
    """LRU retention: a prompt admitted AFTER its twin completed still hits
    — the refcount-0 pages kept their KV."""
    eng = make_edge_engine(max_seq=128, max_batch=2, seed=0)
    r = Request("the quick brown fox jumps over the lazy dog",
                max_new_tokens=4)
    eng.generate([r])
    assert eng.prefix_hits == 0
    out2, st = eng.generate([Request(r.prompt, max_new_tokens=4)])
    assert st.prefix_hits == 1
    assert st.prefix_tokens_shared == len(eng.tok.encode(r.prompt)) - 1

def test_admission_blocks_on_pages_not_slots():
    """With a page pool far smaller than the slot pool, residency is bounded
    by pages; queued work still drains to completion."""
    eng = make_edge_engine(max_seq=64, max_batch=6, seed=0,
                           num_pages=64 // 16)     # exactly one worst case
    assert eng.kv_layout == "paged"
    big = Request("z" * 40, max_new_tokens=20)     # needs the whole pool
    assert eng.can_admit(big)
    eng.admit(big)
    small = Request("hi", max_new_tokens=2)
    assert eng.free_slots > 0 and not eng.can_admit(small)
    with pytest.raises(PagingError):
        eng.admit(small)
    drain(eng)
    assert eng.can_admit(small)
    sched = TierScheduler({"edge": eng})
    for i in range(6):                    # 6 free slots, but only 4 pages
        sched.submit(Request(f"q{i}", max_new_tokens=2), "edge")
    done = sched.drain()
    assert len(done) == 6
    assert eng.available_pages == eng.num_pages
    # each small request needs 1 page: with 6 slots free the scheduler still
    # only reaches 4 residents — pages, not slots, were the binding limit
    assert eng.peak_active == 4

def test_more_residents_than_equal_memory_contiguous():
    """At equal KV token capacity, short requests pack >2x more resident
    work into the paged pool than the contiguous layout's max_batch."""
    base_batch, max_seq, ps = 2, 128, 16
    eng = make_edge_engine(max_seq=max_seq, max_batch=4 * base_batch, seed=0,
                           page_size=ps,
                           num_pages=base_batch * (max_seq // ps))
    assert eng.kv_cache_tokens == base_batch * max_seq
    reqs = [Request("ab", max_new_tokens=8) for _ in range(8)]
    eng.generate(reqs)
    assert eng.peak_active >= 2 * base_batch

def test_contiguous_layout_still_available():
    eng = make_edge_engine(max_seq=64, max_batch=2, kv_layout="contiguous")
    assert eng.kv_layout == "contiguous"
    assert eng.free_pages is None
    assert eng.can_admit(Request("x"))
    texts, _ = eng.generate([Request("hello", max_new_tokens=3)])
    assert len(texts) == 1

def test_paged_rejected_for_unpageable_model():
    from repro.configs import get_config
    from repro.serving.engine import ServingEngine
    cfg = get_config("gemma3-4b", reduced=True)    # sliding-window ring
    with pytest.raises(ValueError):
        ServingEngine(cfg, max_seq=64, max_batch=1, kv_layout="paged")
    eng = ServingEngine(cfg, max_seq=64, max_batch=1)     # auto falls back
    assert eng.kv_layout == "contiguous"
