"""Paged KV-cache engine: allocator accounting, page-gated admission,
token-identity with the contiguous layout, and compile stability."""
import numpy as np
import pytest

from repro.serving.engine import Request, make_edge_engine
from repro.serving.paging import PageAllocator, pages_needed
from repro.serving.scheduler import TierScheduler


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------

def test_allocator_distinct_ids_and_recycling():
    a = PageAllocator(8)
    x = a.alloc(3)
    y = a.alloc(5)
    ids = np.concatenate([x, y])
    assert len(set(ids.tolist())) == 8 and 0 not in ids    # distinct, no trash
    assert a.free_pages == 0
    with pytest.raises(RuntimeError):
        a.alloc(1)
    a.free(x)
    assert a.free_pages == 3
    z = a.alloc(3)
    assert sorted(z.tolist()) == sorted(x.tolist())        # recycled
    with pytest.raises(AssertionError):
        a.free([int(z[0]), int(z[0])])                     # double free


def test_pages_needed_rounding():
    assert pages_needed(1, 16) == 1
    assert pages_needed(16, 16) == 1
    assert pages_needed(17, 16) == 2
    assert pages_needed(0, 16) == 1


# ---------------------------------------------------------------------------
# Engine: paged layout end-to-end
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def paged():
    eng = make_edge_engine(max_seq=96, max_batch=3, seed=0)   # auto -> paged
    assert eng.kv_layout == "paged"
    return eng


@pytest.fixture(scope="module")
def contiguous():
    return make_edge_engine(max_seq=96, max_batch=3, seed=0,
                            kv_layout="contiguous")


REQS = [Request("What is the capital of France?", max_new_tokens=6),
        Request("Hello", max_new_tokens=9),
        Request("a" * 60, max_new_tokens=30),
        Request("tiered rag serving", max_new_tokens=4),
        Request("edge node", max_new_tokens=12),
        Request("q" * 30, max_new_tokens=7)]


def test_paged_greedy_token_identical_to_contiguous(paged, contiguous):
    """The tentpole acceptance: greedy decode through the page arena emits
    exactly the tokens the contiguous per-slot lanes emit."""
    out_p, _ = paged.generate(REQS)
    out_c, _ = contiguous.generate(REQS)
    assert out_p == out_c
    # and the static path through the paged engine agrees with itself
    static, _ = paged.generate_static(REQS[:3])
    assert static == out_p[:3]


def test_pages_recycled_after_drain(paged):
    assert paged.free_pages == paged.num_pages
    paged.generate(REQS)
    assert paged.free_pages == paged.num_pages
    assert not paged.has_active
    assert (paged._page_tables == 0).all()


def test_page_reservation_matches_prompt_plus_budget(paged):
    """While a request is resident it holds exactly
    ceil((prompt + budget) / page_size) pages."""
    r = Request("hello world", max_new_tokens=10)
    L = len(paged.tok.encode(r.prompt))
    need = pages_needed(L + 10, paged.page_size)
    paged.admit(r)
    assert paged.free_pages == paged.num_pages - need
    while paged.has_active:
        paged.step()
    assert paged.free_pages == paged.num_pages


def test_decode_never_retraces_across_mixed_stream(paged):
    before = paged.trace_counts["decode"]
    reqs = [Request("x" * (3 + 7 * i), max_new_tokens=1 + i % 5)
            for i in range(8)]
    paged.generate(reqs)
    assert paged.trace_counts["decode"] == before
    assert paged.trace_counts["insert"] == 1


def test_admission_blocks_on_pages_not_slots():
    """With a page pool far smaller than the slot pool, residency is bounded
    by pages; queued work still drains to completion."""
    eng = make_edge_engine(max_seq=64, max_batch=6, seed=0,
                           num_pages=64 // 16)     # exactly one worst case
    assert eng.kv_layout == "paged"
    big = Request("z" * 40, max_new_tokens=20)     # needs the whole pool
    assert eng.can_admit(big)
    eng.admit(big)
    small = Request("hi", max_new_tokens=2)
    assert eng.free_slots > 0 and not eng.can_admit(small)
    with pytest.raises(RuntimeError):
        eng.admit(small)
    while eng.has_active:
        eng.step()
    assert eng.can_admit(small)
    sched = TierScheduler({"edge": eng})
    for i in range(6):                    # 6 free slots, but only 4 pages
        sched.submit(Request(f"q{i}", max_new_tokens=2), "edge")
    done = sched.drain()
    assert len(done) == 6
    assert eng.free_pages == eng.num_pages
    # each small request needs 1 page: with 6 slots free the scheduler still
    # only reaches 4 residents — pages, not slots, were the binding limit
    assert eng.peak_active == 4


def test_more_residents_than_equal_memory_contiguous():
    """At equal KV token capacity, short requests pack >2x more resident
    work into the paged pool than the contiguous layout's max_batch."""
    base_batch, max_seq, ps = 2, 128, 16
    eng = make_edge_engine(max_seq=max_seq, max_batch=4 * base_batch, seed=0,
                           page_size=ps,
                           num_pages=base_batch * (max_seq // ps))
    assert eng.kv_cache_tokens == base_batch * max_seq
    reqs = [Request("ab", max_new_tokens=8) for _ in range(8)]
    eng.generate(reqs)
    assert eng.peak_active >= 2 * base_batch


def test_contiguous_layout_still_available():
    eng = make_edge_engine(max_seq=64, max_batch=2, kv_layout="contiguous")
    assert eng.kv_layout == "contiguous"
    assert eng.free_pages is None
    assert eng.can_admit(Request("x"))
    texts, _ = eng.generate([Request("hello", max_new_tokens=3)])
    assert len(texts) == 1


def test_paged_rejected_for_unpageable_model():
    from repro.configs import get_config
    from repro.serving.engine import ServingEngine
    cfg = get_config("gemma3-4b", reduced=True)    # sliding-window ring
    with pytest.raises(ValueError):
        ServingEngine(cfg, max_seq=64, max_batch=1, kv_layout="paged")
    eng = ServingEngine(cfg, max_seq=64, max_batch=1)     # auto falls back
    assert eng.kv_layout == "contiguous"
