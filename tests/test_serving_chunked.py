"""Fused chunked-prefill + decode (token-budget serving).

Covers the PR's acceptance surface: greedy token-identity of chunked vs
whole-suffix admission (including prefix-cache hits landing mid-chunk and
CoW tails), the budget policy (interactive-first chunk selection, the
starvation guard), preempt/resume and crash/requeue of half-prefilled
residents, stats surfacing (mixed_steps / prefill_chunks /
budget_utilization / ttft_s), and trace discipline (a single chunk pad
bucket: one prefill + one fused trace, zero decode retraces)."""
import numpy as np
import pytest

from repro.core.clock import VirtualClock
from repro.serving.engine import EngineError, Request, make_edge_engine
from repro.serving.scheduler import TierScheduler

LONG = "retrieval augmented generation at the edge with adaptive update "
MIX = [
    LONG,                       # multi-chunk prompt
    "short q",                  # single-chunk prompt
    LONG + "and a longer unique tail for the second document",
    "x",                        # degenerate 2-token prompt
]


def budget_engine(**kw):
    kw.setdefault("max_seq", 128)
    kw.setdefault("max_batch", 4)
    kw.setdefault("step_token_budget", 12)
    kw.setdefault("prefill_chunk", 16)
    return make_edge_engine(seed=0, **kw)


def whole_engine(**kw):
    kw.setdefault("max_seq", 128)
    kw.setdefault("max_batch", 4)
    return make_edge_engine(seed=0, **kw)


def drain_virtual(sched, clock, step=0.05, max_steps=10_000):
    done = []
    for _ in range(max_steps):
        if not (sched.pending() or sched.in_flight()):
            return done
        done.extend(sched.pump(now=clock.now()))
        clock.advance(step)
    raise AssertionError("virtual drain did not converge")


# ---------------------------------------------------------------------------
# greedy token identity
# ---------------------------------------------------------------------------

def test_chunked_greedy_identical_to_whole_suffix():
    reqs = lambda: [Request(p, max_new_tokens=8) for p in MIX]   # noqa: E731
    ref, _ = whole_engine().generate(reqs())
    eng = budget_engine()
    out, stats = eng.generate(reqs())
    assert out == ref
    assert eng.prefill_chunks > 0
    assert eng.mixed_steps > 0           # decode really overlapped a chunk
    assert stats.prefill_chunks == eng.prefill_chunks
    assert 0.0 < stats.budget_utilization <= 1.0
    eng.assert_quiescent()


def test_prefix_hit_mid_chunk_identity():
    """A prefix-cache hit leaves prefill_done mid-prompt (shared pages +
    CoW tail, generally NOT chunk- or page-aligned): chunking must resume
    from there and stay token-identical to whole-suffix admission."""
    ctx = "c o m m o n r e t r i e v e d c o n t e x t " * 2
    batch1 = [Request(ctx + "alpha?", max_new_tokens=6)]
    batch2 = [Request(ctx + "beta!!", max_new_tokens=6)]
    we = whole_engine()
    ref = we.generate(batch1)[0] + we.generate(batch2)[0]
    eng = budget_engine()
    out = eng.generate(batch1)[0]
    out += eng.generate(batch2)[0]
    assert out == ref
    assert eng.prefix_hits >= 1
    assert eng.prefix_tokens_shared > 0
    eng.assert_quiescent()


# ---------------------------------------------------------------------------
# budget policy
# ---------------------------------------------------------------------------

def test_pick_chunk_interactive_first_and_starvation_guard():
    eng = budget_engine()
    rid_b = eng.admit(Request(LONG, max_new_tokens=4, slo="batch"))
    rid_i = eng.admit(Request(LONG + "??", max_new_tokens=4,
                              slo="interactive"))
    # interactive wins despite the batch request's earlier admission
    ci, cs, clen = eng._pick_chunk(0)
    assert cs.req_id == rid_i
    assert clen == eng.prefill_chunk
    # budget partially consumed by decode rows: chunk gets the leftover
    ci, cs, clen = eng._pick_chunk(eng.step_token_budget - 5)
    assert cs.req_id == rid_i and clen == 5
    # budget fully consumed: the interactive head still gets a small
    # chunk (starvation guard — first tokens are the interactive SLO)
    ci, cs, clen = eng._pick_chunk(eng.step_token_budget)
    assert cs.req_id == rid_i and 0 < clen <= 8
    # ...but a batch head does not
    eng.preempt(rid_i)
    assert eng._pick_chunk(eng.step_token_budget) is None
    ci, cs, clen = eng._pick_chunk(0)
    assert cs.req_id == rid_b and clen == eng.prefill_chunk
    eng.preempt(rid_b)
    eng.assert_quiescent()


def test_admission_is_async_and_first_token_deferred():
    eng = budget_engine(max_batch=2)
    p0 = eng.prefill_tokens
    rid = eng.admit(Request(LONG, max_new_tokens=4))
    assert eng.prefill_tokens == p0        # no model compute at admit
    assert eng.prefilling_slots == 1
    assert eng.harvest() == []             # nothing to emit mid-prefill
    steps = 0
    while eng.prefilling_slots and steps < 50:
        eng.step()
        steps += 1
    s = next(s for s in eng._slots if s is not None and s.req_id == rid)
    assert s.pending is not None           # first token sampled...
    assert s.first_token_at is not None    # ...and stamped, at final chunk
    assert eng.prefill_tokens - p0 == s.prompt_tokens
    eng.preempt(rid)
    eng.assert_quiescent()


# ---------------------------------------------------------------------------
# preempt / crash of half-prefilled residents
# ---------------------------------------------------------------------------

def test_preempt_half_prefilled_resident_resumes_identical():
    clock = VirtualClock()
    eng = budget_engine(max_batch=1, clock=clock)
    batch = Request(LONG, max_new_tokens=6, slo="batch")
    ref, _ = eng.generate([Request(LONG, max_new_tokens=6)])
    eng.invalidate_prefix_cache()

    sched = TierScheduler({"edge": eng}, clock=clock)
    sched.submit(batch, "edge", now=clock.now())
    sched.pump(now=clock.now())            # batch parks mid-prefill
    assert eng.prefilling_slots == 1
    inter = Request("hi there", max_new_tokens=4, slo="interactive")
    sched.submit(inter, "edge", now=clock.now())
    done = {id(c.request): c for c in drain_virtual(sched, clock)}
    assert sched.counters["preempted"] >= 1
    assert sched.counters["resumed"] >= 1
    assert done[id(batch)].preemptions >= 1
    assert done[id(batch)].text == ref[0]  # half-prefilled resume, greedy
    eng.assert_quiescent()


def test_crash_requeues_half_prefilled_residents():
    clock = VirtualClock()
    eng = budget_engine(max_batch=2, clock=clock)
    reqs = [Request(p, max_new_tokens=6) for p in (LONG, LONG + "more")]
    ref, _ = eng.generate([Request(p, max_new_tokens=6)
                           for p in (LONG, LONG + "more")])
    eng.invalidate_prefix_cache()

    sched = TierScheduler({"edge": eng}, clock=clock, requeue_lost=True)
    for r in reqs:
        sched.submit(r, "edge", now=clock.now())
    sched.pump(now=clock.now())
    assert eng.prefilling_slots >= 1       # half-prefilled work is resident
    lost = eng.crash()                     # every device-side byte is gone
    assert len(lost) == 2
    eng.restart()
    done = {id(c.request): c for c in drain_virtual(sched, clock)}
    assert sched.counters["requeued_lost"] == 2
    assert [done[id(r)].text for r in reqs] == ref
    eng.assert_quiescent()


# ---------------------------------------------------------------------------
# stats, TTFT, trace discipline
# ---------------------------------------------------------------------------

def test_scheduler_surfaces_fused_stats_and_ttft():
    clock = VirtualClock()
    eng = budget_engine(clock=clock)
    sched = TierScheduler({"edge": eng}, clock=clock)
    reqs = [Request(p, max_new_tokens=6,
                    slo="interactive" if i % 2 else "batch")
            for i, p in enumerate(MIX)]
    for r in reqs:
        sched.submit(r, "edge", now=clock.now())
    done = drain_virtual(sched, clock)
    assert len(done) == len(reqs)
    for c in done:
        # 0.0 is legal for a single-chunk prompt admitted and finished
        # within one pump (the virtual clock only moves between pumps)
        assert c.ttft_s >= 0.0
        assert c.ttft_s <= c.queue_wait_s + c.time_in_engine_s + 1e-9
    long_ttfts = [c.ttft_s for c in done
                  if c.request.prompt.startswith(LONG)]
    assert long_ttfts and all(t > 0.0 for t in long_ttfts)
    #      ^ multi-chunk prompts span pumps, so their first token is late
    e = sched.debug_state_dict()["tiers"]["edge"]["engines"][0]
    for key in ("prefilling", "mixed_steps", "prefill_chunks",
                "budget_utilization"):
        assert key in e
    assert e["mixed_steps"] == eng.mixed_steps > 0
    eng.assert_quiescent()


def test_single_chunk_bucket_and_zero_retraces():
    eng = budget_engine()
    # budget mode prefills ONLY fixed-size chunks: warmup collapses to the
    # single chunk bucket no matter how long the prompts are
    eng.warmup(len(eng.tok.encode(p)) for p in MIX)
    assert list(eng.pad_buckets) == [eng._chunk_pad]
    t0 = dict(eng.trace_counts)
    assert t0["prefill"] == 1 and t0["fused"] == 1
    eng.generate([Request(p, max_new_tokens=8) for p in MIX])
    for kind in ("prefill", "fused", "decode"):
        assert eng.trace_counts[kind] == t0[kind], kind
    eng.assert_quiescent()


def test_budget_mode_guards():
    with pytest.raises(EngineError):
        budget_engine(kv_layout="contiguous")
    with pytest.raises(EngineError):
        budget_engine(step_token_budget=0)
