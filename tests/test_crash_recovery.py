"""Crash tolerance: engine crash/restart, scheduler reaping, circuit
breakers, hedged requests, gate availability masking, and knowledge
epochs. All scheduler-level tests run on a virtual clock with real (tiny)
engines so crash/resume stays token-identical under greedy decode."""
import numpy as np
import pytest

from repro.core.clock import VirtualClock
from repro.core.knowledge import AdaptiveKnowledgeUpdater, KnowledgeUpdateConfig
from repro.core.safeobo import SafeOBO, SafeOBOConfig
from repro.serving.engine import EngineError, Request, make_edge_engine
from repro.serving.health import CircuitBreaker
from repro.serving.scheduler import TierScheduler


def drain_virtual(sched, clock, step=0.05, max_steps=10_000):
    done = []
    for _ in range(max_steps):
        if not (sched.pending() or sched.in_flight()):
            return done
        done.extend(sched.pump(now=clock.now()))
        clock.advance(step)
    raise AssertionError("virtual drain did not converge")


# ---------------------------------------------------------------------------
# CircuitBreaker state machine
# ---------------------------------------------------------------------------

def test_breaker_trips_after_threshold():
    b = CircuitBreaker(threshold=3, reset_timeout_s=5.0)
    assert b.allow(0.0)
    b.record_failure(0.0)
    b.record_failure(0.1)
    assert b.allow(0.2)                 # below threshold: still closed
    b.record_failure(0.2)
    assert b.state(0.3) == "open"
    assert not b.allow(0.3)
    assert b.trips == 1


def test_breaker_half_open_single_probe_then_close():
    b = CircuitBreaker(threshold=1, reset_timeout_s=2.0)
    b.record_failure(0.0)
    assert not b.allow(1.0)
    assert b.state(2.5) == "half_open"  # timeout elapsed
    assert b.allow(2.5)
    b.begin_probe(2.5)
    assert not b.allow(2.6)             # probe slot occupied
    b.record_success(3.0)
    assert b.state(3.1) == "closed"
    assert b.allow(3.1)
    assert b.consecutive_failures == 0


def test_breaker_half_open_failure_reopens():
    b = CircuitBreaker(threshold=2, reset_timeout_s=1.0)
    b.record_failure(0.0)
    b.record_failure(0.1)
    assert b.state(1.5) == "half_open"
    b.begin_probe(1.5)
    b.record_failure(1.6)               # probe failed: back to open
    assert b.state(1.7) == "open"
    assert not b.allow(2.0)
    assert b.state(2.7) == "half_open"  # timer restarted from 1.6
    assert b.trips == 2


def test_breaker_success_resets_failure_streak():
    b = CircuitBreaker(threshold=3, reset_timeout_s=1.0)
    b.record_failure(0.0)
    b.record_failure(0.1)
    b.record_success(0.2)
    b.record_failure(0.3)
    b.record_failure(0.4)
    assert b.state(0.5) == "closed"     # streak broken; never reached 3


def test_breaker_validates_args():
    with pytest.raises(ValueError):
        CircuitBreaker(threshold=0)
    with pytest.raises(ValueError):
        CircuitBreaker(reset_timeout_s=0.0)


# ---------------------------------------------------------------------------
# Engine crash / restart
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def crash_engine():
    return make_edge_engine(max_seq=96, max_batch=2, seed=0,
                            kv_layout="paged", page_size=16,
                            prefix_cache=True)


def test_crash_drops_everything_and_restart_rebuilds(crash_engine):
    e = crash_engine
    rid = e.admit(Request("what is the capital of France", max_new_tokens=4))
    assert e.active_slots == 1
    gen0 = e.engine_generation
    lost = e.crash()
    assert lost == [rid]
    assert e.dead and e.crashes >= 1
    assert e.active_slots == 0
    # a dead engine refuses all work, loudly
    assert not e.can_admit(Request("x", max_new_tokens=1))
    with pytest.raises(EngineError):
        e.admit(Request("x", max_new_tokens=1))
    with pytest.raises(EngineError):
        e.step()
    with pytest.raises(EngineError):
        e.preempt(rid)
    with pytest.raises(EngineError):
        e.crash()                       # double-crash is a bug
    e.restart()
    assert not e.dead
    assert e.engine_generation == gen0 + 1
    assert e.free_slots == e.max_batch
    with pytest.raises(EngineError):
        e.restart()                     # restart without a crash is a bug


def test_crash_restart_is_token_identical(crash_engine):
    """Greedy decode after a cold restart reproduces the pre-crash output
    exactly: nothing about generation depends on engine generation."""
    e = crash_engine
    req = Request("the quick brown fox jumps over", max_new_tokens=6)
    e.admit(req)
    ref = None
    while e.has_active:
        for c in e.step():
            ref = c.token_ids
    e.crash()
    e.restart()
    e.admit(Request("the quick brown fox jumps over", max_new_tokens=6))
    out = None
    while e.has_active:
        for c in e.step():
            out = c.token_ids
    assert out == ref


# ---------------------------------------------------------------------------
# Scheduler reaping
# ---------------------------------------------------------------------------

def _mk_sched(clock, **kw):
    e0 = make_edge_engine(max_seq=96, max_batch=2, seed=0,
                          kv_layout="paged", page_size=16, prefix_cache=True)
    e1 = make_edge_engine(max_seq=96, max_batch=2, seed=1,
                          kv_layout="paged", page_size=16, prefix_cache=True)
    cloud = make_edge_engine(max_seq=96, max_batch=2, seed=2,
                             kv_layout="paged", page_size=16,
                             prefix_cache=True)
    sched = TierScheduler({"edge": [e0, e1], "cloud": cloud},
                          clock=clock, **kw)
    return sched, (e0, e1, cloud)


def test_reap_requeues_lost_residents_and_completes():
    """Crash an engine mid-decode: its residents re-enter the queue, finish
    on the surviving engine, and conservation holds with zero sheds."""
    clock = VirtualClock()
    sched, (e0, e1, _) = _mk_sched(clock, requeue_lost=True)
    prompts = [f"crash recovery prompt {i}" for i in range(4)]
    for p in prompts:
        sched.submit(Request(p, max_new_tokens=4), "edge", now=clock.now())
    sched.pump(now=clock.now())          # fills both edge engines
    assert sched.in_flight("edge") == 4
    lost = e0.crash()
    assert len(lost) == 2
    done = drain_virtual(sched, clock)
    assert sorted(c.request.prompt for c in done) == sorted(prompts)
    assert sched.counters["requeued_lost"] == 2
    assert sched.shed_total == 0
    assert sched.conservation_ok()
    e0.restart()                         # leave the fixture pool healthy


def test_reap_sheds_engine_lost_when_requeue_disabled():
    clock = VirtualClock()
    sched, (e0, _, _) = _mk_sched(clock, requeue_lost=False)
    sched.submit(Request("doomed resident", max_new_tokens=4), "edge",
                 now=clock.now())
    sched.pump(now=clock.now())
    assert sched.in_flight() == 1
    e0.crash()
    sched.pump(now=clock.now())
    sheds = sched.pop_sheds()
    assert [s.reason for s in sheds] == ["engine_lost"]
    assert sched.counters["engine_lost"] == 1
    assert sched.conservation_ok()


def test_reap_catches_crash_restart_between_pumps():
    """A full crash->restart cycle between two pumps leaves the engine
    alive but a generation ahead: residents admitted under the old
    generation must still be reaped, never treated as live."""
    clock = VirtualClock()
    sched, (e0, _, _) = _mk_sched(clock, requeue_lost=True)
    sched.submit(Request("generation fence test", max_new_tokens=4), "edge",
                 now=clock.now())
    sched.pump(now=clock.now())
    e0.crash()
    e0.restart()                         # engine looks healthy again...
    assert not e0.dead
    done = drain_virtual(sched, clock)   # ...but the resident is gone
    assert [c.request.prompt for c in done] == ["generation fence test"]
    assert sched.counters["requeued_lost"] == 1
    assert sched.conservation_ok()


def test_resume_after_preempt_then_crash_keeps_banked_tokens():
    """Tokens banked by an earlier preemption live in the control plane and
    survive a later crash; only in-engine progress is lost. The final text
    still matches an uninterrupted run (greedy, token-identical)."""
    clock = VirtualClock()
    ref_e = make_edge_engine(max_seq=96, max_batch=1, seed=5)
    ref_sched = TierScheduler({"edge": ref_e}, clock=VirtualClock())
    ref_sched.submit(Request("banked token prompt", max_new_tokens=6),
                     "edge", now=0.0)
    ref = drain_virtual(ref_sched, VirtualClock())[0].text

    e0 = make_edge_engine(max_seq=96, max_batch=1, seed=5,
                          kv_layout="paged", page_size=16,
                          prefix_cache=True)
    sched = TierScheduler({"edge": e0}, clock=clock, requeue_lost=True)
    sched.submit(Request("banked token prompt", max_new_tokens=6,
                         slo="batch"), "edge", now=clock.now())
    sched.pump(now=clock.now())
    clock.advance(0.05)
    sched.pump(now=clock.now())          # a couple of decode rounds
    # preempt by hand (higher-priority arrival simulation): banks tokens
    key = next(iter(sched._inflight))
    it = sched._inflight.pop(key)
    snap = e0.preempt(key[2])
    it.enc = list(snap.prompt_ids)
    it.emitted.extend(snap.emitted_ids)
    it.preemptions += 1
    it.run_request = sched._resume_request(it)
    import heapq
    heapq.heappush(sched._queues["edge"], it)
    banked = len(it.emitted)
    sched.pump(now=clock.now())          # re-admit resume request
    e0.crash()                           # in-engine progress dies here
    e0.restart()
    done = drain_virtual(sched, clock)
    assert len(done) == 1
    assert done[0].text == ref
    assert banked > 0
    assert sched.counters["requeued_lost"] == 1
    assert sched.conservation_ok()


# ---------------------------------------------------------------------------
# Scheduler breakers
# ---------------------------------------------------------------------------

def test_breaker_quarantines_flaky_engine():
    """Three consecutive crash-losses trip engine 0's breaker: fresh work
    then lands on engine 1 only, until the reset window passes."""
    clock = VirtualClock()
    sched, (e0, e1, _) = _mk_sched(clock, requeue_lost=True,
                                   breaker_threshold=3, breaker_reset_s=50.0)
    for i in range(3):
        sched.submit(Request(f"flaky victim {i}", max_new_tokens=2,
                             slo="batch"), "edge", now=clock.now())
        # force engine 0 (fill e1 first? simpler: e0 is first candidate)
        sched.pump(now=clock.now())
        if not e0.dead and any(k[1] == 0 and k[0] == "edge"
                               for k in sched._inflight):
            e0.crash()
            sched.pump(now=clock.now())      # reap -> breaker failure
            e0.restart()
        drain_virtual(sched, clock)
    b = sched.breakers[("edge", 0)]
    assert b.state(clock.now()) == "open"
    # with the breaker open, new work avoids engine 0 entirely
    sched.submit(Request("routed around the flake", max_new_tokens=2,
                         slo="batch"), "edge", now=clock.now())
    sched.pump(now=clock.now())
    assert all(k[1] == 1 for k in sched._inflight if k[0] == "edge")
    drain_virtual(sched, clock)
    assert sched.conservation_ok()
    # after the reset window, a half-open probe may land on engine 0 again
    clock.advance(60.0)
    assert b.allow(clock.now())


# ---------------------------------------------------------------------------
# Hedging
# ---------------------------------------------------------------------------

def test_hedge_fires_and_first_completion_wins():
    """An interactive request stuck behind a crashed edge pool hedges to
    the cloud tier; exactly one completion surfaces, the loser is
    cancelled, and hedge-aware conservation holds."""
    clock = VirtualClock()
    sched, (e0, e1, _) = _mk_sched(clock, requeue_lost=True,
                                   hedge_s=0.5, hedge_from="edge",
                                   hedge_to="cloud")
    e0.crash()
    e1.crash()                           # the whole edge pool is down
    sched.submit(Request("hedge me to the cloud", max_new_tokens=3,
                         slo="interactive"), "edge", now=clock.now())
    done = drain_virtual(sched, clock)
    assert len(done) == 1
    assert done[0].hedged and done[0].tier == "cloud"
    assert sched.counters["hedged"] == 1
    assert sched.conservation_ok()
    e0.restart()
    e1.restart()
    # the primary leg is still queued on the dead edge pool's queue or was
    # cancelled — either way conservation already accounted for it
    drain_virtual(sched, clock)
    assert sched.conservation_ok()


def test_hedge_not_fired_for_batch_or_before_threshold():
    clock = VirtualClock()
    sched, _ = _mk_sched(clock, hedge_s=100.0)
    sched.submit(Request("quick interactive", max_new_tokens=2,
                         slo="interactive"), "edge", now=clock.now())
    sched.submit(Request("batch job", max_new_tokens=2, slo="batch"),
                 "edge", now=clock.now())
    drain_virtual(sched, clock)
    assert sched.counters["hedged"] == 0
    assert sched.conservation_ok()


def test_hedge_gate_vetoes_firing():
    clock = VirtualClock()
    sched, (e0, e1, _) = _mk_sched(clock, hedge_s=0.1,
                                   hedge_gate=lambda now: False)
    e0.crash()
    e1.crash()
    sched.submit(Request("gated hedge", max_new_tokens=2,
                         slo="interactive"), "edge", now=clock.now())
    for _ in range(20):
        sched.pump(now=clock.now())
        clock.advance(0.1)
    assert sched.counters["hedged"] == 0
    assert sched.pending("edge") == 1    # still waiting on the dead pool
    e0.restart()
    e1.restart()
    drain_virtual(sched, clock)
    assert sched.conservation_ok()


def test_debug_state_reports_breakers_and_residents():
    clock = VirtualClock()
    sched, (e0, _, _) = _mk_sched(clock, breaker_threshold=2)
    sched.submit(Request("diagnose me", max_new_tokens=2), "edge",
                 now=clock.now())
    sched.pump(now=clock.now())
    s = sched.debug_state()
    assert "tier 'edge'" in s and "breaker=closed" in s
    assert "residents=1" in s and "counters=" in s
    drain_virtual(sched, clock)


# ---------------------------------------------------------------------------
# Gate availability mask
# ---------------------------------------------------------------------------

def test_safeobo_mask_never_selects_unavailable_arm():
    cfg = SafeOBOConfig(n_arms=4, context_dim=3, warmup_steps=10)
    obo = SafeOBO(cfg, seed=0)
    rng = np.random.default_rng(1)
    mask = (True, False, True, False)
    for t in range(40):                 # spans warmup AND exploit phases
        ctx = rng.normal(size=3).astype(np.float32)
        arm, info = obo.select(ctx, available=mask)
        assert mask[arm], f"masked arm {arm} selected in {info['phase']}"
        obo.update(ctx, arm, cost=1.0, accuracy=1.0, delay=0.1)


def test_safeobo_mask_excludes_safe_seed_arm():
    """The S_0 seed arm is NOT safe when unreachable: with it masked the
    optimizer must pick among the remaining arms."""
    cfg = SafeOBOConfig(n_arms=4, context_dim=3, warmup_steps=0,
                        safe_seed_arm=3)
    obo = SafeOBO(cfg, seed=0)
    ctx = np.zeros(3, np.float32)
    arm, _ = obo.select(ctx, available=(True, True, True, False))
    assert arm != 3


def test_safeobo_none_mask_is_bit_identical():
    """available=None must preserve the legacy RNG stream exactly."""
    a = SafeOBO(SafeOBOConfig(n_arms=4, context_dim=3, warmup_steps=50),
                seed=9)
    b = SafeOBO(SafeOBOConfig(n_arms=4, context_dim=3, warmup_steps=50),
                seed=9)
    ctx = np.zeros(3, np.float32)
    arms_a = [a.select(ctx)[0] for _ in range(30)]
    arms_b = [b.select(ctx, available=None)[0] for _ in range(30)]
    assert arms_a == arms_b


def test_safeobo_mask_validation():
    obo = SafeOBO(SafeOBOConfig(n_arms=4, context_dim=3), seed=0)
    ctx = np.zeros(3, np.float32)
    with pytest.raises(ValueError):
        obo.select(ctx, available=(True, False))        # wrong shape
    with pytest.raises(ValueError):
        obo.select(ctx, available=(False,) * 4)         # nothing reachable


# ---------------------------------------------------------------------------
# Knowledge epochs
# ---------------------------------------------------------------------------

class _FakeGraph:
    def __init__(self, chunks):
        self._chunks = chunks

    def community_chunks_for_queries(self, queries, top_k, max_chunks):
        return self._chunks[:max_chunks]


def _mk_updater():
    from repro.retrieval.store import VectorStore, make_chunk
    chunks = [make_chunk(f"epoch test fact number {i} about topic", ts=0.0)
              for i in range(6)]
    upd = AdaptiveKnowledgeUpdater(
        _FakeGraph(chunks), KnowledgeUpdateConfig(update_trigger=2))
    return upd, VectorStore(capacity=10)


def test_epoch_advances_on_ship_and_store_tracks():
    upd, store = _mk_updater()
    assert store.epoch == 0 and upd.latest_epoch == 0
    upd.observe_query("e0", "topic question one", store, link_up=True)
    due = upd.observe_query("e0", "topic question two", store, link_up=True)
    assert due
    assert upd.latest_epoch == 1
    assert store.epoch == 1
    assert not upd.is_stale(store)


def test_partition_defers_then_anti_entropy_syncs():
    """Updates due behind a partition advance the epoch but ship nothing:
    the store is stale (flagged) until sync() reconciles on heal."""
    upd, store = _mk_updater()
    upd.observe_query("e0", "topic question one", store, link_up=False)
    upd.observe_query("e0", "topic question two", store, link_up=False)
    assert upd.latest_epoch == 1
    assert store.epoch == 0
    assert upd.is_stale(store)
    assert "e0" in upd.deferred
    assert upd.stats["e0"].deferred == 1
    assert len(store) == 0               # nothing shipped through the cut
    shipped = upd.sync("e0", store, now=1.0)
    assert shipped > 0
    assert store.epoch == upd.latest_epoch
    assert not upd.is_stale(store)
    assert "e0" not in upd.deferred
    assert upd.stats["e0"].synced == 1
    assert upd.sync("e0", store) == 0    # idempotent: nothing owed


def test_epochs_are_monotone_across_edges():
    upd, s0 = _mk_updater()
    from repro.retrieval.store import VectorStore
    s1 = VectorStore(capacity=10)
    for q in ("alpha one", "alpha two"):
        upd.observe_query("e0", q, s0, link_up=True)
    for q in ("beta one", "beta two"):
        upd.observe_query("e1", q, s1, link_up=True)
    assert upd.latest_epoch == 2
    assert s1.epoch == 2
    assert s0.epoch == 1                 # e0 now trails: stale, flagged
    assert upd.is_stale(s0)
