"""GP regression + SafeOBO invariants (unit + property-based)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # tier-1 must collect without hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.core.gp import (
    GPHypers, gp_add, gp_init, gp_log_marginal, gp_posterior, rbf,
)
from repro.core.safeobo import SafeOBO, SafeOBOConfig


def _fill(gp, X, y):
    for xi, yi in zip(X, y):
        gp = gp_add(gp, jnp.asarray(xi), float(yi))
    return gp


def test_gp_interpolates_noise_free():
    X = np.random.default_rng(0).normal(size=(20, 3)).astype(np.float32)
    y = np.sin(X.sum(1))
    gp = _fill(gp_init(64, 3), X, y)
    mu, sd = gp_posterior(gp, jnp.asarray(X), 1.0, 1.0, 1e-4)
    np.testing.assert_allclose(np.asarray(mu), y, atol=0.05)
    assert float(sd.max()) < 0.1


def test_gp_uncertainty_grows_away_from_data():
    X = np.zeros((10, 2), np.float32)
    y = np.ones(10, np.float32)
    gp = _fill(gp_init(32, 2), X, y)
    q = jnp.asarray([[0.0, 0.0], [5.0, 5.0]])
    mu, sd = gp_posterior(gp, q, 1.0, 1.0, 0.05)
    assert float(sd[1]) > float(sd[0]) * 3
    assert abs(float(mu[1])) < 0.1          # reverts to prior mean


def test_gp_empty_slots_do_not_matter():
    """Posterior must be identical whether the buffer is tight or padded."""
    rng = np.random.default_rng(1)
    X = rng.normal(size=(8, 2)).astype(np.float32)
    y = rng.normal(size=8).astype(np.float32)
    g_small = _fill(gp_init(8, 2), X, y)
    g_big = _fill(gp_init(64, 2), X, y)
    q = jnp.asarray(rng.normal(size=(5, 2)).astype(np.float32))
    m1, s1 = gp_posterior(g_small, q, 1.3, 1.0, 0.05)
    m2, s2 = gp_posterior(g_big, q, 1.3, 1.0, 0.05)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


def test_gp_ring_overwrite():
    gp = gp_init(4, 1)
    for i in range(10):
        gp = gp_add(gp, jnp.asarray([float(i)]), float(i))
    assert int(gp.count) == 10
    # buffer holds the last 4 observations (6,7,8,9) in ring order
    assert sorted(np.asarray(gp.y).tolist()) == [6.0, 7.0, 8.0, 9.0]


@settings(max_examples=15, deadline=None)
@given(st.floats(0.3, 3.0), st.floats(0.2, 2.0))
def test_rbf_kernel_psd(ls, sv):
    X = jnp.asarray(np.random.default_rng(2).normal(size=(12, 4)),
                    jnp.float32)
    K = rbf(X, X, GPHypers(ls, sv, 0.0)) + 1e-5 * jnp.eye(12)
    evs = np.linalg.eigvalsh(np.asarray(K))
    assert evs.min() > -1e-5


# ---------------------------------------------------------------------------
# SafeOBO on a synthetic contextual bandit
# ---------------------------------------------------------------------------

class _SyntheticEnv:
    """Arm 0 cheap but unsafe on 'hard' contexts; arm 1 mid; arm 2 safe."""
    COST = [1.0, 10.0, 100.0]

    def __init__(self, seed=0):
        self.rng = np.random.default_rng(seed)

    def ctx(self):
        hard = self.rng.random() < 0.5
        # informative feature ARD-stretched (as context_features does) so the
        # fixed accuracy-GP lengthscale can separate the two regimes
        return np.array([6.0 if hard else 0.0, self.rng.random()],
                        np.float32), hard

    def play(self, arm, hard):
        acc_p = {0: 0.99 if not hard else 0.3, 1: 0.97, 2: 0.995}[arm]
        acc = float(self.rng.random() < acc_p)
        delay = {0: 0.3, 1: 1.0, 2: 2.0}[arm]
        return self.COST[arm], acc, delay


def test_safeobo_learns_context_dependent_policy():
    env = _SyntheticEnv()
    obo = SafeOBO(SafeOBOConfig(
        n_arms=3, context_dim=2, warmup_steps=200, capacity=256,
        qos_min_acc=0.80, qos_max_delay=5.0, safe_seed_arm=2,
        cost_scale=100.0), seed=0)
    picks_easy, picks_hard = [], []
    for t in range(600):
        ctx, hard = env.ctx()
        arm, info = obo.select(ctx)
        cost, acc, delay = env.play(arm, hard)
        obo.update(ctx, arm, cost=cost, accuracy=acc, delay=delay)
        if t >= 450:
            (picks_hard if hard else picks_easy).append(arm)
    # on easy contexts the cheap arm should dominate
    assert np.mean([a == 0 for a in picks_easy]) > 0.6, picks_easy
    # on hard contexts arm 0 must be avoided
    assert np.mean([a == 0 for a in picks_hard]) < 0.15, picks_hard


def test_safeobo_warmup_is_random_then_stops():
    obo = SafeOBO(SafeOBOConfig(n_arms=4, context_dim=2, warmup_steps=20),
                  seed=1)
    ctx = np.zeros(2, np.float32)
    for t in range(20):
        assert obo.in_warmup
        arm, info = obo.select(ctx)
        assert info["phase"] == "warmup"
        obo.update(ctx, arm, cost=1.0, accuracy=1.0, delay=0.1)
    assert not obo.in_warmup
    _, info = obo.select(ctx)
    assert info["phase"] == "exploit"


def test_safeobo_seed_arm_always_safe():
    obo = SafeOBO(SafeOBOConfig(n_arms=3, context_dim=2, warmup_steps=0,
                                safe_seed_arm=2, qos_min_acc=0.999,
                                qos_max_delay=0.001), seed=2)
    arm, info = obo.select(np.zeros(2, np.float32))
    assert 2 in info["safe"]
    assert arm == 2      # nothing else can be safe under impossible QoS
