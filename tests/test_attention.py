"""Attention-layer properties: blockwise==naive, sliding windows, RoPE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # tier-1 must collect without hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.models.layers import (
    apply_rope, causal_attention, decode_attention,
)


def _naive_causal(q, k, v, n_kv, window=0):
    B, S, H, hd = q.shape
    G = H // n_kv
    qg = q.reshape(B, S, n_kv, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kf) / np.sqrt(hd)
    i = jnp.arange(S)
    mask = i[None, :] <= i[:, None]
    if window:
        mask &= i[None, :] > (i[:, None] - window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd)


@pytest.mark.parametrize("S,q_chunk,window", [
    (64, 64, 0), (128, 32, 0), (128, 32, 48), (96, 48, 16), (256, 64, 64),
])
def test_blockwise_equals_naive(S, q_chunk, window):
    B, H, KV, hd = 2, 4, 2, 32
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd))
    out = causal_attention(q, k, v, n_kv=KV, window=window, q_chunk=q_chunk)
    ref = _naive_causal(q, k, v, KV, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_decode_matches_last_row_of_full():
    B, S, H, KV, hd = 2, 48, 4, 2, 32
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(4), (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(5), (B, S, KV, hd))
    full = _naive_causal(q, k, v, KV)
    dec = decode_attention(q[:, -1], k, v, jnp.full((B,), S), n_kv=KV)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                               atol=2e-5, rtol=2e-5)


def test_ring_buffer_decode_equals_windowed():
    """Ring-buffer cache (slot=pos%W) must give the same softmax as a
    windowed full cache (order independence)."""
    B, H, KV, hd, W = 1, 2, 1, 16, 8
    total = 20
    key = jax.random.PRNGKey(6)
    ks = jax.random.normal(key, (total, KV, hd))
    vs = jax.random.normal(jax.random.PRNGKey(7), (total, KV, hd))
    q = jax.random.normal(jax.random.PRNGKey(8), (B, H, hd))
    # windowed reference over the last W tokens
    pos = total - 1
    lo = pos - W + 1
    k_ref = ks[None, lo : pos + 1]
    v_ref = vs[None, lo : pos + 1]
    ref = decode_attention(q, k_ref, v_ref, jnp.array([W]), n_kv=KV)
    # ring cache
    ring_k = jnp.zeros((B, W, KV, hd))
    ring_v = jnp.zeros((B, W, KV, hd))
    for p in range(total):
        ring_k = ring_k.at[0, p % W].set(ks[p])
        ring_v = ring_v.at[0, p % W].set(vs[p])
    out = decode_attention(q, ring_k, ring_v, jnp.array([total]),
                           n_kv=KV, window=W, ring=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 16), st.integers(1, 500))
def test_rope_preserves_norm(dim_half, pos):
    d = dim_half * 2
    x = jnp.arange(1, d + 1, dtype=jnp.float32).reshape(1, 1, 1, d)
    y = apply_rope(x, jnp.array([[pos]]), 10000.0)
    np.testing.assert_allclose(float(jnp.linalg.norm(y)),
                               float(jnp.linalg.norm(x)), rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 64), st.integers(1, 64))
def test_rope_relative_property(p0, delta):
    """<rope(q,p0+d), rope(k,p0)> depends only on d, not p0."""
    d = 16
    key = jax.random.PRNGKey(11)
    q = jax.random.normal(key, (1, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(12), (1, 1, 1, d))

    def score(p):
        qr = apply_rope(q, jnp.array([[p + delta]]), 1000.0)
        kr = apply_rope(k, jnp.array([[p]]), 1000.0)
        return float(jnp.sum(qr * kr))

    assert abs(score(p0) - score(p0 + 37)) < 1e-3
