"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import ops as da_ops
from repro.kernels.decode_attention.kernel import (
    decode_attention_pallas, paged_append_attention_pallas,
    paged_decode_attention_pallas,
)
from repro.kernels.decode_attention.ref import (
    decode_attention_ref, paged_append_attention_ref,
    paged_decode_attention_ref,
)
from repro.kernels.retrieval_topk.kernel import retrieval_topk_pallas
from repro.kernels.retrieval_topk.ref import retrieval_topk_ref
from repro.kernels.rbf.kernel import rbf_matrix_pallas
from repro.kernels.rbf.ref import rbf_matrix_ref


@pytest.mark.parametrize("B,H,KV,hd,S,block_s", [
    (1, 4, 1, 64, 128, 64),
    (2, 8, 2, 128, 512, 128),
    (3, 14, 2, 64, 256, 256),      # qwen2-0.5b geometry
    (2, 8, 4, 256, 384, 128),      # gemma3 geometry
    (1, 16, 16, 128, 512, 512),    # MHA, single block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, H, KV, hd, S, block_s, dtype):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(42), 4)
    q = jax.random.normal(k1, (B, H, hd), dtype)
    k = jax.random.normal(k2, (B, S, KV, hd), dtype)
    v = jax.random.normal(k3, (B, S, KV, hd), dtype)
    lengths = jax.random.randint(k4, (B,), 1, S + 1)
    out = decode_attention_pallas(q, k, v, lengths, block_s=block_s)
    ref = decode_attention_ref(q, k, v, lengths)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_decode_attention_length_mask_strict():
    """Cache contents beyond `length` must not influence the output."""
    B, H, KV, hd, S = 1, 4, 2, 64, 128
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd))
    lengths = jnp.array([40])
    out1 = decode_attention_pallas(q, k, v, lengths)
    k2 = k.at[:, 40:].set(999.0)
    v2 = v.at[:, 40:].set(-999.0)
    out2 = decode_attention_pallas(q, k2, v2, lengths)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


@pytest.mark.parametrize("S,block_s", [
    (64, 256),     # S < block_s: used to collapse to a zero-size seq grid
    (100, 256),    # S < block_s AND not an 8-multiple
    (4, 256),      # S smaller than the minimum 8-row tile
    (40, 16),      # ragged tail: S not a multiple of block_s
])
def test_decode_attention_block_clamp_regression(S, block_s):
    """ops hardcoding block_s=256 must not yield S // block_s == 0 programs
    (or silently drop a ragged tail) for short caches."""
    B, H, KV, hd = 2, 4, 2, 64
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(k1, (B, H, hd))
    k = jax.random.normal(k2, (B, S, KV, hd))
    v = jax.random.normal(k3, (B, S, KV, hd))
    lengths = jnp.array([S, max(1, S - 3)])
    out = decode_attention_pallas(q, k, v, lengths, block_s=block_s)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    # the public dispatch with its default block_s must agree too
    out2 = da_ops.decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("length", ["zero", "full", "ragged"])
def test_decode_attention_length_edges(length):
    """length=0 (defined: zeros), length=S, and length not a multiple of
    block_s must all match the oracle."""
    B, H, KV, hd, S, bs = 2, 4, 2, 64, 128, 32
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(k1, (B, H, hd))
    k = jax.random.normal(k2, (B, S, KV, hd))
    v = jax.random.normal(k3, (B, S, KV, hd))
    lengths = {"zero": jnp.array([0, 0]),
               "full": jnp.array([S, S]),
               "ragged": jnp.array([bs - 5, S - 7])}[length]
    out = decode_attention_pallas(q, k, v, lengths, block_s=bs)
    ref = decode_attention_ref(q, k, v, lengths)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    if length == "zero":
        assert (np.asarray(out) == 0).all()


# ---------------------------------------------------------------------------
# Paged flash-decode
# ---------------------------------------------------------------------------

def _ragged_paged_cache(B, P, ps, KV, hd, pages_per_row, seed=0):
    """Random arenas + page tables with distinct physical pages per row
    (scattered, unordered) and trash-page-0 padding."""
    rng = np.random.default_rng(seed)
    k_arena = jnp.asarray(rng.normal(size=(P, ps, KV, hd)).astype(np.float32))
    v_arena = jnp.asarray(rng.normal(size=(P, ps, KV, hd)).astype(np.float32))
    n_pages = max(pages_per_row)
    pt = np.zeros((B, n_pages), np.int32)
    perm = rng.permutation(np.arange(1, P))
    used = 0
    for b, n in enumerate(pages_per_row):
        pt[b, :n] = perm[used:used + n]
        used += n
    return k_arena, v_arena, jnp.asarray(pt)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_matches_paged_ref(dtype):
    B, H, KV, hd, ps = 3, 8, 2, 64, 16
    P = 32
    k_arena, v_arena, pt = _ragged_paged_cache(B, P, ps, KV, hd, [6, 3, 1])
    k_arena = k_arena.astype(dtype)
    v_arena = v_arena.astype(dtype)
    q = jax.random.normal(jax.random.PRNGKey(1), (B, H, hd), dtype)
    lengths = jnp.array([6 * ps, 3 * ps - 5, 1], jnp.int32)
    out = paged_decode_attention_pallas(q, k_arena, v_arena, pt, lengths)
    ref = paged_decode_attention_ref(q, k_arena, v_arena, pt, lengths)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_paged_matches_contiguous_oracle_ragged_tables():
    """Paged kernel output on a scattered arena == the contiguous oracle on
    the logically reassembled cache, to fp32 tolerance."""
    B, H, KV, hd, ps = 4, 8, 4, 64, 8
    P = 64
    k_arena, v_arena, pt = _ragged_paged_cache(B, P, ps, KV, hd,
                                               [7, 5, 2, 1], seed=3)
    n_pages = pt.shape[1]
    q = jax.random.normal(jax.random.PRNGKey(2), (B, H, hd))
    lengths = jnp.array([7 * ps, 5 * ps - 3, ps + 1, 0], jnp.int32)
    out = paged_decode_attention_pallas(q, k_arena, v_arena, pt, lengths)
    k_c = k_arena[pt].reshape(B, n_pages * ps, KV, hd)
    v_c = v_arena[pt].reshape(B, n_pages * ps, KV, hd)
    ref = decode_attention_ref(q, k_c, v_c, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    assert (np.asarray(out)[3] == 0).all()        # length-0 row contract


def test_paged_trash_page_contents_never_leak():
    """Whatever lives in the trash page (id 0) and in pages past a row's
    valid length must not influence the output."""
    B, H, KV, hd, ps = 2, 4, 2, 64, 16
    P = 16
    k_arena, v_arena, pt = _ragged_paged_cache(B, P, ps, KV, hd, [4, 2])
    q = jax.random.normal(jax.random.PRNGKey(4), (B, H, hd))
    lengths = jnp.array([4 * ps - 9, 2 * ps - 1], jnp.int32)
    out1 = paged_decode_attention_pallas(q, k_arena, v_arena, pt, lengths)
    k2 = k_arena.at[0].set(999.0)                 # poison trash page
    v2 = v_arena.at[0].set(-999.0)
    # poison the tail of each row's last valid page too
    k2 = k2.at[pt[0, 3], ps - 9:].set(777.0)
    v2 = v2.at[pt[0, 3], ps - 9:].set(-777.0)
    out2 = paged_decode_attention_pallas(q, k2, v2, pt, lengths)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


# ---------------------------------------------------------------------------
# Paged append attention (chunked suffix prefill)
# ---------------------------------------------------------------------------

def _append_case(P, ps, KV, hd, n_pages, seed=0):
    rng = np.random.default_rng(seed)
    k_arena = jnp.asarray(rng.normal(size=(P, ps, KV, hd)).astype(np.float32))
    v_arena = jnp.asarray(rng.normal(size=(P, ps, KV, hd)).astype(np.float32))
    pt = np.zeros(n_pages, np.int32)
    perm = rng.permutation(np.arange(1, P))
    pt[:] = perm[:n_pages]
    return k_arena, v_arena, jnp.asarray(pt)


@pytest.mark.parametrize("H,KV,hd,ps,S,prefix,suffix,block_q", [
    (8, 2, 64, 16, 64, 21, 33, 16),     # ragged prefix/suffix, small chunks
    (14, 2, 64, 16, 96, 0, 96, 128),    # full prefill (no prefix), clamp bq
    (8, 4, 128, 8, 32, 40, 7, 32),      # long prefix, tiny suffix + padding
    (4, 4, 64, 32, 40, 32, 40, 128),    # MHA, page-aligned prefix, bq->40
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_append_matches_ref(H, KV, hd, ps, S, prefix, suffix, block_q,
                                  dtype):
    P = 24
    n_pages = -(-(prefix + suffix) // ps) + 1
    k_arena, v_arena, pt = _append_case(P, ps, KV, hd, n_pages)
    k_arena = k_arena.astype(dtype)
    v_arena = v_arena.astype(dtype)
    q = jax.random.normal(jax.random.PRNGKey(1), (S, H, hd), dtype)
    lens = jnp.asarray([prefix, prefix + suffix], jnp.int32)
    out = paged_append_attention_pallas(q, k_arena, v_arena, pt, lens,
                                        block_q=block_q)
    ref = paged_append_attention_ref(q, k_arena, v_arena, pt,
                                     jnp.int32(prefix),
                                     jnp.int32(prefix + suffix))
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)
    # padded q rows (beyond the valid suffix) are defined zeros
    if suffix < S:
        assert (np.asarray(out, np.float32)[suffix:] == 0).all()


def test_paged_append_rejects_unpadded_suffix():
    """S not a multiple of 8 violates the sublane-layout contract and must
    raise a clear error, not derail the block_q clamp."""
    k_arena, v_arena, pt = _append_case(8, 16, 2, 64, 2)
    q = jax.random.normal(jax.random.PRNGKey(0), (20, 4, 64))
    with pytest.raises(ValueError, match="multiple of 8"):
        paged_append_attention_pallas(q, k_arena, v_arena, pt,
                                      jnp.asarray([0, 20], jnp.int32))


def test_paged_append_last_row_equals_decode():
    """The append kernel's last valid row must equal the decode kernel run
    on that single token — they are the same attention at chunk size 1."""
    H, KV, hd, ps = 8, 2, 64, 16
    prefix, suffix = 19, 24
    n_pages = -(-(prefix + suffix) // ps)
    k_arena, v_arena, pt = _append_case(32, ps, KV, hd, n_pages, seed=5)
    q = jax.random.normal(jax.random.PRNGKey(2), (suffix, H, hd))
    lens = jnp.asarray([prefix, prefix + suffix], jnp.int32)
    out = paged_append_attention_pallas(q, k_arena, v_arena, pt, lens,
                                        block_q=8)
    dec = paged_decode_attention_pallas(
        q[suffix - 1][None], k_arena, v_arena, pt[None],
        jnp.asarray([prefix + suffix], jnp.int32))
    np.testing.assert_allclose(np.asarray(out)[suffix - 1], np.asarray(dec)[0],
                               atol=1e-5, rtol=1e-5)


def test_paged_append_causal_and_stale_page_masking():
    """Keys at positions > the query's (later suffix tokens) and stale data
    beyond total_len — including the trash page — must not leak in."""
    H, KV, hd, ps = 4, 2, 64, 16
    prefix, suffix = 16, 9
    n_pages = 3
    k_arena, v_arena, pt = _append_case(16, ps, KV, hd, n_pages, seed=7)
    q = jax.random.normal(jax.random.PRNGKey(3), (16, H, hd))
    lens = jnp.asarray([prefix, prefix + suffix], jnp.int32)
    out1 = paged_append_attention_pallas(q, k_arena, v_arena, pt, lens)
    # poison everything at/after total_len plus the whole trash page
    total = prefix + suffix
    k2 = k_arena.at[0].set(999.0)
    v2 = v_arena.at[0].set(-999.0)
    k2 = k2.at[pt[1], total - ps:].set(777.0)
    v2 = v2.at[pt[1], total - ps:].set(-777.0)
    k2 = k2.at[pt[2]].set(555.0)
    v2 = v2.at[pt[2]].set(-555.0)
    out2 = paged_append_attention_pallas(q, k2, v2, pt, lens)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


@pytest.mark.parametrize("N,D,k,block_n,n_valid", [
    (100, 384, 5, 64, None),
    (1000, 384, 5, 256, 900),
    (513, 128, 8, 512, 513),
    (64, 384, 3, 64, 10),
    (2048, 256, 1, 512, None),
])
def test_retrieval_topk_sweep(N, D, k, block_n, n_valid):
    key = jax.random.PRNGKey(7)
    emb = jax.random.normal(key, (N, D), jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(8), (D,), jnp.float32)
    v, i = retrieval_topk_pallas(emb, q, k, block_n=block_n, n_valid=n_valid)
    vr, ir = retrieval_topk_ref(emb, q, k, n_valid=n_valid)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), atol=1e-4)
    assert (np.asarray(i) == np.asarray(ir)).all()


@pytest.mark.parametrize("M,N,D", [(10, 10, 7), (300, 200, 11),
                                   (128, 128, 384), (257, 65, 16)])
@pytest.mark.parametrize("ls,sv", [(1.0, 1.0), (0.5, 2.0), (3.0, 0.25)])
def test_rbf_sweep(M, N, D, ls, sv):
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x1 = jax.random.normal(k1, (M, D))
    x2 = jax.random.normal(k2, (N, D))
    K = rbf_matrix_pallas(x1, x2, ls, sv)
    Kr = rbf_matrix_ref(x1, x2, ls, sv)
    np.testing.assert_allclose(np.asarray(K), np.asarray(Kr),
                               atol=1e-5, rtol=1e-5)


def test_rbf_diagonal_is_signal_var():
    x = jax.random.normal(jax.random.PRNGKey(0), (50, 9))
    K = rbf_matrix_pallas(x, x, 1.7, 0.8)
    np.testing.assert_allclose(np.asarray(jnp.diagonal(K)), 0.8, atol=1e-5)
