"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.retrieval_topk.kernel import retrieval_topk_pallas
from repro.kernels.retrieval_topk.ref import retrieval_topk_ref
from repro.kernels.rbf.kernel import rbf_matrix_pallas
from repro.kernels.rbf.ref import rbf_matrix_ref


@pytest.mark.parametrize("B,H,KV,hd,S,block_s", [
    (1, 4, 1, 64, 128, 64),
    (2, 8, 2, 128, 512, 128),
    (3, 14, 2, 64, 256, 256),      # qwen2-0.5b geometry
    (2, 8, 4, 256, 384, 128),      # gemma3 geometry
    (1, 16, 16, 128, 512, 512),    # MHA, single block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, H, KV, hd, S, block_s, dtype):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(42), 4)
    q = jax.random.normal(k1, (B, H, hd), dtype)
    k = jax.random.normal(k2, (B, S, KV, hd), dtype)
    v = jax.random.normal(k3, (B, S, KV, hd), dtype)
    lengths = jax.random.randint(k4, (B,), 1, S + 1)
    out = decode_attention_pallas(q, k, v, lengths, block_s=block_s)
    ref = decode_attention_ref(q, k, v, lengths)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_decode_attention_length_mask_strict():
    """Cache contents beyond `length` must not influence the output."""
    B, H, KV, hd, S = 1, 4, 2, 64, 128
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd))
    lengths = jnp.array([40])
    out1 = decode_attention_pallas(q, k, v, lengths)
    k2 = k.at[:, 40:].set(999.0)
    v2 = v.at[:, 40:].set(-999.0)
    out2 = decode_attention_pallas(q, k2, v2, lengths)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


@pytest.mark.parametrize("N,D,k,block_n,n_valid", [
    (100, 384, 5, 64, None),
    (1000, 384, 5, 256, 900),
    (513, 128, 8, 512, 513),
    (64, 384, 3, 64, 10),
    (2048, 256, 1, 512, None),
])
def test_retrieval_topk_sweep(N, D, k, block_n, n_valid):
    key = jax.random.PRNGKey(7)
    emb = jax.random.normal(key, (N, D), jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(8), (D,), jnp.float32)
    v, i = retrieval_topk_pallas(emb, q, k, block_n=block_n, n_valid=n_valid)
    vr, ir = retrieval_topk_ref(emb, q, k, n_valid=n_valid)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), atol=1e-4)
    assert (np.asarray(i) == np.asarray(ir)).all()


@pytest.mark.parametrize("M,N,D", [(10, 10, 7), (300, 200, 11),
                                   (128, 128, 384), (257, 65, 16)])
@pytest.mark.parametrize("ls,sv", [(1.0, 1.0), (0.5, 2.0), (3.0, 0.25)])
def test_rbf_sweep(M, N, D, ls, sv):
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x1 = jax.random.normal(k1, (M, D))
    x2 = jax.random.normal(k2, (N, D))
    K = rbf_matrix_pallas(x1, x2, ls, sv)
    Kr = rbf_matrix_ref(x1, x2, ls, sv)
    np.testing.assert_allclose(np.asarray(K), np.asarray(Kr),
                               atol=1e-5, rtol=1e-5)


def test_rbf_diagonal_is_signal_var():
    x = jax.random.normal(jax.random.PRNGKey(0), (50, 9))
    K = rbf_matrix_pallas(x, x, 1.7, 0.8)
    np.testing.assert_allclose(np.asarray(jnp.diagonal(K)), 0.8, atol=1e-5)
