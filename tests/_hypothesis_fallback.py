"""Stand-ins for ``hypothesis`` so tier-1 collection works without it.

Property-test modules import via::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, st

When hypothesis is installed (see requirements-dev.txt) the real library is
used and the property tests run; when it is missing, each ``@given`` test
becomes a cleanly-skipped stub and every other test in the module still
runs — a missing dev dependency must never break tier-1 collection.
"""
from __future__ import annotations

import pytest


class _DummyStrategy:
    """Absorbs any strategy construction/chaining at decoration time."""

    def __call__(self, *args, **kwargs):
        return self

    def __getattr__(self, name):
        return self


st = _DummyStrategy()


def given(*args, **kwargs):
    def deco(fn):
        # plain zero-arg stub: pytest must not see hypothesis-injected
        # parameters as fixture requests
        def stub():
            pytest.skip("hypothesis not installed (pip install -r "
                        "requirements-dev.txt)")
        stub.__name__ = fn.__name__
        stub.__doc__ = fn.__doc__
        return stub
    return deco


def settings(*args, **kwargs):
    def deco(fn):
        return fn
    return deco
