"""Circuit-breaker edge cases around the half-open probe protocol — the
transitions the DST breaker-legality oracle enforces. Pure virtual-time
state machine, no engines, no JAX."""
import pytest

from repro.serving.health import CLOSED, HALF_OPEN, OPEN, CircuitBreaker


def tripped(threshold=2, reset=5.0, at=0.0):
    b = CircuitBreaker(threshold=threshold, reset_timeout_s=reset)
    for _ in range(threshold):
        b.record_failure(at)
    assert b.state(at) == OPEN
    return b


# ---------------------------------------------------------------------------
# Half-open probe loss re-opens with a full backoff window
# ---------------------------------------------------------------------------

def test_probe_loss_reopens_with_backoff():
    """A failed half-open probe restarts the reset window from the failure
    time: the breaker must stay open for a FULL reset_timeout_s again, not
    re-enter half-open early on the stale opened_at."""
    b = tripped(reset=5.0, at=0.0)
    assert b.state(5.0) == HALF_OPEN
    b.begin_probe(5.0)
    b.record_failure(6.0)            # probe's work was lost
    assert b.state(6.0) == OPEN
    assert b.opened_at == 6.0        # window counts from the new failure
    assert b.state(10.9) == OPEN     # 6.0 + 5.0 not yet elapsed
    assert b.state(11.0) == HALF_OPEN
    assert b.trips == 2              # the re-open is a counted trip


def test_repeated_probe_losses_each_restart_the_window():
    b = tripped(reset=2.0, at=0.0)
    t = 0.0
    for _ in range(3):
        t += 2.0
        assert b.state(t) == HALF_OPEN
        b.begin_probe(t)
        b.record_failure(t + 0.5)
        t += 0.5
        assert b.state(t) == OPEN
        assert b.opened_at == t
    assert b.trips == 4 and b.probes == 3


# ---------------------------------------------------------------------------
# Concurrent probe exclusion
# ---------------------------------------------------------------------------

def test_single_probe_slot_excludes_concurrent_probes():
    """Exactly one in-flight probe: once a caller commits via begin_probe,
    allow() must refuse a second admission until the probe resolves."""
    b = tripped(reset=1.0, at=0.0)
    assert b.allow(1.0)              # half-open, slot free
    assert b.allow(1.0)              # allow alone never consumes the slot
    assert not b.probing
    b.begin_probe(1.0)
    assert b.probing and b.probes == 1
    assert not b.allow(1.0)          # slot occupied: no concurrent probe
    assert not b.allow(1.5)
    b.begin_probe(1.5)               # double-commit is a no-op
    assert b.probes == 1
    b.record_success(2.0)
    assert b.state(2.0) == CLOSED and not b.probing
    assert b.allow(2.0)


def test_probe_slot_freed_by_failure():
    b = tripped(reset=1.0, at=0.0)
    b.begin_probe(1.0)
    b.record_failure(1.2)
    assert not b.probing             # failure releases the slot...
    assert not b.allow(1.3)          # ...but the breaker is open again
    assert b.state(2.2) == HALF_OPEN
    assert b.allow(2.2)              # next probe window admits again


# ---------------------------------------------------------------------------
# Crash-during-half-open legality
# ---------------------------------------------------------------------------

def test_crash_during_half_open_reopens_legally():
    """An engine crash while its breaker is half-open (probe in flight or
    not) lands as record_failure: the only legal successor states are
    open (failure) or closed (success) — exactly what the DST oracle
    checks via snapshot()."""
    b = tripped(reset=3.0, at=0.0)
    assert b.state(3.0) == HALF_OPEN
    snap = b.snapshot(3.0)
    assert snap["state"] == HALF_OPEN and not snap["probing"]
    # crash reaps the pool member before any probe was committed
    b.record_failure(3.4)
    snap = b.snapshot(3.4)
    assert snap["state"] == OPEN and snap["opened_at"] == 3.4
    # half_open may only be observed after a FULL window from opened_at
    assert b.state(3.4 + 3.0 - 0.01) == OPEN
    assert b.state(3.4 + 3.0) == HALF_OPEN


def test_success_from_open_is_legal_inflight_pretrip_work():
    """Work admitted before the trip may complete while the breaker is
    open; its success legally closes the breaker early."""
    b = tripped(reset=5.0, at=0.0)
    b.record_success(1.0)
    assert b.state(1.0) == CLOSED
    assert b.consecutive_failures == 0


# ---------------------------------------------------------------------------
# Snapshot semantics
# ---------------------------------------------------------------------------

def test_snapshot_tracks_state_machine():
    b = CircuitBreaker(threshold=1, reset_timeout_s=2.0)
    assert b.snapshot(0.0) == {"state": CLOSED, "failures": 0,
                               "probing": False, "opened_at": 0.0,
                               "trips": 0, "probes": 0}
    b.record_failure(1.0)
    s = b.snapshot(1.0)
    assert s["state"] == OPEN and s["trips"] == 1 and s["opened_at"] == 1.0
    s = b.snapshot(3.0)
    assert s["state"] == HALF_OPEN
    b.begin_probe(3.0)
    s = b.snapshot(3.0)
    assert s["probing"] and s["probes"] == 1
    b.record_success(3.5)
    s = b.snapshot(3.5)
    assert s == {"state": CLOSED, "failures": 0, "probing": False,
                 "opened_at": 1.0, "trips": 1, "probes": 1}


def test_constructor_validation():
    with pytest.raises(ValueError):
        CircuitBreaker(threshold=0)
    with pytest.raises(ValueError):
        CircuitBreaker(reset_timeout_s=0.0)
