"""Retrieval substrate: embedder, store FIFO, overlap, GraphRAG, updates."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # tier-1 must collect without hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.core.edge_assist import query_keywords, select_edge
from repro.core.knowledge import AdaptiveKnowledgeUpdater, KnowledgeUpdateConfig
from repro.data.corpus import wiki_like
from repro.retrieval.embedder import embed, embed_batch, cosine
from repro.retrieval.graph_rag import KnowledgeGraph
from repro.retrieval.store import VectorStore, make_chunk


@pytest.fixture(scope="module")
def corpus():
    return wiki_like(seed=0)


def test_embedder_deterministic_and_normalized():
    e1 = embed("the amber falcon guards the harbor")
    e2 = embed("the amber falcon guards the harbor")
    np.testing.assert_array_equal(e1, e2)
    assert abs(np.linalg.norm(e1) - 1.0) < 1e-5


def test_embedder_similarity_ordering():
    a = embed("the capital of france is paris")
    b = embed("paris is the capital city of france")
    c = embed("quantum chromodynamics lattice simulation")
    assert cosine(a, b) > cosine(a, c) + 0.2


@settings(max_examples=20, deadline=None)
@given(st.text(alphabet="abcdefg hij", min_size=1, max_size=60))
def test_embedder_never_nan(text):
    v = embed(text)
    assert np.isfinite(v).all()


def test_store_fifo_capacity():
    store = VectorStore(capacity=10)
    chunks = [make_chunk(f"fact number {i} about entity{i}") for i in range(25)]
    evicted = store.add(chunks)
    assert len(store) == 10
    assert evicted == 15
    # the newest chunks survive
    assert store.chunks[-1].text == chunks[-1].text
    assert store.chunks[0].text == chunks[15].text


def test_store_search_finds_relevant(corpus):
    store = VectorStore(capacity=2000)
    store.add(corpus.chunks)
    fact = corpus.facts[0]
    q = f"What is the {fact.attr} of {fact.entity}?"
    results = store.search(q, k=5)
    assert any(fact.value in c.text for c, _ in results), "gold chunk in top-5"


def test_overlap_ratio_bounds(corpus):
    store = VectorStore(capacity=2000)
    store.add(corpus.chunks[:20])
    kws = query_keywords(corpus.qa[0].question)
    r = store.overlap_ratio(kws)
    assert 0.0 <= r <= 1.0
    assert store.overlap_ratio([]) == 0.0


def test_select_edge_prefers_coverage(corpus):
    t0, t1 = corpus.topics[0], corpus.topics[1]
    s0, s1 = VectorStore(500), VectorStore(500)
    s0.add(corpus.chunks_for_topic(t0))
    s1.add(corpus.chunks_for_topic(t1))
    qa = next(q for q in corpus.qa if q.topic == t1 and not q.multihop)
    sel = select_edge({"e0": s0, "e1": s1}, qa.question)
    assert sel.edge_id == "e1"
    assert sel.overlap > 0.4


def test_graph_communities_cover_chunks(corpus):
    g = KnowledgeGraph(seed=0).build(corpus.chunks)
    assert len(g.communities) >= 2
    covered = set()
    for com in g.communities.values():
        covered.update(com.chunk_ids)
    assert len(covered) >= 0.9 * len(corpus.chunks)


def test_graph_retrieval_hits_gold(corpus):
    g = KnowledgeGraph(seed=0).build(corpus.chunks)
    hits = 0
    singles = [q for q in corpus.qa if not q.multihop][:40]
    for qa in singles:
        res = g.retrieve(qa.question, k=10)
        hits += any(qa.answer in c.text for c, _ in res)
    assert hits / len(singles) > 0.6


def test_adaptive_update_trigger(corpus):
    g = KnowledgeGraph(seed=0).build(corpus.chunks)
    upd = AdaptiveKnowledgeUpdater(g, KnowledgeUpdateConfig(
        update_trigger=5, max_chunks_per_update=50))
    store = VectorStore(capacity=100)
    fired = []
    for i, qa in enumerate(corpus.qa[:12]):
        fired.append(upd.observe_query("e0", qa.question, store))
    assert sum(fired) == 2                    # every 5 queries
    assert len(store) > 0
    st_ = upd.stats["e0"]
    assert st_.updates == 2
    assert st_.chunks_shipped <= 100


def test_update_improves_coverage(corpus):
    """After updates driven by topic-X queries, the store covers topic X."""
    g = KnowledgeGraph(seed=0).build(corpus.chunks)
    upd = AdaptiveKnowledgeUpdater(g, KnowledgeUpdateConfig(
        update_trigger=5, max_chunks_per_update=200))
    store = VectorStore(capacity=400)
    topic = corpus.topics[2]
    qs = [q for q in corpus.qa if q.topic == topic][:10]
    before = store.overlap_ratio(query_keywords(qs[-1].question))
    for qa in qs:
        upd.observe_query("e0", qa.question, store)
    after = store.overlap_ratio(query_keywords(qs[-1].question))
    assert after > before
    assert after > 0.5
