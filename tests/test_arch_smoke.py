"""Per-architecture smoke tests (deliverable f): each assigned arch's
REDUCED variant (<=2 layers, d_model<=512, <=4 experts) runs one forward /
train step on CPU with finite outputs and correct shapes, plus a
prefill->decode consistency check against the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model

ARCH_IDS = sorted(ARCHS)


def _memory(model, B):
    ei = model.extra_input_defs(B)
    if not ei:
        return None
    d = ei["memory"]
    return jnp.full(d.shape, 0.01, d.dtype)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_limits(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe.n_experts:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, rng_key):
    B, S = 2, 64
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg, max_seq=S)
    params = model.init(rng_key)
    tokens = jax.random.randint(rng_key, (B, S), 0, cfg.vocab)
    targets = jnp.roll(tokens, -1, axis=1)
    batch = {"tokens": tokens, "targets": targets}
    mem = _memory(model, B)
    if mem is not None:
        batch["memory"] = mem
    loss, metrics = model.train_loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), (arch, loss)
    # gradients exist and are finite for every leaf
    grads = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert leaves
    for g in leaves:
        assert bool(jnp.isfinite(g.astype(jnp.float32)).all()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch, rng_key):
    B, S = 2, 32
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg, max_seq=S + 4)
    params = model.init(rng_key)
    tokens = jax.random.randint(rng_key, (B, S), 0, cfg.vocab)
    nxt = jax.random.randint(jax.random.PRNGKey(9), (B, 1), 0, cfg.vocab)
    mem = _memory(model, B)
    full = model.forward_logits(params, jnp.concatenate([tokens, nxt], 1), mem)
    logits_p, cache = model.prefill(params, tokens, mem)
    assert logits_p.shape == (B, cfg.vocab)
    logits_d, _ = model.decode_step(params, cache, nxt,
                                    jnp.full((B,), S, jnp.int32))
    assert logits_d.shape == (B, cfg.vocab)
    scale = float(jnp.abs(full).max()) + 1e-6
    err_p = float(jnp.abs(logits_p - full[:, S - 1]).max()) / scale
    err_d = float(jnp.abs(logits_d - full[:, S]).max()) / scale
    # MoE capacity-dropping differs between batch shapes -> looser bound;
    # hybrid (chunked SSD scan) is sensitive to bf16 reduction reassociation
    tol = 0.08 if cfg.family == "moe" else (
        0.02 if cfg.family == "hybrid" else 5e-3)
    assert err_p < tol, (arch, err_p)
    assert err_d < tol, (arch, err_d)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    spec = {
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "rwkv6-3b": (32, 2560, 0, 0, 8960, 65536),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == spec
    assert cfg.source, "config must cite its source"


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "gemma3-4b", "qwen2-72b"])
def test_int8_kv_cache_decode_consistency(arch, rng_key):
    """int8 per-(token,head) absmax KV quantization: <2% relative logit
    error vs the bf16 cache path (the §Perf pair-2 serving optimization)."""
    import dataclasses
    B, S = 2, 32
    cfg = dataclasses.replace(get_config(arch, reduced=True),
                              kv_cache_dtype="int8")
    model = build_model(cfg, max_seq=S + 4)
    params = model.init(rng_key)
    tokens = jax.random.randint(rng_key, (B, S), 0, cfg.vocab)
    nxt = jax.random.randint(jax.random.PRNGKey(5), (B, 1), 0, cfg.vocab)
    full = model.forward_logits(params, jnp.concatenate([tokens, nxt], 1))
    _, cache = model.prefill(params, tokens)
    # caches must actually be int8
    dtypes = {str(l.dtype) for l in jax.tree.leaves(cache)}
    assert "int8" in dtypes, dtypes
    logits_d, _ = model.decode_step(params, cache, nxt,
                                    jnp.full((B,), S, jnp.int32))
    scale = float(jnp.abs(full).max()) + 1e-6
    err = float(jnp.abs(logits_d - full[:, S]).max()) / scale
    assert err < 0.02, (arch, err)
