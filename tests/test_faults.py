"""Direct unit tests for the deterministic fault injector: stall-window
rotation, net-spike windows, seeded completion-drop determinism, and the
hard-failure schedules (crashes, partitions) added for crash tolerance.
Pure functions of virtual time — no engines, no JAX."""
import numpy as np
import pytest

from repro.cluster.faults import FaultConfig, FaultInjector


# ---------------------------------------------------------------------------
# Stalls
# ---------------------------------------------------------------------------

def test_stall_window_and_rotation():
    """Within each period the first ``stall_duration_s`` freezes exactly one
    pool member, and the victim rotates across cycles."""
    fi = FaultInjector(FaultConfig(stall_period_s=10.0, stall_duration_s=2.0))
    # cycle 0 (t in [0, 10)): victim is member 0
    assert fi.stalled("edge", 0, 1.0, pool_size=2)
    assert not fi.stalled("edge", 1, 1.0, pool_size=2)
    assert not fi.stalled("edge", 0, 5.0, pool_size=2)   # window over
    # cycle 1: victim rotates to member 1
    assert fi.stalled("edge", 1, 11.0, pool_size=2)
    assert not fi.stalled("edge", 0, 11.0, pool_size=2)
    assert fi.stall_hits == 2


def test_stall_respects_start_and_tiers():
    fi = FaultInjector(FaultConfig(stall_period_s=10.0, stall_duration_s=2.0,
                                   stall_start_s=100.0,
                                   stall_tiers=("edge",)))
    assert not fi.stalled("edge", 0, 1.0)        # before stall_start_s
    assert fi.stalled("edge", 0, 101.0)
    assert not fi.stalled("cloud", 0, 101.0)     # unlisted tier never stalls


# ---------------------------------------------------------------------------
# Crashes
# ---------------------------------------------------------------------------

def test_crash_window_rotates_like_stalls():
    fi = FaultInjector(FaultConfig(crash_period_s=8.0, crash_duration_s=1.0))
    assert fi.crashed("edge", 0, 0.5, pool_size=2)
    assert not fi.crashed("edge", 1, 0.5, pool_size=2)
    assert not fi.crashed("edge", 0, 2.0, pool_size=2)   # window over
    assert fi.crashed("edge", 1, 8.5, pool_size=2)       # rotated victim
    assert fi.crash_hits == 2


def test_crash_rotate_false_pins_member_zero():
    """The one-flaky-node pattern: every crash lands on pool member 0, the
    case per-engine circuit breakers exist for."""
    fi = FaultInjector(FaultConfig(crash_period_s=5.0, crash_duration_s=1.0,
                                   crash_rotate=False))
    for cycle in range(4):
        t = 5.0 * cycle + 0.25
        assert fi.crashed("edge", 0, t, pool_size=3)
        assert not fi.crashed("edge", 1, t, pool_size=3)
        assert not fi.crashed("edge", 2, t, pool_size=3)


def test_crash_respects_start_and_tiers():
    fi = FaultInjector(FaultConfig(crash_period_s=5.0, crash_duration_s=1.0,
                                   crash_start_s=20.0,
                                   crash_tiers=("cloud",)))
    assert not fi.crashed("cloud", 0, 0.5)
    assert fi.crashed("cloud", 0, 20.5)
    assert not fi.crashed("edge", 0, 20.5)


def test_crash_disabled_by_default():
    fi = FaultInjector(FaultConfig())
    assert not fi.crashed("edge", 0, 1.0)
    assert not fi.partitioned(1.0)
    assert fi.crash_hits == 0 and fi.partition_hits == 0


# ---------------------------------------------------------------------------
# Partitions
# ---------------------------------------------------------------------------

def test_partition_window_phases():
    fi = FaultInjector(FaultConfig(partition_period_s=10.0,
                                   partition_duration_s=3.0,
                                   partition_start_s=5.0))
    assert not fi.partitioned(4.0)     # before start
    assert fi.partitioned(5.5)         # inside first window
    assert fi.partitioned(7.9)
    assert not fi.partitioned(8.5)     # healed
    assert fi.partitioned(15.5)        # next cycle
    assert fi.partition_hits == 3


# ---------------------------------------------------------------------------
# Net spikes and drops
# ---------------------------------------------------------------------------

def test_net_spike_window():
    fi = FaultInjector(FaultConfig(net_spike_period_s=4.0,
                                   net_spike_duration_s=1.0,
                                   net_spike_extra_s=0.7))
    assert fi.net_spike(0.5) == pytest.approx(0.7)
    assert fi.net_spike(2.0) == 0.0
    assert fi.net_spike(4.5) == pytest.approx(0.7)


def test_drop_determinism_under_seed():
    """Same seed -> identical drop sequence; different seed -> (almost
    surely) different; rate approximates the configured probability."""
    a = FaultInjector(FaultConfig(drop_completion_p=0.3, seed=7))
    b = FaultInjector(FaultConfig(drop_completion_p=0.3, seed=7))
    c = FaultInjector(FaultConfig(drop_completion_p=0.3, seed=8))
    draws_a = [a.drop_completion(t) for t in range(500)]
    draws_b = [b.drop_completion(t) for t in range(500)]
    draws_c = [c.drop_completion(t) for t in range(500)]
    assert draws_a == draws_b
    assert draws_a != draws_c
    assert abs(np.mean(draws_a) - 0.3) < 0.08
    assert a.dropped == sum(draws_a)
