"""Direct unit tests for the deterministic fault injector: stall-window
rotation, net-spike windows, seeded completion-drop determinism, the
hard-failure schedules (crashes, partitions) added for crash tolerance,
and the event-timeline representation underneath them (explicit
FaultEvent records; periodic FaultConfig formulas lazily expand onto the
same timeline). Pure functions of virtual time — no engines, no JAX."""
import numpy as np
import pytest

from repro.cluster.faults import (
    FaultConfig, FaultEvent, FaultInjector, TimelineFaultInjector,
)


# ---------------------------------------------------------------------------
# Stalls
# ---------------------------------------------------------------------------

def test_stall_window_and_rotation():
    """Within each period the first ``stall_duration_s`` freezes exactly one
    pool member, and the victim rotates across cycles."""
    fi = FaultInjector(FaultConfig(stall_period_s=10.0, stall_duration_s=2.0))
    # cycle 0 (t in [0, 10)): victim is member 0
    assert fi.stalled("edge", 0, 1.0, pool_size=2)
    assert not fi.stalled("edge", 1, 1.0, pool_size=2)
    assert not fi.stalled("edge", 0, 5.0, pool_size=2)   # window over
    # cycle 1: victim rotates to member 1
    assert fi.stalled("edge", 1, 11.0, pool_size=2)
    assert not fi.stalled("edge", 0, 11.0, pool_size=2)
    assert fi.stall_hits == 2


def test_stall_respects_start_and_tiers():
    fi = FaultInjector(FaultConfig(stall_period_s=10.0, stall_duration_s=2.0,
                                   stall_start_s=100.0,
                                   stall_tiers=("edge",)))
    assert not fi.stalled("edge", 0, 1.0)        # before stall_start_s
    assert fi.stalled("edge", 0, 101.0)
    assert not fi.stalled("cloud", 0, 101.0)     # unlisted tier never stalls


# ---------------------------------------------------------------------------
# Crashes
# ---------------------------------------------------------------------------

def test_crash_window_rotates_like_stalls():
    fi = FaultInjector(FaultConfig(crash_period_s=8.0, crash_duration_s=1.0))
    assert fi.crashed("edge", 0, 0.5, pool_size=2)
    assert not fi.crashed("edge", 1, 0.5, pool_size=2)
    assert not fi.crashed("edge", 0, 2.0, pool_size=2)   # window over
    assert fi.crashed("edge", 1, 8.5, pool_size=2)       # rotated victim
    assert fi.crash_hits == 2


def test_crash_rotate_false_pins_member_zero():
    """The one-flaky-node pattern: every crash lands on pool member 0, the
    case per-engine circuit breakers exist for."""
    fi = FaultInjector(FaultConfig(crash_period_s=5.0, crash_duration_s=1.0,
                                   crash_rotate=False))
    for cycle in range(4):
        t = 5.0 * cycle + 0.25
        assert fi.crashed("edge", 0, t, pool_size=3)
        assert not fi.crashed("edge", 1, t, pool_size=3)
        assert not fi.crashed("edge", 2, t, pool_size=3)


def test_crash_respects_start_and_tiers():
    fi = FaultInjector(FaultConfig(crash_period_s=5.0, crash_duration_s=1.0,
                                   crash_start_s=20.0,
                                   crash_tiers=("cloud",)))
    assert not fi.crashed("cloud", 0, 0.5)
    assert fi.crashed("cloud", 0, 20.5)
    assert not fi.crashed("edge", 0, 20.5)


def test_crash_disabled_by_default():
    fi = FaultInjector(FaultConfig())
    assert not fi.crashed("edge", 0, 1.0)
    assert not fi.partitioned(1.0)
    assert fi.crash_hits == 0 and fi.partition_hits == 0


# ---------------------------------------------------------------------------
# Partitions
# ---------------------------------------------------------------------------

def test_partition_window_phases():
    fi = FaultInjector(FaultConfig(partition_period_s=10.0,
                                   partition_duration_s=3.0,
                                   partition_start_s=5.0))
    assert not fi.partitioned(4.0)     # before start
    assert fi.partitioned(5.5)         # inside first window
    assert fi.partitioned(7.9)
    assert not fi.partitioned(8.5)     # healed
    assert fi.partitioned(15.5)        # next cycle
    assert fi.partition_hits == 3


# ---------------------------------------------------------------------------
# Net spikes and drops
# ---------------------------------------------------------------------------

def test_net_spike_window():
    fi = FaultInjector(FaultConfig(net_spike_period_s=4.0,
                                   net_spike_duration_s=1.0,
                                   net_spike_extra_s=0.7))
    assert fi.net_spike(0.5) == pytest.approx(0.7)
    assert fi.net_spike(2.0) == 0.0
    assert fi.net_spike(4.5) == pytest.approx(0.7)


def test_drop_determinism_under_seed():
    """Same seed -> identical drop sequence; different seed -> (almost
    surely) different; rate approximates the configured probability."""
    a = FaultInjector(FaultConfig(drop_completion_p=0.3, seed=7))
    b = FaultInjector(FaultConfig(drop_completion_p=0.3, seed=7))
    c = FaultInjector(FaultConfig(drop_completion_p=0.3, seed=8))
    draws_a = [a.drop_completion(t) for t in range(500)]
    draws_b = [b.drop_completion(t) for t in range(500)]
    draws_c = [c.drop_completion(t) for t in range(500)]
    assert draws_a == draws_b
    assert draws_a != draws_c
    assert abs(np.mean(draws_a) - 0.3) < 0.08
    assert a.dropped == sum(draws_a)


# ---------------------------------------------------------------------------
# Event timelines (the representation under both injectors)
# ---------------------------------------------------------------------------

def test_timeline_explicit_engine_events():
    """Explicit FaultEvent records with pinned victims: windows are
    half-open [t, t+duration), overlap freely, and never rotate."""
    tl = TimelineFaultInjector([
        FaultEvent(1.0, "stall", 2.0, tier="edge", engine=1),
        FaultEvent(2.0, "crash", 1.5, tier="edge", engine=0),
        FaultEvent(2.5, "partition", 1.0),
    ])
    assert not tl.stalled("edge", 1, 0.9, pool_size=2)
    assert tl.stalled("edge", 1, 1.0, pool_size=2)      # window start incl.
    assert not tl.stalled("edge", 0, 1.5, pool_size=2)  # pinned victim
    assert not tl.stalled("edge", 1, 3.0, pool_size=2)  # window end excl.
    # crash + stall overlap on different members of the same tier
    assert tl.crashed("edge", 0, 2.5, pool_size=2)
    assert tl.stalled("edge", 1, 2.5, pool_size=2)
    assert tl.partitioned(2.5)
    assert not tl.partitioned(3.5)
    assert tl.horizon() == pytest.approx(3.5)
    assert [e.kind for e in tl.events()] == ["stall", "crash", "partition"]


def test_timeline_rotating_victim_resolution():
    """engine=-1 defers victim choice to query time: cycle % pool_size —
    the same schedule retargets correctly for any pool width."""
    ev0 = FaultEvent(0.0, "crash", 1.0, tier="edge", engine=-1, cycle=0)
    ev3 = FaultEvent(9.0, "crash", 1.0, tier="edge", engine=-1, cycle=3)
    tl = TimelineFaultInjector([ev0, ev3])
    assert tl.crashed("edge", 0, 0.5, pool_size=2)
    assert not tl.crashed("edge", 1, 0.5, pool_size=2)
    assert tl.crashed("edge", 1, 9.5, pool_size=2)   # 3 % 2 == 1
    assert tl.crashed("edge", 0, 9.5, pool_size=3)   # 3 % 3 == 0


def test_timeline_drop_windows():
    """A drop window's magnitude is the drop probability; magnitude 1.0
    loses every completion inside the window and none outside."""
    tl = TimelineFaultInjector([FaultEvent(5.0, "drop", 2.0, magnitude=1.0)])
    assert not tl.drop_completion(4.9)       # outside: p==0, no draw
    assert tl.drop_completion(5.5)
    assert tl.drop_completion(6.9)
    assert not tl.drop_completion(7.0)
    assert tl.dropped == 2


def test_fault_event_dict_round_trip():
    """to_dict omits defaults (compact traces) and from_dict restores the
    exact event."""
    full = FaultEvent(3.5, "net_spike", 1.25, tier="cloud", engine=2,
                      magnitude=0.7, cycle=4, params={"edge": 1})
    assert FaultEvent.from_dict(full.to_dict()) == full
    bare = FaultEvent(1.0, "partition")
    assert bare.to_dict() == {"t": 1.0, "kind": "partition"}
    assert FaultEvent.from_dict(bare.to_dict()) == bare


def _closed_form(kind, cfg, tier, i, t, pool_size):
    """The original (pre-timeline) periodic-window formulas, kept here as
    the reference the lazy expansion must match exactly."""
    if kind == "stall":
        period, dur, start, tiers, rotate = (
            cfg.stall_period_s, cfg.stall_duration_s, cfg.stall_start_s,
            cfg.stall_tiers, True)
    else:
        period, dur, start, tiers, rotate = (
            cfg.crash_period_s, cfg.crash_duration_s, cfg.crash_start_s,
            cfg.crash_tiers, cfg.crash_rotate)
    if period <= 0 or t < start or tier not in tiers:
        return False
    phase = (t - start) % period
    cycle = int((t - start) // period)
    victim = cycle % pool_size if rotate else 0
    return phase < min(dur, period) and i == victim


@pytest.mark.parametrize("rotate", [True, False])
def test_lazy_expansion_matches_closed_form(rotate):
    """The timeline compilation of FaultConfig must agree with the original
    closed-form window arithmetic on a dense time grid — including
    duration > period (clamped to the reachable phase range) and
    out-of-order queries (expansion is monotone in max queried time)."""
    cfg = FaultConfig(stall_period_s=3.0, stall_duration_s=1.2,
                      stall_start_s=2.0, stall_tiers=("edge", "cloud"),
                      crash_period_s=2.5, crash_duration_s=4.0,  # > period
                      crash_start_s=1.0, crash_tiers=("edge",),
                      crash_rotate=rotate,
                      partition_period_s=7.0, partition_duration_s=2.0,
                      partition_start_s=3.0,
                      net_spike_period_s=4.0, net_spike_duration_s=1.0,
                      net_spike_extra_s=0.6)
    fi = FaultInjector(cfg)
    grid = [round(0.25 * k, 2) for k in range(100)]       # t in [0, 25)
    # a far-future probe first: expansion must not skip earlier cycles
    assert fi.partitioned(24.5) == _partition_ref(cfg, 24.5)
    for t in grid:
        for tier in ("edge", "cloud"):
            for i in range(3):
                assert fi.stalled(tier, i, t, pool_size=3) == \
                    _closed_form("stall", cfg, tier, i, t, 3), (tier, i, t)
                assert fi.crashed(tier, i, t, pool_size=3) == \
                    _closed_form("crash", cfg, tier, i, t, 3), (tier, i, t)
        assert fi.partitioned(t) == _partition_ref(cfg, t), t
        want = 0.6 if (t % 4.0) < 1.0 else 0.0
        assert fi.net_spike(t) == pytest.approx(want), t


def _partition_ref(cfg, t):
    if cfg.partition_period_s <= 0 or t < cfg.partition_start_s:
        return False
    phase = (t - cfg.partition_start_s) % cfg.partition_period_s
    return phase < min(cfg.partition_duration_s, cfg.partition_period_s)
