import os
import sys

# tests run on ONE device (the dry-run, and only the dry-run, forces 512)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
