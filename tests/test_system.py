"""End-to-end behaviour tests for the EACO-RAG system (paper-level claims,
scaled down for CI): the collaborative gate must (1) respect QoS, (2) cut
cost vs always-cloud at comparable accuracy, and (3) adapt its routing to
context. Also covers the serving engine end-to-end."""
import numpy as np
import pytest

from repro.cluster.simulator import EACOCluster, SimConfig
from repro.data.corpus import wiki_like
from repro.serving.engine import Request, make_edge_engine


@pytest.fixture(scope="module")
def corpus():
    return wiki_like(seed=0)


@pytest.fixture(scope="module")
def eaco_run(corpus):
    sim = EACOCluster(
        corpus, SimConfig(warmup_steps=200, seed=0, qos_min_acc=0.85,
                          qos_max_delay=5.0), policy="eaco")
    sim.run(900)
    return sim


@pytest.fixture(scope="module")
def cloud_run(corpus):
    sim = EACOCluster(corpus, SimConfig(seed=0), policy="fixed:3")
    sim.run(300)
    return sim


def test_eaco_cuts_cost_vs_cloud(eaco_run, cloud_run):
    m_e = eaco_run.metrics()
    m_c = cloud_run.metrics(skip_warmup=False)
    assert m_e["cost_mean"] < 0.5 * m_c["cost_mean"], (
        m_e["cost_mean"], m_c["cost_mean"])
    assert m_e["accuracy"] > m_c["accuracy"] - 0.06


def test_eaco_respects_delay_qos(eaco_run):
    m = eaco_run.metrics()
    assert m["delay_mean"] < 5.0


def test_eaco_uses_multiple_arms(eaco_run):
    m = eaco_run.metrics()
    assert sum(f > 0.05 for f in m["arm_fracs"]) >= 2, m["arm_fracs"]


def test_eaco_routes_multihop_to_stronger_arms(eaco_run):
    logs = [l for l in eaco_run.logs if l.phase == "exploit"]
    mh = [l.arm for l in logs if l.multihop]
    sh = [l.arm for l in logs if not l.multihop]
    if mh and sh:
        assert np.mean(mh) >= np.mean(sh), "multi-hop should escalate more"


def test_fixed_baseline_ordering(corpus):
    """Accuracy must be monotone in strategy strength (paper Table 4)."""
    accs = []
    for pol in ["fixed:0", "fixed:1", "fixed:3"]:
        sim = EACOCluster(corpus, SimConfig(seed=1), policy=pol)
        sim.run(250)
        accs.append(sim.metrics(skip_warmup=False)["accuracy"])
    assert accs[0] < accs[1] < accs[2], accs


def test_knowledge_updates_fire(eaco_run):
    total_updates = sum(s.updates for s in eaco_run.updater.stats.values())
    assert total_updates > 5
    assert all(len(st) <= st.capacity for st in eaco_run.stores.values())


def test_serving_engine_end_to_end():
    eng = make_edge_engine(max_seq=128, seed=0)
    reqs = [Request("What is the capital of France?", max_new_tokens=8),
            Request("Hello", max_new_tokens=8)]
    texts, stats = eng.generate(reqs)
    assert len(texts) == 2
    assert stats.prompt_tokens > 0
    assert 0 <= stats.new_tokens <= 16
    # greedy decoding is deterministic
    texts2, _ = eng.generate(reqs)
    assert texts == texts2
