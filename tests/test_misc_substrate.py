"""Optimizer, tokenizer, pipeline, cost model, sharding rules, HLO cost,
MoE dispatch, workload/network substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # tier-1 must collect without hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.configs.base import MoEConfig
from repro.core.cost_model import (
    PAPER_CLOUD, PAPER_EDGE, CostWeights, inference_tflops, total_cost,
)
from repro.data.corpus import wiki_like
from repro.data.pipeline import PackedLMDataset
from repro.data.tokenizer import ByteTokenizer
from repro.launch.hlo_cost import analyze_hlo
from repro.models.moe import moe_defs, moe_ffn
from repro.models.pdefs import ParamDef, init_from_defs, resolve_axes
from repro.training.optimizer import (
    AdamWConfig, adamw_init, adamw_update, lr_schedule,
)


# ---- optimizer ---------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, grad_clip=100.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(cfg, g, opt, params)
    assert float(loss(params)) < 1e-2


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0,
                      warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    g = {"w": jnp.full(4, 1e6)}
    p2, _, metrics = adamw_update(cfg, g, opt, params)
    assert float(metrics["grad_norm"]) > 1e5
    assert float(jnp.abs(p2["w"]).max()) < 20.0


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert lrs[2] > lrs[3] > lrs[4]
    assert lrs[4] == pytest.approx(0.1, abs=1e-3)


# ---- tokenizer / pipeline ------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.text(max_size=80))
def test_tokenizer_roundtrip(text):
    tok = ByteTokenizer()
    ids = tok.encode(text, bos=True, eos=True)
    assert ids[0] == tok.bos_id and ids[-1] == tok.eos_id
    assert tok.decode(ids) == text.encode("utf-8", "replace").decode("utf-8", "replace")


def test_pad_batch():
    tok = ByteTokenizer()
    out, lens = tok.pad_batch([[1, 2, 3], [4]], 5)
    assert out.shape == (2, 5)
    assert lens.tolist() == [3, 1]
    assert out[1, 1] == tok.pad_id


def test_packed_dataset_batches():
    ds = PackedLMDataset(wiki_like(), seq_len=64, batch=4, vocab_cap=256)
    it = iter(ds)
    x, y = next(it)
    assert x.shape == (4, 64) and y.shape == (4, 64)
    # targets are inputs shifted by one
    assert (x[:, 1:] == y[:, :-1]).all()
    assert ds.n_batches_per_epoch() > 2


# ---- cost model ----------------------------------------------------------------

def test_inference_tflops_matches_table1():
    """Table 1: naive RAG 3632+27 tokens on a 3B model ~ 22-23 TFLOPs."""
    t = inference_tflops(3.0, 3632, 26.6)
    assert 21.0 < t < 23.5


def test_total_cost_weights():
    w = CostWeights(delta1=2.0, delta2=0.5)
    assert total_cost(10.0, 4.0, w) == pytest.approx(22.0)


# ---- sharding rules -------------------------------------------------------------

def _mesh22():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_resolve_axes_drops_nondividing():
    import jax as _jax
    mesh = _jax.make_mesh((1,), ("model",))
    spec = resolve_axes(("heads", None), (14, 64), mesh)
    # 14 % 1 == 0 -> sharded over trivial axis is fine
    assert spec is not None


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 512), st.integers(1, 8))
def test_resolve_axes_divisibility_property(size, _unused):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = resolve_axes(("vocab",), (size,), mesh)
    # with 1-sized axes everything divides; never raises, never duplicates
    used = [s for s in spec if s is not None]
    flat = []
    for u in used:
        flat.extend(u if isinstance(u, tuple) else [u])
    assert len(flat) == len(set(flat))


# ---- MoE dispatch ----------------------------------------------------------------

def test_moe_capacity_drops_bounded():
    m = MoEConfig(n_experts=4, top_k=2, expert_ff=32, capacity_factor=1.0)
    defs = moe_defs(16, m, jnp.float32)
    params = init_from_defs(defs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
    out, aux = moe_ffn(params, x, m, group_size=32, dtype=jnp.float32)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert float(aux) >= 0.0


def test_moe_uniform_router_balanced():
    """With near-uniform routing the aux loss approaches its minimum E*mean."""
    m = MoEConfig(n_experts=4, top_k=1, expert_ff=16, router_aux_weight=1.0)
    defs = moe_defs(8, m, jnp.float32)
    params = init_from_defs(defs, jax.random.PRNGKey(0))
    params["router"] = params["router"] * 0.0       # uniform router
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 8))
    _, aux = moe_ffn(params, x, m, group_size=64, dtype=jnp.float32)
    assert float(aux) == pytest.approx(1.0, abs=0.15)   # E * sum(f*p) ~ 1


# ---- HLO cost analyzer ------------------------------------------------------------

def test_hlo_cost_counts_scan_trips():
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=7)
        return h
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    txt = jax.jit(f).lower(x, w).compile().as_text()
    c = analyze_hlo(txt)
    expect = 7 * 2 * 64 ** 3
    assert abs(c.flops - expect) / expect < 0.05
