"""Closed-loop cluster tests: gate decisions served by REAL engine pools on
one virtual clock, plus the satellite regressions from the clock-mixing PR
(per-instance default configs, single retrieval per step, typed engine
guards that survive ``python -O``)."""
import dataclasses

import pytest

from repro.cluster.network import NetworkConfig, NetworkModel
from repro.cluster.simulator import EACOCluster, SimConfig
from repro.cluster.workload import WorkloadConfig, WorkloadGenerator
from repro.configs import get_config
from repro.data.corpus import wiki_like
from repro.serving.engine import EngineError, Request, ServingEngine, \
    make_edge_engine


@pytest.fixture(scope="module")
def corpus():
    return wiki_like(seed=0)


def small_cfg(**kw) -> SimConfig:
    base = dict(seed=0, n_edges=3, warmup_steps=4, n_edge_engines=1,
                edge_max_seq=128, edge_max_batch=2, cloud_max_seq=128,
                cloud_max_batch=2, max_new_slm=8, max_new_graph=12,
                mean_arrivals=1.2, max_arrivals=3, hot_topic_boost=0.2)
    base.update(kw)
    return SimConfig(**base)


# ---------------------------------------------------------------------------
# Shared mutable default-config instances (evaluated once at def time)
# ---------------------------------------------------------------------------

def test_workload_default_config_not_shared(corpus):
    w1 = WorkloadGenerator(corpus)
    w1.cfg.mean_arrivals = 99.0
    w1.cfg.n_edges = 1
    w2 = WorkloadGenerator(corpus)
    assert w2.cfg.mean_arrivals == WorkloadConfig().mean_arrivals
    assert w2.cfg.n_edges == WorkloadConfig().n_edges


def test_network_default_config_not_shared():
    n1 = NetworkModel()
    n1.cfg.cloud_ms = 1e9
    n2 = NetworkModel()
    assert n2.cfg.cloud_ms == NetworkConfig().cloud_ms


def test_cluster_default_config_not_shared(corpus):
    s1 = EACOCluster(corpus)
    s1.cfg.retrieval_k = 99
    s2 = EACOCluster(corpus)
    assert s2.cfg.retrieval_k == SimConfig().retrieval_k
    assert s1.cfg is not s2.cfg


# ---------------------------------------------------------------------------
# Retrieval runs once per step and rides on the StepLog
# ---------------------------------------------------------------------------

def test_step_retrieves_once_and_exposes_texts(corpus):
    sim = EACOCluster(corpus, SimConfig(seed=0), policy="fixed:1")
    calls = []
    orig = sim._retrieve
    sim._retrieve = lambda *a, **k: (calls.append(1), orig(*a, **k))[1]
    for ev in sim.workload.stream(5):
        n0 = len(calls)
        log = sim.step(ev)
        assert len(calls) == n0 + 1            # exactly one retrieval
        assert log.retrieved                   # texts exposed on the log
        # the exposed texts are the ones the hit was computed from
        assert log.hit == any(ev.qa.answer in t for t in log.retrieved)


# ---------------------------------------------------------------------------
# Admission guards survive python -O (typed exceptions, not bare asserts)
# ---------------------------------------------------------------------------

def test_guard_page_size_alignment():
    with pytest.raises(EngineError):
        make_edge_engine(max_seq=96, max_batch=1, page_size=12)


def test_guard_max_seq_divisibility():
    with pytest.raises(EngineError):
        make_edge_engine(max_seq=100, max_batch=1, page_size=16)


def test_guard_pool_fits_one_request():
    with pytest.raises(EngineError):
        make_edge_engine(max_seq=64, max_batch=1, page_size=16, num_pages=2)


def test_guard_vocab_covers_bytes():
    cfg = dataclasses.replace(get_config("qwen2-0.5b", reduced=True),
                              vocab=16)
    with pytest.raises(EngineError):
        ServingEngine(cfg, max_seq=64, max_batch=1)


def test_guard_unknown_kv_layout():
    with pytest.raises(EngineError):
        make_edge_engine(max_seq=64, max_batch=1, kv_layout="banana")


def test_guard_static_batch_bounds_and_busy_pool():
    eng = make_edge_engine(max_seq=64, max_batch=1, seed=0)
    with pytest.raises(EngineError):
        eng.generate_static([])
    with pytest.raises(EngineError):
        eng.generate_static([Request("a"), Request("b")])
    eng.admit(Request("busy", max_new_tokens=4))
    with pytest.raises(EngineError):
        eng.generate([Request("x")])
    with pytest.raises(EngineError):
        eng.warmup([8])
    while not eng.step():
        pass                                   # drain the resident request
    assert not eng.has_active


# ---------------------------------------------------------------------------
# The closed loop: gate decision -> real engine completion -> gate update
# ---------------------------------------------------------------------------

def _run_closed_loop(corpus, policy="eaco", steps=6):
    sim = EACOCluster(corpus, small_cfg(), policy=policy, backend="engines")
    sim.run(steps)
    return sim


def test_closed_loop_serves_everything(corpus):
    sim = _run_closed_loop(corpus)
    assert len(sim.logs) > 0
    assert sim.sched.pending() == 0 and sim.sched.in_flight() == 0
    assert not sim._pending                    # every submit was finalized
    for pool in sim.sched.pools.values():
        for e in pool:
            assert e.decode_traces <= 1        # zero decode retraces
            assert not e.has_active
    for log in sim.logs:
        assert log.tier in ("edge", "cloud")
        assert log.queue_wait_s >= 0.0
        assert log.engine_s >= 0.0
        assert log.delay > 0.0
        assert log.out_tokens >= 1
        assert log.in_tokens > 0
        # generation location must match the serving tier
        assert (log.tier == "cloud") == (log.arm == 3)
    # the virtual clock moved past the arrival horizon
    assert sim.clock.now() >= steps_horizon(sim)


def steps_horizon(sim):
    return 6 * sim.cfg.arrival_period_s


def test_closed_loop_updates_the_gate(corpus):
    sim = _run_closed_loop(corpus, policy="eaco")
    # past warmup the gate has been updated with engine-measured rewards:
    # its SafeOBO step counter equals the number of finalized completions
    assert sim.gate.obo.t == len(sim.logs) > 0


def test_closed_loop_deterministic_under_fixed_seed(corpus):
    def fingerprint():
        sim = _run_closed_loop(corpus, steps=5)
        return [(l.arm, l.edge_id, round(l.delay, 9),
                 round(l.queue_wait_s, 9), l.out_tokens, l.correct)
                for l in sim.logs]
    assert fingerprint() == fingerprint()


def test_fixed_cloud_policy_uses_cloud_pool_only(corpus):
    sim = _run_closed_loop(corpus, policy="fixed:3", steps=4)
    assert sim.logs and all(l.tier == "cloud" for l in sim.logs)
    assert all(e.decode_rounds == 0 for e in sim.sched.pools["edge"])
