"""Overload robustness: the preemption lifecycle, typed shed / timeout
outcomes, submit-time rejection of never-fitting work, prefix-cache
invalidation on knowledge rotation, and cluster-level failover under
injected faults.

Every guard exercised here is a real exception or typed outcome — this
file is part of the ``make test-opt`` lane and must pass under
``python -O`` (no load-bearing asserts in library code).
"""
import pytest

from repro.cluster.faults import FaultConfig, FaultInjector
from repro.cluster.simulator import EACOCluster, SimConfig
from repro.core.clock import VirtualClock
from repro.data.corpus import wiki_like
from repro.serving.engine import (
    EngineError, Request, make_edge_engine,
)
from repro.serving.scheduler import SchedulerError, Shed, TierScheduler


# ---------------------------------------------------------------------------
# helpers / fixtures
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def corpus():
    return wiki_like(seed=0)


def _serve_ids(eng, request):
    """Serve one request on an idle engine, returning its token ids."""
    rid = eng.admit(request)
    done = {}
    while eng.has_active:
        for ec in eng.step():
            done[ec.req_id] = ec.token_ids
    return done[rid]


def _cluster_cfg(**kw):
    base = dict(seed=0, n_edges=3, warmup_steps=2, n_edge_engines=1,
                edge_max_seq=128, edge_max_batch=2, cloud_max_seq=128,
                cloud_max_batch=2, max_new_slm=8, max_new_graph=12,
                mean_arrivals=1.2, max_arrivals=3)
    base.update(kw)
    return SimConfig(**base)


# ---------------------------------------------------------------------------
# engine level: preempt() frees everything and snapshots enough to resume
# ---------------------------------------------------------------------------
def test_preempt_restores_page_accounting_exactly():
    eng = make_edge_engine(max_seq=64, max_batch=2, seed=0)
    ra = eng.admit(Request("alpha context words for request a", max_new_tokens=8))
    rb = eng.admit(Request("beta context words for request b!", max_new_tokens=8))
    for _ in range(3):
        eng.step()
    snap = eng.preempt(ra)
    assert snap.req_id == ra and len(snap.emitted_ids) == 3
    assert snap.budget_left == 8 - len(snap.emitted_ids)
    assert eng.free_slots == 1
    eng.preempt(rb)
    # both residents reclaimed: every page is free or parked in the LRU
    # cache (refcount 0), none leaked
    assert eng.free_slots == eng.max_batch and not eng.has_active
    assert eng.available_pages == eng.num_pages
    assert all(eng._allocator.refcount(p) == 0
               for p in range(1, eng.num_pages + 1))
    assert eng.preemptions == 2
    # engine still serves fresh work after the reclaim
    texts, _ = eng.generate([Request("gamma words", max_new_tokens=4)])
    assert len(texts) == 1


def test_preempt_unknown_req_id_raises():
    eng = make_edge_engine(max_seq=64, max_batch=1, seed=0)
    with pytest.raises(EngineError):
        eng.preempt(12345)


def test_preempt_resume_token_identical():
    prompt = "Context: some shared retrieval text. Question: what follows?"
    budget = 12
    ref = make_edge_engine(max_seq=96, max_batch=1, seed=0)
    want = _serve_ids(ref, Request(prompt, max_new_tokens=budget))
    assert len(want) > 4

    eng = make_edge_engine(max_seq=96, max_batch=1, seed=0)
    rid = eng.admit(Request(prompt, max_new_tokens=budget))
    for _ in range(4):
        eng.step()
    snap = eng.preempt(rid)
    assert 0 < len(snap.emitted_ids) < budget
    assert snap.prompt_ids == eng.tok.encode(prompt)
    # resume = new admission of prompt + emitted, with the leftover budget;
    # the prefix cache serves the original prompt pages
    h0 = eng.prefix_hits
    resume = Request(prompt, max_new_tokens=snap.budget_left,
                     prompt_ids=snap.prompt_ids + snap.emitted_ids)
    tail = _serve_ids(eng, resume)
    assert eng.prefix_hits == h0 + 1
    assert snap.emitted_ids + tail == want


# ---------------------------------------------------------------------------
# engine level: feasibility is explicit, never a silent truncation
# ---------------------------------------------------------------------------
def test_engine_rejects_unfittable_prompt():
    eng = make_edge_engine(max_seq=64, max_batch=1, seed=0)
    big = Request("x" * 200, max_new_tokens=4)
    assert not eng.fits(big)
    assert not eng.can_admit(big)
    with pytest.raises(EngineError):
        eng.admit(big)
    with pytest.raises(EngineError):
        eng.generate([big])


# ---------------------------------------------------------------------------
# scheduler level: the overload state machine
# ---------------------------------------------------------------------------
def test_scheduler_rejects_unfittable_at_submit():
    eng = make_edge_engine(max_seq=64, max_batch=1, seed=0)
    sched = TierScheduler({"edge": eng})
    with pytest.raises(SchedulerError):
        sched.submit(Request("x" * 200, max_new_tokens=4), "edge")
    # the reject leaves no trace: nothing submitted, drain is a no-op
    assert sched.counters["submitted"] == 0 and sched.pending() == 0
    assert sched.drain() == []
    assert sched.conservation_ok()


def test_scheduler_preempts_batch_for_interactive_token_identical():
    prompts = {
        "batch": ("a longer background batch job prompt with extra words",
                  10, "batch"),
        "inter": ("quick interactive question?", 3, "interactive"),
    }
    want = {}
    for name, (p, n, _slo) in prompts.items():
        ref = make_edge_engine(max_seq=96, max_batch=1, seed=0)
        want[name] = ref.tok.decode(_serve_ids(ref, Request(p, max_new_tokens=n)))

    clock = VirtualClock()
    eng = make_edge_engine(max_seq=96, max_batch=1, seed=0)
    sched = TierScheduler({"edge": eng}, clock=clock)
    b = Request(*prompts["batch"][:2], slo="batch")
    sched.submit(b, "edge", deadline_s=1000.0)
    for _ in range(3):            # admit + decode a few rounds
        sched.pump()
    assert sched.in_flight() == 1
    i = Request(*prompts["inter"][:2], slo="interactive")
    sched.submit(i, "edge", deadline_s=5.0)
    done = {}
    while sched.pending() or sched.in_flight():
        for c in sched.pump():
            done[c.request.prompt] = c
        clock.advance(0.01)
    assert sched.counters["preempted"] == 1
    assert sched.counters["resumed"] == 1
    cb, ci = done[b.prompt], done[i.prompt]
    assert ci.preemptions == 0 and cb.preemptions == 1
    assert ci.slo == "interactive" and cb.slo == "batch"
    # the victim's resumed output is token-identical to an uninterrupted run
    assert cb.text == want["batch"] and ci.text == want["inter"]
    assert cb.new_tokens == prompts["batch"][1]
    assert sched.conservation_ok()
    # pages fully recycled after the preempt/resume churn
    assert eng.available_pages == eng.num_pages


def test_uniform_slo_never_preempts():
    clock = VirtualClock()
    eng = make_edge_engine(max_seq=96, max_batch=1, seed=0)
    sched = TierScheduler({"edge": eng}, clock=clock)
    for k in range(3):
        sched.submit(Request(f"request number {k}", max_new_tokens=4),
                     "edge", deadline_s=clock.now() + 100.0)
    done = sched.drain()
    assert len(done) == 3
    assert sched.counters["preempted"] == 0
    assert all(c.preemptions == 0 for c in done)


def test_shed_overdue_is_typed_not_silent():
    clock = VirtualClock()
    eng = make_edge_engine(max_seq=64, max_batch=1, seed=0)
    sched = TierScheduler({"edge": eng}, clock=clock, shed_overdue=True)
    sched.submit(Request("resident request words", max_new_tokens=6),
                 "edge", deadline_s=50.0)
    sched.pump()                  # resident admitted, slot now full
    late = Request("will be overdue", max_new_tokens=4, slo="interactive")
    sched.submit(late, "edge", deadline_s=1.0)
    clock.advance(2.0)            # deadline passes while queued
    sched.pump()
    sheds = sched.pop_sheds()
    assert len(sheds) == 1 and isinstance(sheds[0], Shed)
    assert sheds[0].reason == "deadline" and sheds[0].request is late
    assert sheds[0].slo == "interactive"
    assert sheds[0].queue_wait_s == pytest.approx(2.0)
    assert sched.counters["shed"] == 1
    assert sched.pop_sheds() == []          # drained
    done = sched.drain()                    # resident still finishes
    assert len(done) == 1
    assert sched.conservation_ok()


def test_timeout_reclaims_stuck_resident():
    clock = VirtualClock()
    eng = make_edge_engine(max_seq=64, max_batch=1, seed=0)
    sched = TierScheduler({"edge": eng}, clock=clock, request_timeout_s=1.0)
    sched.submit(Request("gets stuck on a frozen engine", max_new_tokens=8),
                 "edge", deadline_s=1e9)
    sched.pump()                  # admitted, one healthy decode step
    assert sched.in_flight() == 1
    clock.advance(5.0)            # engine frozen past the timeout
    sched.pump(stalled=lambda tier, i: True)
    sheds = sched.pop_sheds()
    assert [s.reason for s in sheds] == ["timeout"]
    assert sheds[0].emitted_tokens > 0      # partial work is reported
    # slot and pages reclaimed even though the engine itself was "frozen"
    assert sched.in_flight() == 0 and not eng.has_active
    assert eng.available_pages == eng.num_pages
    assert sched.counters["timed_out"] == 1
    assert sched.conservation_ok()


def test_overload_watermark_sheds_batch_keeps_interactive():
    clock = VirtualClock()
    eng = make_edge_engine(max_seq=64, max_batch=1, seed=0)
    sched = TierScheduler({"edge": eng}, clock=clock, overload_watermark=1.0)
    r1 = Request("first batch request", max_new_tokens=2, slo="batch")
    r2 = Request("second batch request", max_new_tokens=2, slo="batch")
    r3 = Request("interactive request", max_new_tokens=2, slo="interactive")
    sched.submit(r1, "edge")              # saturation 0 -> 1.0
    sched.submit(r2, "edge")              # at watermark: batch sheds
    sched.submit(r3, "edge")              # interactive always enqueues
    sheds = sched.pop_sheds()
    assert [s.reason for s in sheds] == ["overload"]
    assert sheds[0].request is r2
    done = sched.drain()
    assert {c.request.prompt for c in done} == {r1.prompt, r3.prompt}
    assert sched.counters["overload_shed"] == 1
    assert sched.conservation_ok()


def test_drain_wedge_raises_typed_error(monkeypatch):
    eng = make_edge_engine(max_seq=64, max_batch=1, seed=0)
    sched = TierScheduler({"edge": eng}, preempt=False)
    sched.submit(Request("fine request", max_new_tokens=2), "edge")
    monkeypatch.setattr(eng, "can_admit", lambda r: False)
    with pytest.raises(SchedulerError):
        sched.drain()


def test_mixed_slo_overload_conserves_every_request():
    clock = VirtualClock()
    eng = make_edge_engine(max_seq=96, max_batch=2, seed=0)
    sched = TierScheduler({"edge": eng}, clock=clock, shed_overdue=True)
    n = 12
    for k in range(n):
        slo = "interactive" if k % 3 == 0 else "batch"
        slack = 0.5 if slo == "interactive" else 50.0
        sched.submit(
            Request(f"request {k} " + "pad " * (k % 4),
                    max_new_tokens=4 + k % 5, slo=slo),
            "edge", deadline_s=clock.now() + slack)
    done = []
    while sched.pending() or sched.in_flight():
        done.extend(sched.pump())
        clock.advance(0.11)
    assert sched.conservation_ok()
    assert sched.counters["submitted"] == n
    assert len(done) + sched.shed_total == n
    assert len(done) == sched.counters["completed"]
    # every shed is typed; nothing vanished silently
    assert all(s.reason in ("deadline", "timeout", "overload")
               for s in sched.pop_sheds())
    assert eng.available_pages == eng.num_pages


# ---------------------------------------------------------------------------
# prefix invalidation: knowledge rotation must not serve stale pages
# ---------------------------------------------------------------------------
def test_prefix_invalidation_forces_full_recompute():
    eng = make_edge_engine(max_seq=128, max_batch=2, seed=0)
    prompt = ("Context: a shared retrieved context block that spans "
              "several KV pages of this engine. Question: and so?")
    req = lambda: Request(prompt, max_new_tokens=4)  # noqa: E731
    want, _ = eng.generate([req()])
    h0 = eng.prefix_hits
    eng.generate([req()])
    assert eng.prefix_hits == h0 + 1        # warm cache serves the prefix

    dropped = eng.invalidate_prefix_cache()
    assert dropped > 0
    m0, ft0 = eng.prefix_misses, eng.prefill_tokens
    got, _ = eng.generate([req()])
    # post-invalidation: a full-prompt miss — every token re-prefills
    assert eng.prefix_misses == m0 + 1
    assert eng.prefill_tokens - ft0 == len(eng.tok.encode(prompt))
    assert got == want                      # same weights -> same answer
    # and the recomputed pages are cacheable again
    h1 = eng.prefix_hits
    eng.generate([req()])
    assert eng.prefix_hits == h1 + 1


def test_invalidate_prefix_cache_noop_without_prefix():
    eng = make_edge_engine(max_seq=64, max_batch=1, seed=0,
                           prefix_cache=False)
    assert eng.invalidate_prefix_cache() == 0


# ---------------------------------------------------------------------------
# cluster level: failover, knowledge-update invalidation, fault injection
# ---------------------------------------------------------------------------
def test_cluster_watermark_fails_over_to_cloud(corpus):
    cfg = _cluster_cfg(overload_watermark=0.0)   # edge always "saturated"
    sim = EACOCluster(corpus, cfg, policy="fixed:0", backend="engines")
    sim.run(4)
    assert sim.logs
    assert all(l.tier == "cloud" for l in sim.logs)
    assert all(l.rerouted for l in sim.logs)
    assert sim.counters["failed_over"] >= len(sim.logs)
    assert sim.conservation_ok()
    assert not sim._pending and not sim._retries


def test_cluster_knowledge_update_invalidates_edge_prefix(corpus):
    cfg = _cluster_cfg(update_trigger=2, warmup_steps=1)
    sim = EACOCluster(corpus, cfg, policy="fixed:1", backend="engines")
    calls = {"n": 0}
    for e in sim.sched.pools["edge"]:
        orig = e.invalidate_prefix_cache

        def spy(_orig=orig):
            calls["n"] += 1
            return _orig()

        e.invalidate_prefix_cache = spy
    sim.run(6)
    assert sim.counters["prefix_invalidations"] > 0
    assert calls["n"] >= sim.counters["prefix_invalidations"]
    assert sim.conservation_ok()


def test_cluster_survives_faults_with_typed_outcomes(corpus):
    faults = FaultInjector(FaultConfig(
        stall_period_s=2.0, stall_duration_s=0.5,
        net_spike_period_s=3.0, net_spike_duration_s=0.5,
        net_spike_extra_s=0.2, drop_completion_p=0.3, seed=1))
    cfg = _cluster_cfg(request_timeout_s=3.0)
    sim = EACOCluster(corpus, cfg, backend="engines", faults=faults)
    sim.run(6)
    # graceful degradation: the loop finishes, every query has a typed
    # terminal outcome, and the books balance
    assert sim.conservation_ok()
    assert not sim._pending and not sim._retries
    c = sim.counters
    assert c["submitted"] == c["completed"] + c["shed"] + c["failed"]
    assert c["dropped_completions"] == faults.dropped
    assert c["retries"] >= 1                # seed chosen so faults do bite
    assert all(l.outcome in ("ok", "shed", "failed") for l in sim.logs)
    m = sim.metrics()
    assert m["counters"]["submitted"] == c["submitted"]


def test_cluster_conservation_default_config(corpus):
    sim = EACOCluster(corpus, _cluster_cfg(), backend="engines")
    sim.run(4)
    assert sim.conservation_ok()
    c = sim.counters
    assert c["submitted"] == c["completed"]       # no knobs -> no sheds
    assert c["shed"] == c["failed"] == c["failed_over"] == 0
