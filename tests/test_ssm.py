"""SSM correctness: chunked scans vs exact per-step recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SSMConfig
from repro.models.mamba2 import mamba2_defs, mamba2_dims, mamba2_scan, mamba2_step
from repro.models.pdefs import init_from_defs
from repro.models.rwkv6 import (
    channel_mix, rwkv6_defs, time_mix, time_mix_step,
)


@pytest.mark.parametrize("S,chunk", [(32, 8), (64, 16), (48, 48)])
def test_mamba2_chunked_equals_stepwise(S, chunk):
    d = 32
    s = SSMConfig(d_state=8, d_head=16, expand=2, conv_width=4, chunk=chunk)
    defs = mamba2_defs(d, s, jnp.float32)
    params = init_from_defs(defs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, S, d)) * 0.5

    y_scan, final = mamba2_scan(params, x, s)

    # exact sequential reference via mamba2_step
    d_in, H = mamba2_dims(d, s)
    state = jnp.zeros((2, H, s.d_head, s.d_state), jnp.float32)
    conv = jnp.zeros((2, s.conv_width - 1, d_in + 2 * s.d_state), jnp.float32)
    outs = []
    for t in range(S):
        y1, state, conv = mamba2_step(params, x[:, t : t + 1], s, state, conv)
        outs.append(y1)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_seq),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(final), np.asarray(state),
                               atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("S,chunk", [(32, 8), (64, 32), (64, 64)])
def test_rwkv_chunked_equals_sequential(S, chunk):
    d, d_head = 32, 16
    defs = rwkv6_defs(d, 64, d_head, jnp.float32)
    params = init_from_defs(defs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, S, d)) * 0.5
    y_seq, S_seq, _ = time_mix(params["tm"], x, d_head, chunk=1)
    y_chk, S_chk, _ = time_mix(params["tm"], x, d_head, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_chk),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(S_seq), np.asarray(S_chk),
                               atol=2e-4, rtol=2e-3)


def test_rwkv_fullseq_equals_stepwise():
    d, d_head, S = 32, 16, 24
    defs = rwkv6_defs(d, 64, d_head, jnp.float32)
    params = init_from_defs(defs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (1, S, d)) * 0.5
    y_full, S_full, _ = time_mix(params["tm"], x, d_head)
    H = d // d_head
    state = jnp.zeros((1, H, d_head, d_head), jnp.float32)
    x_last = jnp.zeros((1, 1, d), x.dtype)
    outs = []
    for t in range(S):
        y1, state, x_last = time_mix_step(params["tm"], x[:, t : t + 1],
                                          d_head, state, x_last)
        outs.append(y1)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_seq),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(S_full), np.asarray(state),
                               atol=2e-4, rtol=2e-3)


def test_channel_mix_shift_consistency():
    d = 16
    defs = rwkv6_defs(d, 32, 8, jnp.float32)
    params = init_from_defs(defs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 6, d))
    y_full, _ = channel_mix(params["cm"], x)
    # stepwise with explicit shift state
    x_last = jnp.zeros((1, 1, d))
    outs = []
    for t in range(6):
        y1, x_last = channel_mix(params["cm"], x[:, t : t + 1], x_last)
        outs.append(y1)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(outs, 1)),
                               atol=1e-5, rtol=1e-5)


def test_mamba2_decay_bounded():
    """All SSD decay exponentials must stay in (0, 1] (numerical safety)."""
    d = 32
    s = SSMConfig(d_state=8, d_head=16, chunk=16)
    defs = mamba2_defs(d, s, jnp.float32)
    params = init_from_defs(defs, jax.random.PRNGKey(5))
    x = 10.0 * jax.random.normal(jax.random.PRNGKey(6), (1, 64, d))
    y, final = mamba2_scan(params, x, s)
    assert bool(jnp.isfinite(y).all())
    assert bool(jnp.isfinite(final).all())
