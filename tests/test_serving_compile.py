"""Engine compile stability: the continuous-batching engine must run all
fixed-shape jitted functions (decode step, sampling, page copy) from a
single trace no matter how the serving mix changes, and prefill from at
most one trace per power-of-two pad bucket. The engine's ``trace_counts``
increment inside each traced body, so a retrace is directly observable."""
import pytest

from repro.serving.engine import Request, make_edge_engine
from repro.serving.scheduler import TierScheduler


@pytest.fixture(scope="module")
def engine():
    return make_edge_engine(max_seq=128, max_batch=4, seed=0)


def test_decode_traces_once_across_stream_shapes(engine):
    """Two streams with different batch sizes and prompt lengths — plus the
    static path — must never re-trace the decode step."""
    stream_a = [Request("short", max_new_tokens=3),
                Request("b" * 40, max_new_tokens=5)]
    engine.generate(stream_a)
    assert engine.trace_counts["decode"] == 1

    stream_b = [Request("c" * (4 + 9 * i), max_new_tokens=2 + i % 3)
                for i in range(7)]                     # 7 reqs > max_batch
    engine.generate(stream_b)
    assert engine.trace_counts["decode"] == 1

    engine.generate_static(stream_a)
    assert engine.trace_counts["decode"] == 1


def test_sample_copy_and_insert_trace_counts_stable(engine):
    """Sampling compiles once per logits batch shape (1 for admission,
    max_batch for decode); the CoW page copy compiles at most once; the
    paged engine never uses the contiguous lane insert (suffix prefill
    writes straight into pages)."""
    before = dict(engine.trace_counts)
    engine.generate([Request("hello world", max_new_tokens=4),
                     Request("x" * 70, max_new_tokens=3)])
    assert engine.trace_counts["insert"] == before["insert"] == 0
    assert engine.trace_counts["sample"] == before["sample"] == 2
    assert engine.trace_counts["copy"] <= 1


def test_prefill_compiles_per_pow2_bucket_only(engine):
    """Prefill pads the (suffix) prompt to power-of-two buckets: a prompt
    landing in an already-seen bucket must not add a trace, and total
    prefill traces stay bounded by the bucket count."""
    before = engine.trace_counts["prefill"]
    engine.generate([Request("a" * 69, max_new_tokens=2)])   # bucket 128
    mid = engine.trace_counts["prefill"]
    engine.generate([Request("b" * 73, max_new_tokens=2)])   # same bucket
    assert engine.trace_counts["prefill"] == mid
    assert mid - before <= 1
    # lifetime bound: buckets are 8, 16, ..., max_seq
    assert engine.pad_buckets == [8, 16, 32, 64, 128]
    assert engine.trace_counts["prefill"] <= len(engine.pad_buckets)


def test_scheduler_pump_does_not_retrace(engine):
    """Continuous admission through the scheduler — slots freeing and
    refilling at varying occupancy, prefix hits remapping shared pages —
    keeps the single decode trace."""
    sched = TierScheduler({"edge": engine})
    for i in range(9):
        sched.submit(Request(f"req {i} " + "y" * (3 * i),
                             max_new_tokens=1 + i % 4), "edge")
    done = sched.drain()
    assert len(done) == 9
    assert engine.trace_counts["decode"] == 1


def test_warmup_precompiles_everything(engine):
    """After warmup, serving previously-unseen prompt lengths — including
    prefix-cache hits whose suffix lands in a SMALLER bucket than any full
    prompt — triggers zero traces of any kind."""
    engine.warmup([1, engine.max_seq])     # compiles every pow2 bucket
    before = dict(engine.trace_counts)
    engine.generate([Request("z" * 30, max_new_tokens=2),
                     Request("z" * 30 + "!", max_new_tokens=2),   # hit
                     Request("w" * (engine.cfg.q_chunk + 20),
                             max_new_tokens=2)])
    assert engine.trace_counts == before


def test_contiguous_insert_still_single_trace():
    """The contiguous fallback keeps the lane insert and compiles it
    exactly once across a mixed stream."""
    eng = make_edge_engine(max_seq=64, max_batch=2, seed=0,
                           kv_layout="contiguous")
    eng.generate([Request("hello", max_new_tokens=2),
                  Request("v" * 40, max_new_tokens=3),
                  Request("w" * 20, max_new_tokens=2)])
    assert eng.trace_counts["insert"] == 1
    assert eng.trace_counts["decode"] == 1
    assert eng.trace_counts["copy"] == 0
