"""Deterministic simulation testing: schedule-generator determinism,
green runs with per-pump oracles, byte-identical replay, planted-bug
drills with ddmin shrinking, and the audit/introspection surfaces the
oracles are built on (PageAllocator.audit, engine assert_quiescent,
scheduler debug_state_dict). One module-scoped harness shares the
engine pools across tests."""
import json

import pytest

from repro.cluster.dst import (
    DSTHarness, DSTViolation, generate_schedule, make_failure_predicate,
    replay_trace, run_dst, shrink_schedule,
)
from repro.cluster.faults import FAULT_KINDS, FaultEvent
from repro.core.clock import VirtualClock
from repro.serving.paging import PageAllocator, PagingError
from repro.serving.scheduler import TierScheduler


@pytest.fixture(scope="module")
def harness():
    return DSTHarness()


# ---------------------------------------------------------------------------
# Schedule generator
# ---------------------------------------------------------------------------

def test_generate_schedule_deterministic():
    a = generate_schedule(7)
    b = generate_schedule(7)
    c = generate_schedule(8)
    assert a == b
    assert a != c
    assert all(isinstance(e, FaultEvent) for e in a)
    assert [e.t for e in a] == sorted(e.t for e in a)
    # every event is either an injector fault or a harness workload event
    assert {e.kind for e in a} <= set(FAULT_KINDS) | {
        "arrivals", "knowledge", "slo_shift"}
    # schedules always carry work (an empty universe proves nothing)
    assert any(e.kind == "arrivals" for e in a)


def test_schedule_survives_json_round_trip():
    events = generate_schedule(3)
    back = [FaultEvent.from_dict(json.loads(json.dumps(e.to_dict())))
            for e in events]
    assert back == events


# ---------------------------------------------------------------------------
# Green runs, oracles on every pump
# ---------------------------------------------------------------------------

def test_green_run_checks_every_pump(harness):
    res = run_dst(0, harness=harness)
    assert res.ok and res.failure is None
    assert res.n_pumps >= 1
    assert len(res.snapshots) == res.n_pumps   # one oracle pass per pump
    led = res.ledger
    assert led["submitted"] >= 1
    assert led["submitted"] == (led["delivered"] + led["dropped"]
                                + led["shed"])
    for snap in res.snapshots:
        assert "violations" not in snap
        assert snap["counters"]["submitted"] >= 0
        for tier, reports in snap["pages"].items():
            for rep in reports:
                if not rep.get("skipped"):
                    assert (rep["free"] + rep["cached"] + rep["active"]
                            == rep["num_pages"])


def test_replay_is_byte_identical(harness):
    res = run_dst(1, harness=harness)
    assert res.ok
    replayed, matched = replay_trace(res.trace(), harness)
    assert matched
    assert replayed.n_pumps == res.n_pumps
    assert (json.dumps(replayed.snapshots, sort_keys=True)
            == json.dumps(res.snapshots, sort_keys=True))


# ---------------------------------------------------------------------------
# Planted-bug drills: each bug is caught by ITS oracle and shrinks small
# ---------------------------------------------------------------------------

def _first_failing(harness, bug, n=6):
    for s in range(n):
        res = run_dst(s, harness=harness, bug=bug)
        if res.failure is not None:
            return s, res
    raise AssertionError(f"{bug} never caught across {n} seeds")


def test_leak_page_caught_and_shrunk(harness):
    """The acceptance drill: a skipped refcount decrement must be caught
    by the page-audit oracle and ddmin-shrink to <= 5 events."""
    seed, res = _first_failing(harness, "leak_page")
    assert res.failure_oracle == "page-audit"
    assert "refcount mismatch" in res.failure or "leak" in res.failure
    pred = make_failure_predicate(harness, inj_seed=seed, bug="leak_page",
                                  oracle="page-audit")
    mini = shrink_schedule(res.events, pred)
    assert 0 < len(mini) <= 5
    # minimal repro still fails the same way WITH the bug...
    again = harness.run(mini, seed=seed, inj_seed=seed, bug="leak_page")
    assert again.failure_oracle == "page-audit"
    # ...and passes without it: the schedule isolates the bug, not noise
    clean = harness.run(mini, seed=seed, inj_seed=seed)
    assert clean.ok


def test_epoch_regress_caught(harness):
    _, res = _first_failing(harness, "epoch_regress")
    assert res.failure_oracle == "epoch"
    assert "regressed" in res.failure


def test_breaker_jump_caught(harness):
    _, res = _first_failing(harness, "breaker_jump")
    assert res.failure_oracle == "breaker"
    assert "teleported" in res.failure


def test_violation_carries_snapshot(harness):
    _, res = _first_failing(harness, "leak_page")
    snap = res.snapshots[-1]
    assert snap["violations"]
    assert snap["violations"][0].startswith("page-audit")


# ---------------------------------------------------------------------------
# Audit surfaces the oracles are built on
# ---------------------------------------------------------------------------

def test_page_allocator_audit_accounts_every_page():
    a = PageAllocator(8)
    ids = [int(p) for p in a.alloc(3)]
    rep = a.audit({p: 1 for p in ids})
    assert rep == {"num_pages": 8, "free": 5, "cached": 0, "active": 3}
    a.free(ids)
    assert a.audit({}) == {"num_pages": 8, "free": 8, "cached": 0,
                           "active": 0}


def test_page_allocator_audit_catches_refcount_mismatch():
    a = PageAllocator(8)
    ids = [int(p) for p in a.alloc(2)]
    with pytest.raises(PagingError, match="refcount mismatch"):
        a.audit({ids[0]: 1})      # second page mapped nowhere yet ref 1
    with pytest.raises(PagingError, match="refcount mismatch"):
        a.audit({ids[0]: 2, ids[1]: 1})


def test_page_allocator_audit_catches_leak():
    a = PageAllocator(4)
    ids = [int(p) for p in a.alloc(1)]
    a._refs[ids[0]] = 0           # simulate a lost page: no state owns it
    with pytest.raises(PagingError, match="page leak"):
        a.audit()


def test_engine_audit_and_quiescence(harness):
    e = harness.pools["edge"][0]
    e.crash()
    e.restart()   # cold engine: earlier drill tests leaked pages on purpose
    e.assert_quiescent()          # idle engine: zero active pages
    rep = e.audit()
    assert rep["active"] == 0
    assert rep["free"] + rep["cached"] + rep["active"] == rep["num_pages"]
    e.crash()
    assert e.audit().get("skipped") == 1   # dead engine has no arena
    e.assert_quiescent()                   # and is trivially quiescent
    e.restart()
    e.assert_quiescent()


def test_debug_state_dict_json_round_trip(harness):
    sched = TierScheduler(harness.pools, clock=VirtualClock(),
                          breaker_threshold=2)
    d = sched.debug_state_dict(now=1.5)
    assert set(d) == {"t", "tiers", "counters", "conservation_ok", "fences"}
    assert set(d["tiers"]) == {"edge", "cloud"}
    for td in d["tiers"].values():
        assert td["queued"] == 0
        for ed in td["engines"]:
            assert ed["residents"] == 0 and not ed["dead"]
            assert ed["breaker"]["state"] == "closed"
    assert d == json.loads(json.dumps(d))   # JSON-serializable, lossless
    # the human rendering embeds the same dict on its json= line
    text = sched.debug_state(now=1.5)
    tail = [ln for ln in text.splitlines() if ln.startswith("json=")]
    assert len(tail) == 1
    assert json.loads(tail[0][len("json="):]) == d
    assert sched.fences_ok()
