"""Serving scheduler + continuous-batching engine behaviour."""
import pytest

from repro.core.clock import VirtualClock
from repro.serving.engine import Request, make_edge_engine
from repro.serving.scheduler import TierScheduler


@pytest.fixture(scope="module")
def engine():
    return make_edge_engine(max_seq=96, max_batch=3, seed=0)


@pytest.fixture()
def sched(engine):
    assert not engine.has_active
    return TierScheduler({"edge": engine})


# ---------------------------------------------------------------------------
# Admission / slot reuse
# ---------------------------------------------------------------------------

def test_slot_reuse_streams_past_max_batch(engine, sched):
    """11 requests stream through 3 slots; occupancy never exceeds the pool
    and every request completes exactly once."""
    for i in range(11):
        sched.submit(Request(f"query number {i}", max_new_tokens=2 + i % 5),
                     "edge")
    assert sched.pending() == 11
    peak, done = 0, []
    while sched.pending() or sched.in_flight():
        done.extend(sched.pump())
        peak = max(peak, engine.active_slots)
    assert peak == engine.max_batch == 3
    assert engine.free_slots == 3
    assert len(done) == 11
    assert sorted(c.request.prompt for c in done) == \
        sorted(f"query number {i}" for i in range(11))


def test_admission_is_incremental(engine, sched):
    """A freed slot is refilled mid-stream: with budgets (1, 8) and a queued
    third request, the third is admitted long before the 8-token request
    finishes."""
    sched.submit(Request("aaaa", max_new_tokens=1), "edge")
    sched.submit(Request("bbbb", max_new_tokens=8), "edge")
    sched.submit(Request("cccc", max_new_tokens=8), "edge")
    sched.submit(Request("dddd", max_new_tokens=1), "edge")
    done = sched.pump()               # admits first 3 (pool of 3), one step
    assert sched.pending() == 1
    while sched.in_flight() or sched.pending():
        done.extend(sched.pump())
    # the 1-token requests finish first; "dddd" was admitted into a freed
    # slot while bbbb/cccc were still decoding
    assert [c.request.prompt for c in done][:2] == ["aaaa", "dddd"]
    assert len(done) == 4


# ---------------------------------------------------------------------------
# Deadline ordering across tiers
# ---------------------------------------------------------------------------

def test_deadline_priority_within_tier(engine, sched):
    sched.submit(Request("late", max_new_tokens=2), "edge", deadline_s=10.0)
    sched.submit(Request("urgent", max_new_tokens=2), "edge", deadline_s=1.0)
    done = sched.drain()
    assert done[0].request.prompt == "urgent"


def test_deadline_ordering_across_tiers():
    """Each tier serves its own deadline heap; completions carry the tier."""
    edge = make_edge_engine(max_seq=64, max_batch=1, seed=0)
    cloud = make_edge_engine(max_seq=64, max_batch=1, seed=1)
    sched = TierScheduler({"edge": edge, "cloud": cloud})
    for tier in ("edge", "cloud"):
        sched.submit(Request(f"{tier}-late", max_new_tokens=2), tier,
                     deadline_s=50.0)
        sched.submit(Request(f"{tier}-urgent", max_new_tokens=2), tier,
                     deadline_s=1.0)
    done = sched.drain()
    assert len(done) == 4
    for tier in ("edge", "cloud"):
        order = [c.request.prompt for c in done if c.tier == tier]
        assert order == [f"{tier}-urgent", f"{tier}-late"]


def test_unknown_tier_rejected(sched):
    with pytest.raises(KeyError):
        sched.submit(Request("x"), "nonexistent")


# ---------------------------------------------------------------------------
# Per-request completion accounting
# ---------------------------------------------------------------------------

def test_completion_accounting(engine, sched):
    reqs = [Request("what is rag", max_new_tokens=3),
            Request("hello there serving engine", max_new_tokens=5)]
    for r in reqs:
        sched.submit(r, "edge")
    done = sched.drain()
    assert len(done) == 2
    by_prompt = {c.request.prompt: c for c in done}
    for r in reqs:
        c = by_prompt[r.prompt]
        assert c.tier == "edge"
        assert c.queue_wait_s >= 0.0
        assert c.time_in_engine_s > 0.0
        assert c.prompt_tokens == len(engine.tok.encode(r.prompt))
        assert 0 < c.new_tokens <= r.max_new_tokens
        assert len(engine.tok.encode(c.text, bos=False)) == c.new_tokens


# ---------------------------------------------------------------------------
# Logical-clock timing (the old wall/logical clock-mixing bug: submit took a
# logical now= but pump always subtracted it from time.perf_counter)
# ---------------------------------------------------------------------------

def test_queue_wait_exact_under_injected_clock(engine):
    """Queue waits are EXACT logical-time differences when a virtual clock
    drives the scheduler — no wall-clock leakage anywhere."""
    clock = VirtualClock()
    sched = TierScheduler({"edge": engine}, clock=clock)
    sched.submit(Request("hello", max_new_tokens=2), "edge")  # enqueue @ 0.0
    clock.advance(3.5)
    done = list(sched.pump(now=clock.now()))       # admitted @ exactly 3.5
    rounds = 1
    while not done:
        clock.advance(0.25)
        done = sched.pump(now=clock.now())
        rounds += 1
    c = done[0]
    assert c.queue_wait_s == 3.5                   # exact, not approximate
    assert c.time_in_engine_s == 0.25 * (rounds - 1)
    assert c.engine_wall_s > 0.0                   # real compute happened


def test_pump_now_overrides_per_round(engine):
    """submit(now=...) + pump(now=...) pin every timing to caller time even
    while the scheduler's own clock default would disagree."""
    sched = TierScheduler({"edge": engine})        # default wall clock
    sched.submit(Request("hi", max_new_tokens=1), "edge", now=100.0)
    t, done = 107.0, []
    while not done:
        done = sched.pump(now=t)
        t += 1.0
    assert done[0].queue_wait_s == 7.0


def test_scheduler_clock_is_used_without_now(engine):
    """With an injected clock, calls WITHOUT now= read that clock — never
    the wall clock."""
    clock = VirtualClock(start=50.0)
    sched = TierScheduler({"edge": engine}, clock=clock)
    sched.submit(Request("yo", max_new_tokens=1), "edge")
    clock.advance(2.0)
    done = []
    while not done:
        done = sched.pump()
    assert done[0].queue_wait_s == 2.0


# ---------------------------------------------------------------------------
# Engine pools behind one tier
# ---------------------------------------------------------------------------

def test_tier_pool_spreads_load():
    """A tier backed by a pool of engines admits the queue head into ANY
    member with capacity: two max_batch=1 engines serve two requests in the
    same round."""
    pool = [make_edge_engine(max_seq=64, max_batch=1, seed=i)
            for i in range(2)]
    sched = TierScheduler({"edge": pool})
    for i in range(4):
        sched.submit(Request(f"req {i}", max_new_tokens=2), "edge")
    first = sched.pump()
    assert sched.in_flight("edge") + len(first) == 2   # both members busy
    done = list(first) + sched.drain()
    assert len(done) == 4
    assert {c.engine_index for c in done} == {0, 1}
    assert all(c.tier == "edge" for c in done)


# ---------------------------------------------------------------------------
# Per-slot decode budgets (the old static-batch clamp bug)
# ---------------------------------------------------------------------------

def test_budgets_are_per_slot(engine):
    """A short prompt sharing a batch with a near-max_seq prompt keeps its
    full max_new_tokens; only the long prompt is clamped by max_seq. (The
    seed engine clamped every request by the LONGEST prompt in the batch.)"""
    long_req = Request("a" * 60, max_new_tokens=40)    # 61 toks -> budget 35
    short_req = Request("Hello", max_new_tokens=40)    # 6 toks -> budget 40
    texts, stats = engine.generate([long_req, short_req])
    n_long = len(engine.tok.encode(texts[0], bos=False))
    n_short = len(engine.tok.encode(texts[1], bos=False))
    assert n_long <= 96 - 61 == 35
    # greedy on the seed-0 random model never emits EOS for these prompts,
    # so the short request must run to its own full budget
    assert n_short == 40


# ---------------------------------------------------------------------------
# Continuous path == static path (greedy, token-identical)
# ---------------------------------------------------------------------------

def test_continuous_matches_static_greedy(engine):
    reqs = [Request("What is the capital of France?", max_new_tokens=6),
            Request("Hello", max_new_tokens=9),
            Request("a" * 60, max_new_tokens=40),
            Request("tiered rag serving", max_new_tokens=4),
            Request("edge node", max_new_tokens=12),
            Request("q" * 30, max_new_tokens=7),
            Request("adaptive knowledge update", max_new_tokens=11)]
    continuous, _ = engine.generate(reqs)
    static = []
    for i in range(0, len(reqs), engine.max_batch):
        ts, _ = engine.generate_static(reqs[i:i + engine.max_batch])
        static.extend(ts)
    assert continuous == static
    # and the continuous path is itself deterministic
    again, _ = engine.generate(reqs)
    assert again == continuous
