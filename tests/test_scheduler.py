"""Serving scheduler behaviour."""
import pytest

from repro.serving.engine import Request, make_edge_engine
from repro.serving.scheduler import TierScheduler


@pytest.fixture(scope="module")
def sched():
    edge = make_edge_engine(max_seq=96, seed=0)
    return TierScheduler({"edge": edge})


def test_batching_respects_max_batch(sched):
    for i in range(11):
        sched.submit(Request(f"query number {i}", max_new_tokens=2), "edge")
    done = sched.step()
    assert len(done) == sched.engines["edge"].max_batch
    assert sched.pending() == 11 - len(done)
    rest = sched.drain()
    assert sched.pending() == 0
    assert len(done) + len(rest) == 11


def test_deadline_priority(sched):
    sched.submit(Request("late", max_new_tokens=2), "edge", deadline_s=10.0)
    sched.submit(Request("urgent", max_new_tokens=2), "edge", deadline_s=1.0)
    done = sched.drain()
    assert done[0].request.prompt == "urgent"


def test_unknown_tier_rejected(sched):
    with pytest.raises(KeyError):
        sched.submit(Request("x"), "nonexistent")
